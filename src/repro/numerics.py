"""Dtype-aware numeric sentinels (the PR 3 bf16 lesson, as a library).

Hardcoded extrema like ``-3e38`` are a dtype bug waiting to happen: a value
chosen to be "large but finite in float32" is only finite in *some* target
dtypes. bfloat16 shares float32's exponent range but its largest finite value
is smaller (``(2 - 2^-7) * 2^127`` vs ``(2 - 2^-23) * 2^127``), so float32
extrema round **up to inf** under an f32 -> bf16 cast — the exact failure that
made +inf padding sentinels match real queries in PR 3, and that turns an
additive attention mask into NaN logits after softmax max-subtraction.

These helpers derive every sentinel from ``jnp.finfo`` of the dtype that will
actually hold the value, so there is no literal to rot when a model flips
``param_dtype`` or a carrier array is quantized.

Query-bound sanitization (±inf -> finite extrema on the *kernel comparison
dtype*) lives in ``core.types.finite_query_bounds``, built on the same
``finfo``-derived extrema. mdrqlint's ``sentinel`` rule (DESIGN.md §12) flags
``3e38``-family literals and steers device-facing code to one of the two.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["finite_min", "finite_max", "mask_fill"]


def finite_min(dtype) -> float:
    """Most negative finite value representable in ``dtype``, as a float."""
    return float(jnp.finfo(jnp.dtype(dtype)).min)


def finite_max(dtype) -> float:
    """Largest finite value representable in ``dtype``, as a float."""
    return float(jnp.finfo(jnp.dtype(dtype)).max)


def mask_fill(dtype=jnp.bfloat16) -> float:
    """Additive attention-mask fill: large negative, finite in ``dtype``.

    Pass the *narrowest* dtype the masked scores may ever be cast to (the
    default, bfloat16, survives bf16 <-> f32 round trips). The 0.7 factor
    keeps headroom so adding real score magnitudes on top of the fill cannot
    overflow ``dtype`` before the softmax zeroes the lane; ``exp`` of any
    value at this scale underflows to exactly 0 in every float dtype.
    """
    return 0.7 * finite_min(dtype)
