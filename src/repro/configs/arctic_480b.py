"""arctic-480b — 128-expert top-2 MoE with dense residual FFN every layer
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True),
)
