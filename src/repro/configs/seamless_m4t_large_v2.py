"""seamless-m4t-large-v2 — enc-dec multimodal backbone; audio frontend is a
stub supplying precomputed frame embeddings [arXiv:2308.11596]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    encoder_layers=24, frontend="audio", tie_embeddings=True,
)
