"""repro.configs — one module per assigned architecture + base dataclasses."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, SHAPES

ARCH_IDS = [
    "smollm_360m", "h2o_danube_1_8b", "phi3_medium_14b", "qwen3_8b",
    "arctic_480b", "deepseek_moe_16b", "mamba2_780m",
    "seamless_m4t_large_v2", "llava_next_34b", "recurrentgemma_2b",
]


def get_config(name: str) -> ModelConfig:
    """Load the ModelConfig for an architecture id (dashes or underscores)."""
    mod_name = name.replace("-", "_")
    if mod_name not in ARCH_IDS:
        raise ValueError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    import importlib
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "SHAPES", "ARCH_IDS", "get_config"]
