"""Config system: model/parallelism/shape configuration dataclasses.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``;
``repro.models.registry`` turns a config into a runnable model. Configs are
plain frozen dataclasses — serializable, diffable, and cheap to reduce for
smoke tests (``reduced()``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0             # shared (always-on) experts, DeepSeekMoE
    dense_residual: bool = False  # dense FFN in parallel with MoE (Arctic)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None         # default d_model // n_heads
    qk_norm: bool = False                  # Qwen3-style per-head RMS on q/k
    sliding_window: Optional[int] = None   # SWA window (h2o-danube / Mistral)
    local_window: Optional[int] = None     # hybrid local-attn window (Griffin)
    layer_pattern: Optional[str] = None    # hybrid pattern, e.g. "rra"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontends are STUBS: input_specs provides precomputed embeddings
    frontend: Optional[str] = None         # None | "vision" | "audio"
    n_prefix_embeds: int = 0               # patch/frame embeddings per sample
    # training / performance knobs (hillclimbing levers, §Perf)
    remat: str = "full"                    # none | full
    grad_accum: int = 1
    scan_layers: bool = True
    q_chunk: int = 2048                    # attention query-chunk length
    attn_scores_f32: bool = True           # False: bf16 streaming softmax
    attn_batch_shard: bool = False         # policy-C fix: 2D batch-shard attn
    prefill_last_only: bool = False        # unembed only the final position
    seq_shard_resid: bool = False          # residual stream seq-sharded over
                                           # `model` (FSDP-ish: partitioner
                                           # gathers weights, not activations)
    kv_cache_int8: bool = False            # quantized KV cache (decode)
    kv_block_prune: int = 0                # keep top-k key blocks (0 = off)
    kv_block_size: int = 512               # zone-map block granularity
    kv_prune_groups: int = 0               # >0: top-k/groups WITHIN each block
                                           # group (shard-local, no x-dev gather)
    # dtype policy: weights/activations bf16, master+opt f32 (mixed precision)
    param_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=max(2, min(3, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            n_prefix_embeds=8 if self.n_prefix_embeds else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            sliding_window=64 if self.sliding_window else None,
            local_window=32 if self.local_window else None,
            remat="none",
            grad_accum=1,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(2, self.moe.top_k),
                d_ff_expert=64, n_shared=min(1, self.moe.n_shared))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk=32)
        if self.layer_pattern is not None:
            kw["n_layers"] = 3  # one full "rra"-style group
        return self.replace(**kw)

    # -- parameter counting (for MODEL_FLOPS = 6*N*D roofline term) --------
    def param_counts(self) -> dict[str, float]:
        """Returns dict with total and active (per-token) parameter counts."""
        d, hd = self.d_model, self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        dense_ffn = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        per_layer_total = per_layer_active = 0.0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            heads = d_in // s.head_dim
            zxbcdt = d * (2 * d_in + 2 * s.n_groups * s.state_dim + heads)
            per_layer_total = per_layer_active = zxbcdt + d_in * d + 2 * heads
        elif self.family == "hybrid":
            # average over the layer pattern
            pat = self.layer_pattern or "r"
            n_rec = pat.count("r") / len(pat)
            n_att = 1.0 - n_rec
            rec = 3 * d * d + 2 * d  # in/gate/out projections + lru params
            per_layer_total = per_layer_active = (
                n_rec * rec + n_att * attn + dense_ffn)
        else:
            per_layer_total = per_layer_active = attn
            if self.moe is not None:
                mo = self.moe
                e_ffn = 3 * d * mo.d_ff_expert
                per_layer_total += mo.n_experts * e_ffn + mo.n_shared * e_ffn + d * mo.n_experts
                per_layer_active += mo.top_k * e_ffn + mo.n_shared * e_ffn + d * mo.n_experts
                if mo.dense_residual:
                    per_layer_total += dense_ffn
                    per_layer_active += dense_ffn
            else:
                per_layer_total += dense_ffn
                per_layer_active += dense_ffn

        n_dec = self.n_layers
        total = n_dec * per_layer_total
        active = n_dec * per_layer_active
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + dense_ffn)
            total += enc
            active += enc
            # decoder cross-attention
            total += n_dec * attn
            active += n_dec * attn
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb
        return {"total": total, "active": active}
