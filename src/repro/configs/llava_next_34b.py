"""llava-next-34b — VLM decoder backbone; anyres vision frontend is a stub
supplying precomputed patch embeddings [hf:llava-hf/llava-v1.6]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    frontend="vision", n_prefix_embeds=576, tie_embeddings=False,
)
