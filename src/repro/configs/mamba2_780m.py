"""mamba2-780m — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,  # attn unused
    d_ff=0, vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
)
