"""recurrentgemma-2b — Griffin: RG-LRU + local attention, (rec,rec,attn)
pattern, 26 = 8*3 + 2 layers [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    local_window=2048, layer_pattern="rra", tie_embeddings=True,
)
