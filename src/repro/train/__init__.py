"""repro.train — optimizer, train step, checkpointing, fault-tolerant loop."""
from repro.train.optimizer import OptConfig, init_opt_state, adamw_update, opt_state_pspecs
from repro.train.train_step import make_train_step, compressed_psum
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainerConfig, SimulatedPreemption

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "opt_state_pspecs",
           "make_train_step", "compressed_psum", "CheckpointManager",
           "Trainer", "TrainerConfig", "SimulatedPreemption"]
