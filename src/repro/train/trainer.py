"""Trainer: the fault-tolerant training loop.

Fault tolerance model (designed for 1000+ node fleets, exercised at container
scale):
  * checkpoints every ``ckpt_every`` steps, async + atomic (checkpoint.py);
  * the data pipeline is a pure function of (seed, step) -> resume is exact;
  * ``run`` wraps each step in a retry loop: a ``SimulatedPreemption`` (or any
    transient error from an injected failure hook) triggers restore-from-
    latest and replay, the production behaviour of a preempted pod;
  * straggler mitigation: batches are prefetched on a background thread with
    bounded queue depth; a slow host overlaps with device compute;
  * elastic restart: restore() may target a different mesh (see checkpoint.py)
    — ``Trainer.remesh`` rebuilds shardings and re-places the state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.kernels import ops
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


class SimulatedPreemption(RuntimeError):
    """Raised by failure-injection hooks to exercise the recovery path."""


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    max_retries: int = 3


class Trainer:
    def __init__(self, model, pipeline, opt_cfg: OptConfig,
                 ckpt_dir: str, tcfg: TrainerConfig = TrainerConfig(),
                 failure_hook: Optional[Callable[[int], None]] = None,
                 grad_accum: int = 1):
        self.model = model
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.failure_hook = failure_hook
        self.ckpt = CheckpointManager(ckpt_dir)
        self.train_step = jax.jit(make_train_step(model, opt_cfg, grad_accum),
                                  donate_argnums=(0, 1))
        self.params = None
        self.opt_state = None
        self.step = 0
        self.metrics_log: list[dict] = []

    # -- state management ----------------------------------------------------
    def init_state(self, seed: int = 0) -> None:
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.opt_state = init_opt_state(self.params)
        self.step = 0

    def state(self) -> dict:
        return {"params": self.params, "opt": self.opt_state,
                "step": np.asarray(self.step)}

    def save(self) -> None:
        self.ckpt.save_async(self.step, self.state())

    def try_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        like = {"params": self.params, "opt": self.opt_state,
                "step": np.asarray(self.step)}
        restored = self.ckpt.restore(latest, like)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = int(restored["step"])
        return True

    # -- loop -----------------------------------------------------------------
    def run(self) -> list[dict]:
        assert self.params is not None, "call init_state() or try_resume() first"
        retries = 0
        while self.step < self.tcfg.num_steps:
            try:
                self._one_step()
                retries = 0
            except SimulatedPreemption:
                # production path: pod died -> restore latest ckpt, replay
                retries += 1
                if retries > self.tcfg.max_retries:
                    raise
                self.ckpt.wait()
                if not self.try_resume():
                    self.init_state()
        self.ckpt.wait()
        return self.metrics_log

    def _one_step(self) -> None:
        if self.failure_hook is not None:
            self.failure_hook(self.step)  # may raise SimulatedPreemption
        batch = self.pipeline.batch(self.step)
        t0 = time.perf_counter()
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, batch)
        # counted host sync: blocking on the loss is the step's backpressure
        loss = float(ops.device_get(metrics["loss"]))
        dt = time.perf_counter() - t0
        self.step += 1
        if self.step % self.tcfg.log_every == 0 or self.step == 1:
            rec = {"step": self.step, "loss": loss, "sec": dt,
                   "grad_norm": float(ops.device_get(metrics["grad_norm"]))}
            self.metrics_log.append(rec)
        if self.step % self.tcfg.ckpt_every == 0:
            self.save()
