"""Checkpointing: atomic commits, async writes, elastic restore.

Layout: ``<dir>/step_<N>/`` containing one ``arrays.npz`` (flattened pytree,
key = tree path) + ``manifest.json`` (step, tree structure, shapes, dtypes,
crc of the npz). Commit protocol: write into ``step_<N>.tmp`` then
``os.rename`` — readers only ever see complete checkpoints, so a preemption
mid-write can never corrupt the restore path.

Elastic restore: arrays are saved as full logical tensors (gathered), so a
restore may target a *different* mesh — ``restore(..., shardings=...)``
re-shards on load. (On a real multi-host pod each host writes its own shard
files and restore does a distributed gather; single-process container keeps
the same interface with host-local files.)

Async: ``save_async`` hands the (host-fetched) state to a writer thread;
training continues; ``wait()`` joins before the next save or shutdown.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _crc(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while chunk := f.read(1 << 20):
            crc = zlib.crc32(chunk, crc)
    return crc


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def save(self, step: int, state: Any) -> str:
        """Synchronous atomic save. ``state`` is any pytree of arrays."""
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(state)
        npz_path = os.path.join(tmp, "arrays.npz")
        # npz cannot represent ml_dtypes (bf16, fp8): store raw uint views and
        # record the logical dtype in the manifest.
        dtypes = {k: str(v.dtype) for k, v in flat.items()}
        raw = {k: (v.view(np.uint16) if str(v.dtype) == "bfloat16" else v)
               for k, v in flat.items()}
        np.savez(npz_path, **raw)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                     for k, v in flat.items()},
            "crc32": _crc(npz_path),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()
        return final

    def save_async(self, step: int, state: Any) -> None:
        """Fetch to host, then write on a background thread."""
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                self.save(step, host_state)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None,
                verify: bool = True) -> Any:
        """Restore into the structure of ``like`` (values replaced).

        ``shardings``: optional matching tree (or prefix) of NamedSharding for
        elastic placement onto the current mesh.
        """
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        npz_path = os.path.join(d, "arrays.npz")
        if verify and manifest["crc32"] != _crc(npz_path):
            raise IOError(f"checkpoint {d} failed crc verification")
        data = np.load(npz_path)
        flat_like, tdef = jax.tree_util.tree_flatten(like)
        paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
        keys = ["/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
                for p in paths]
        leaves = []
        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree_util.tree_flatten(shardings)[0]
        for i, (k, lk) in enumerate(zip(keys, flat_like)):
            arr = data[k]
            logical = manifest["keys"][k]["dtype"]
            if logical == "bfloat16" and arr.dtype == np.uint16:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(lk.shape):
                raise ValueError(f"{k}: checkpoint shape {arr.shape} != {lk.shape}")
            arr = arr.astype(lk.dtype)
            if shard_flat is not None and i < len(shard_flat):
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(tdef, leaves)
