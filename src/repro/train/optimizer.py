"""AdamW with mixed precision and ZeRO-1 optimizer-state sharding (pure JAX).

Policy: parameters live in bf16 (the compute dtype); the optimizer state
holds f32 master weights + first/second moments. ZeRO-1: every optimizer-state
leaf is additionally sharded over the `data` mesh axis on the first free
(unsharded, divisible) dimension — cutting optimizer memory by up to
|data axis| with zero extra collectives beyond the partitioner-inserted
gather at update time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.params import Param, is_param, DEFAULT_RULES

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac * peak."""
    s = step.astype(F32)
    warm = cfg.peak_lr * (s + 1.0) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params) -> dict:
    """params: Param tree (bf16 values) -> opt state with f32 master/moments."""
    # copy=True: f32 params must not alias the master buffer (donation safety)
    master = jax.tree.map(lambda p: jnp.array(p.value, dtype=F32, copy=True),
                          params, is_leaf=is_param)
    zeros = jax.tree.map(jnp.zeros_like, master)
    return {
        "master": master,
        "m": zeros,
        "v": jax.tree.map(jnp.zeros_like, master),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads_values, opt_state, cfg: OptConfig):
    """One AdamW step.

    Args:
      params: Param tree (bf16 values).
      grads_values: plain value tree (same structure as params' values), any
        float dtype (cast to f32 internally).
      opt_state: from init_opt_state.

    Returns (new_params Param tree, new opt_state, metrics dict).
    """
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads_values)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(master, m, v, g):
        g = g.astype(F32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        master2 = master - lr * (update + cfg.weight_decay * master)
        return master2, m2, v2

    flat_master, tdef = jax.tree.flatten(opt_state["master"])
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_g = tdef.flatten_up_to(grads_values)
    out = [upd(a, b, c, d) for a, b, c, d in zip(flat_master, flat_m, flat_v, flat_g)]
    master2 = jax.tree.unflatten(tdef, [o[0] for o in out])
    m2 = jax.tree.unflatten(tdef, [o[1] for o in out])
    v2 = jax.tree.unflatten(tdef, [o[2] for o in out])

    def cast_back(p: Param, mv):
        return Param(mv.astype(p.value.dtype), p.axes)

    new_params = jax.tree.map(cast_back, params, master2, is_leaf=is_param)
    new_state = {"master": master2, "m": m2, "v": v2, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of the optimizer state
# ---------------------------------------------------------------------------
def zero1_pspec(axes: tuple, shape: tuple, rules: dict, data_axes=("data",),
                data_size: int = 16) -> P:
    """Param pspec with the first free divisible dim additionally data-sharded."""
    base = [rules.get(a) if a is not None else None for a in axes]
    for i, (r, s) in enumerate(zip(base, shape)):
        if r is None and s % data_size == 0:
            base[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            break
    return P(*base)


def opt_state_pspecs(params, rules: dict | None = None, data_axes=("data",),
                     data_size: int = 16):
    """PartitionSpec tree for init_opt_state(params) with ZeRO-1 layout."""
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def f(p: Param):
        return zero1_pspec(p.axes, p.value.shape, rules, data_axes, data_size)

    leaf_specs = jax.tree.map(f, params, is_leaf=is_param)
    return {
        "master": leaf_specs,
        "m": leaf_specs,
        "v": leaf_specs,
        "step": P(),
    }
