"""Train-step builder: value_and_grad + microbatch accumulation + AdamW.

Distributed-optimization features (DESIGN.md §5):
  * gradient accumulation — ``grad_accum`` microbatches via lax.scan; the DP
    all-reduce of gradients happens once per step (not per microbatch) because
    the partitioner hoists the reduction out of the accumulated f32 tree;
  * ZeRO-1 — optimizer state sharded over data (see optimizer.py);
  * optional int8 gradient compression for the explicit shard_map DP variant
    (``compressed_psum``) — quantize per-leaf, integer all-reduce, dequantize.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.params import Param, is_param, split_tree
from repro.train.optimizer import OptConfig, adamw_update

F32 = jnp.float32


def _split_microbatches(batch: dict, accum: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(model, opt_cfg: OptConfig, grad_accum: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    ``params`` is a Param tree; grads are taken w.r.t. the bf16 values and
    accumulated/updated in f32 (mixed precision).
    """

    # Param is a registered pytree node (axes = static aux), so we can
    # differentiate the Param tree directly; grads come back as a Param tree.
    grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads_p = grad_fn(params, batch)
            grads = jax.tree.map(lambda p: p.value, grads_p, is_leaf=is_param)
        else:
            micro = _split_microbatches(batch, grad_accum)

            def acc_step(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g_p = grad_fn(params, mb)
                g = jax.tree.map(lambda p: p.value, g_p, is_leaf=is_param)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(F32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            values, _ = split_tree(params)
            g0 = jax.tree.map(lambda v: jnp.zeros(v.shape, F32), values)
            (grads, loss_sum), _ = jax.lax.scan(acc_step, (g0, jnp.zeros((), F32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {"loss": loss, "aux_loss": jnp.zeros((), F32)}

        new_params, new_opt, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["total_loss"] = loss
        return new_params, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# int8 gradient compression (explicit-DP / shard_map variant)
# ---------------------------------------------------------------------------
def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    amax = jnp.max(jnp.abs(g.astype(F32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads, axis_name: str):
    """All-reduce gradients in int8: quantize -> int32 psum -> dequantize.

    Communication drops 4x vs f32 (2x vs bf16) at ~0.4% relative error per
    tensor (validated in tests). Scales are psum-maxed so dequantization is
    consistent across replicas.
    """
    def one(g):
        scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(g.astype(F32))) / 127.0,
                                         1e-12), axis_name)
        q = jnp.clip(jnp.round(g.astype(F32) / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        n = jax.lax.psum(jnp.ones((), F32), axis_name)
        return (total.astype(F32) * scale) / n

    return jax.tree.map(one, grads)
