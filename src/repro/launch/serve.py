"""Production serving entrypoint: continuous batching + MDRQ admission.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --reduced \\
      --requests 8 --slots 4 [--ckpt-dir /tmp/ckpt]

Loads the latest checkpoint when --ckpt-dir is given (random init otherwise),
then serves a synthetic request queue through the BatchServer. Decode-side
§Perf knobs are CLI-selectable (--kv-int8, --kv-prune).
"""
import argparse
import sys

import numpy as np
import jax

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve import BatchServer, Request, admission_query
from repro.train import CheckpointManager, init_opt_state


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--kv-prune", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.kv_int8:
        cfg = cfg.replace(kv_cache_int8=True)
    if args.kv_prune:
        cfg = cfg.replace(kv_block_prune=args.kv_prune, kv_block_size=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        step = mgr.latest_step()
        if step is not None:
            state = mgr.restore(step, {"params": params,
                                       "opt": jax.eval_shape(init_opt_state, params),
                                       "step": np.asarray(0)})
            params = state["params"]
            print(f"[serve] loaded checkpoint step {step}", flush=True)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 16))).astype(np.int32),
                    max_new=args.max_new,
                    features=np.array([rng.random(), 8, 100.0, rng.random()],
                                      np.float32))
            for i in range(args.requests)]
    srv = BatchServer(model, params, slots=args.slots, max_len=args.max_len)
    done = srv.serve(reqs, admission_query())
    print(f"[serve] completed {len(done)}/{len(reqs)} "
          f"(admission-filtered); kv_int8={args.kv_int8} "
          f"kv_prune={args.kv_prune}", flush=True)
    for r in done:
        print(f"[serve] req {r.rid}: {r.output[:8].tolist()}...", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
