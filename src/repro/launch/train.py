"""Production training entrypoint.

  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \\
      --steps 100 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

On a real pod this runs under the production mesh (--mesh single|multi) with
the per-arch sharding policy; on this CPU box use --reduced for a smoke-scale
run on one device. Resume is automatic from --ckpt-dir.
"""
import argparse
import sys

import jax

from repro.configs import get_config
from repro.data import DataConfig, FilteredTokenPipeline
from repro.models.registry import build_model
from repro.train import OptConfig, Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    pipe = FilteredTokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, n_pool=16384, seed=args.seed))
    print(f"[train] arch={cfg.name} devices={len(jax.devices())} "
          f"admitted={pipe.admitted.size} samples via "
          f"{pipe.filter_stats.method}", flush=True)

    tr = Trainer(model, pipe,
                 OptConfig(peak_lr=args.lr, warmup_steps=max(5, args.steps // 20),
                           decay_steps=args.steps),
                 args.ckpt_dir,
                 TrainerConfig(num_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               log_every=max(1, args.steps // 20)),
                 grad_accum=args.grad_accum)
    if tr.try_resume():
        print(f"[train] resumed at step {tr.step}", flush=True)
    else:
        tr.init_state()
    log = tr.run()
    for r in log:
        print(f"[train] step={r['step']} loss={r['loss']:.4f} "
              f"gnorm={r['grad_norm']:.3f} {r['sec']:.2f}s", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
