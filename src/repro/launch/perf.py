import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: re-lower the three chosen cells with optimization
variants and record roofline deltas next to the paper-faithful baselines.

Cells (EXPERIMENTS.md §Perf):
  * smollm_360m  x train_4k    — worst useful-flops ratio (policy-C attention)
  * qwen3_8b     x prefill_32k — most collective-bound
  * arctic_480b  x decode_32k  — most paper-representative (KV streaming =
    the paper's scan; zone-map block pruning = its MBR prune on key blocks)

Usage: PYTHONPATH=src:. python -m repro.launch.perf [--cell smollm] [--multi-pod]
"""
import argparse
import sys
import traceback

from repro.configs import get_config
from repro.launch.dryrun import RESULTS_DIR, run_cell

VARIANTS = {
    "smollm_360m/train_4k": [
        ("__opt1_attn2d", dict(attn_batch_shard=True)),
        ("__opt2_seqshard", dict(seq_shard_resid=True)),
        ("__opt3_bf16scr", dict(seq_shard_resid=True, attn_scores_f32=False)),
        ("__opt4_qchunk4k", dict(seq_shard_resid=True, attn_scores_f32=False,
                                 q_chunk=4096)),
    ],
    "qwen3_8b/prefill_32k": [
        ("__opt1_lastonly", dict(prefill_last_only=True)),
        ("__opt2_seqshard", dict(prefill_last_only=True,
                                 seq_shard_resid=True)),
        ("__opt3_bf16scr", dict(prefill_last_only=True,
                                seq_shard_resid=True,
                                attn_scores_f32=False)),
    ],
    # generalization of the cell-1 winner to the other policy-C train cells
    "llava_next_34b/train_4k": [
        ("__opt_seqshard", dict(seq_shard_resid=True)),
    ],
    "phi3_medium_14b/train_4k": [
        ("__opt_seqshard", dict(seq_shard_resid=True)),
    ],
    "arctic_480b/train_4k": [
        ("__opt_seqshard", dict(seq_shard_resid=True)),
    ],
    "arctic_480b/decode_32k": [
        ("__opt1_int8kv", dict(kv_cache_int8=True)),
        ("__opt2_prune16", dict(kv_cache_int8=True, kv_block_prune=16,
                                kv_block_size=512)),
        ("__opt3_prune8", dict(kv_cache_int8=True, kv_block_prune=8,
                               kv_block_size=512)),
        ("__opt4_pruneloc", dict(kv_cache_int8=True, kv_block_prune=16,
                                 kv_block_size=512, kv_prune_groups=16)),
        ("__opt5_p_noq8", dict(kv_block_prune=16, kv_block_size=512,
                               kv_prune_groups=16)),
    ],
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="", help="substring filter")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    failures = 0
    for cell, variants in VARIANTS.items():
        if args.cell and args.cell not in cell:
            continue
        arch, shape = cell.split("/")
        for tag, overrides in variants:
            cfg = get_config(arch).replace(**overrides)
            try:
                run_cell(arch, shape, args.multi_pod, args.out,
                         cfg_override=cfg, tag=tag)
            except Exception:
                failures += 1
                print(f"FAILED [{cell} {tag}]", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
