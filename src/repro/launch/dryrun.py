import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective analysis.

MUST be the process entrypoint (the XLA_FLAGS line above runs before any jax
import — jax locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm_360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh, data_axis_size
from repro.models import shardctx
from repro.models.registry import (batch_axes, build_model, make_cell,
                                   shape_applicable, sharding_rules)
from repro.models.params import sharding_tree
from repro.serve.serve_step import make_serve_step
from repro.train.optimizer import OptConfig, init_opt_state, opt_state_pspecs
from repro.train.train_step import make_train_step

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             rules_override=None, verbose: bool = True, cfg_override=None,
             tag: str = "") -> dict:
    """Lower+compile one cell; returns the result record.

    Three compiles per cell (§Roofline methodology):
      1. FULL model, scanned layers  -> sharding validation + memory_analysis
         (the production graph; compiles fast because HLO is compact);
      2. 1-unit model, unrolled      -> cost_analysis + collective bytes;
      3. 2-unit model, unrolled      -> ditto.
    Costs are exactly linear in the layer count for homogeneous stacks, so
      cost(L) = cost(1) + (L-1) * (cost(2) - cost(1)).
    This sidesteps two XLA facts measured on this backend: (a) cost analysis
    counts a while-loop body ONCE, so the scanned graph under-reports by ~L x;
    (b) fully unrolled compiles take minutes per cell on one CPU core.
    A full-unroll spot check validates the extrapolation (see EXPERIMENTS.md).
    """
    from benchmarks import roofline as RL

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag,
           "ts": time.time()}
    if not ok:
        rec.update(status="skipped", reason=why)
        return _save(rec, out_dir)

    seq, batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_override or sharding_rules(cfg)

    # ---- compile 1: full model, scanned (sharding validation + memory) -----
    full_cfg = cfg.replace(scan_layers=True)
    lowered, t_lower = _lower_for(full_cfg, arch, shape, kind, mesh,
                                  multi_pod, rules)
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    # ---- compiles 2+3: 1-unit / 2-unit unrolled (cost extraction) ----------
    units, cfg1, cfg2 = _unit_configs(cfg)
    rls = []
    for c in (cfg1, cfg2):
        lw, _ = _lower_for(c, arch, shape, kind, mesh, multi_pod,
                           rules_override or sharding_rules(c))
        cp = lw.compile()
        rls.append(RL.from_compiled(cp, cp.as_text()))
    rl = RL.extrapolate(rls[0], rls[1], units)

    n_chips = int(np.prod(list(mesh.shape.values())))
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    mf = RL.model_flops(cfg, tokens, train=(kind == "train"))

    counts = cfg.param_counts()
    rec.update(
        status="ok", kind=kind, chips=n_chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        tokens_per_step=tokens,
        params_total=counts["total"], params_active=counts["active"],
        model_flops=mf,
        model_flops_per_device=mf / n_chips,
        useful_flops_ratio=(mf / n_chips) / rl.flops_per_device
        if rl.flops_per_device else None,
        memory_analysis=mem,
        cost_method=f"L1/L2 extrapolation, units={units}",
        **rl.summary(),
    )
    if verbose:
        print(f"[{arch} x {shape} x {mesh_name}] kind={kind} "
              f"compile={t_compile:.1f}s flops/dev={rl.flops_per_device:.3e} "
              f"useful={rec['useful_flops_ratio'] or 0:.2f} "
              f"dominant={rl.dominant} "
              f"(c={rl.compute_s*1e3:.2f}ms m={rl.memory_s*1e3:.2f}ms "
              f"coll={rl.collective_s*1e3:.2f}ms)", flush=True)
    return _save(rec, out_dir)


def _unit_configs(cfg):
    """(units, 1-unit cfg, 2-unit cfg) for the linear cost extrapolation."""
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // 3
        tail = cfg.n_layers - 3 * n_groups
        return (n_groups,
                cfg.replace(n_layers=3 + tail, scan_layers=False),
                cfg.replace(n_layers=6 + tail, scan_layers=False))
    if cfg.encoder_layers:
        return (cfg.n_layers,
                cfg.replace(n_layers=1, encoder_layers=1, scan_layers=False),
                cfg.replace(n_layers=2, encoder_layers=2, scan_layers=False))
    return (cfg.n_layers,
            cfg.replace(n_layers=1, scan_layers=False),
            cfg.replace(n_layers=2, scan_layers=False))


def _lower_for(cfg, arch, shape, kind, mesh, multi_pod, rules):
    """Build + lower the cell function for one config variant."""
    shardctx.set_ctx(mesh, batch_axes(multi_pod))
    model = build_model(cfg)
    cell = make_cell(arch, shape, multi_pod=multi_pod, cfg=cfg)
    params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_sh = sharding_tree(params_abs, mesh, rules)

    t0 = time.perf_counter()
    if kind == "train":
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        opt_sh = _named(mesh, opt_state_pspecs(
            params_abs, rules, data_axes=("data",),
            data_size=mesh.shape["data"]))
        batch_sh = _named(mesh, cell.input_pspecs)
        step = make_train_step(model, OptConfig(), grad_accum=cfg.grad_accum)
        jitted = jax.jit(step,
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, cell.inputs)
    elif kind == "prefill":
        batch_sh = _named(mesh, cell.input_pspecs)
        fn = jax.jit(model.prefill, in_shardings=(param_sh, batch_sh))
        lowered = fn.lower(params_abs, cell.inputs)
    else:  # decode
        cache_sh = _named(mesh, cell.cache_pspecs)
        tok_sh = _named(mesh, cell.input_pspecs)
        fn = jax.jit(make_serve_step(model),
                     in_shardings=(param_sh, cache_sh,
                                   tok_sh["tokens"], tok_sh["pos"]),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,))
        lowered = fn.lower(params_abs, cell.cache_specs,
                           cell.inputs["tokens"], cell.inputs["pos"])
    return lowered, time.perf_counter() - t0


def _save(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{rec.get('tag','')}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        try:
            run_cell(a, s, mp, args.out)
        except Exception:
            failures += 1
            print(f"FAILED [{a} x {s} x {'2x16x16' if mp else '16x16'}]",
                  flush=True)
            traceback.print_exc()
    print(f"dry-run complete: {len(cells) - failures}/{len(cells)} cells ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
