"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and everything else (tests, benches) sees the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axis_size(mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.shape:
        size *= mesh.shape["pod"]
    return size


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
