"""Continuous batching with MDRQ-based admission control.

The serving router is the second place the paper's engine is a first-class
feature (DESIGN.md §3): each request carries a feature vector (priority,
prompt length, SLO deadline, estimated cost, ...) and the admission filter is
a partial-match MDRQ over the pending queue — planner-selected access path,
exactly like the training pipeline's sample filter.

The batcher keeps B decode slots hot: finished/empty slots are refilled from
the admitted queue each step (continuous batching); prompts are prefilled
token-by-token through the same decode path (small-scale container execution;
the chunked ``prefill`` entry point exists for real deployments).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dataset, MDRQEngine, RangeQuery
from repro.kernels import ops
from repro.serve.serve_step import greedy_sample, make_serve_step

REQUEST_FEATURES = ["priority", "prompt_len", "deadline_ms", "est_cost"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    features: np.ndarray          # (4,) float32
    output: Optional[np.ndarray] = None


def admission_query(max_cost: float = 0.8, min_priority: float = 0.2) -> RangeQuery:
    return RangeQuery.partial(len(REQUEST_FEATURES),
                              {0: (min_priority, 1.0), 3: (0.0, max_cost)})


class BatchServer:
    """Fixed-slot continuous batcher over a decode model."""

    def __init__(self, model, params, slots: int = 4, max_len: int = 256):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cfg = model.cfg
        dt = jnp.dtype(self.cfg.param_dtype)
        self.cache = model.init_cache(slots, max_len, dt)
        self.step_fn = jax.jit(make_serve_step(model), donate_argnums=(1,))
        self.pos = np.zeros((slots,), np.int32)
        self.active: list[Optional[Request]] = [None] * slots
        self.remaining = np.zeros((slots,), np.int32)
        self.pending_tok = np.zeros((slots, 1), np.int32)
        self.gen: list[list[int]] = [[] for _ in range(slots)]
        self.to_feed: list[list[int]] = [[] for _ in range(slots)]
        self.done: list[Request] = []

    # -- admission ------------------------------------------------------------
    @staticmethod
    def admit(requests: list[Request], query: RangeQuery) -> list[Request]:
        """MDRQ admission filter over the pending queue."""
        if not requests:
            return []
        feats = Dataset(np.stack([r.features for r in requests]).T)
        eng = MDRQEngine(feats, structures=("scan",))
        ids = eng.query(query, method="scan_vertical")
        return [requests[i] for i in ids]

    # -- slot management --------------------------------------------------------
    def _fill_slot(self, s: int, req: Request) -> None:
        self.active[s] = req
        self.remaining[s] = req.max_new
        self.gen[s] = []
        self.to_feed[s] = list(req.prompt.tolist())
        self.pos[s] = 0
        # reset slot cache region: positions restart; ring/full caches are
        # masked by pos so stale keys beyond pos are never attended to.

    def serve(self, requests: list[Request], query: Optional[RangeQuery] = None
              ) -> list[Request]:
        """Run until all admitted requests complete; returns finished list."""
        queue = self.admit(requests, query or admission_query())
        queue = queue[::-1]  # pop from the end
        while queue or any(a is not None for a in self.active):
            for s in range(self.slots):
                if self.active[s] is None and queue:
                    self._fill_slot(s, queue.pop())
            toks = np.zeros((self.slots, 1), np.int32)
            for s in range(self.slots):
                if self.active[s] is None:
                    continue
                if self.to_feed[s]:
                    toks[s, 0] = self.to_feed[s].pop(0)
                else:
                    toks[s, 0] = self.gen[s][-1]
            logits, self.cache = self.step_fn(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.pos))
            # counted host sync: the decode loop's per-step device->host read
            # (serve-side syncs show up in the host_sync counter budget)
            nxt = ops.device_get(greedy_sample(logits, self.cfg.vocab_size))[:, 0]
            for s in range(self.slots):
                if self.active[s] is None:
                    continue
                self.pos[s] += 1
                if not self.to_feed[s]:  # prompt consumed -> generating
                    self.gen[s].append(int(nxt[s]))
                    self.remaining[s] -= 1
                    if self.remaining[s] <= 0 or self.pos[s] >= self.max_len - 1:
                        req = self.active[s]
                        req.output = np.asarray(self.gen[s], np.int32)
                        self.done.append(req)
                        self.active[s] = None
        return self.done
