"""repro.serve — decode steps, continuous batching, MDRQ admission."""
from repro.serve.serve_step import make_serve_step, make_prefill, greedy_sample
from repro.serve.batching import BatchServer, Request, admission_query

__all__ = ["make_serve_step", "make_prefill", "greedy_sample",
           "BatchServer", "Request", "admission_query"]
