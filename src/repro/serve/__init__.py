"""repro.serve — decode steps, continuous batching, MDRQ admission, and the
throughput-oriented batched MDRQ query server."""
from repro.serve.serve_step import make_serve_step, make_prefill, greedy_sample
from repro.serve.batching import BatchServer, Request, admission_query
from repro.serve.mdrq_server import MDRQServer, ServerStats, Ticket
from repro.serve.pipeline import (Overloaded, PipelinedMDRQServer,
                                  PipelineTicket, WarmupReport,
                                  serve_pipelined)

__all__ = ["make_serve_step", "make_prefill", "greedy_sample",
           "BatchServer", "Request", "admission_query",
           "MDRQServer", "ServerStats", "Ticket",
           "Overloaded", "PipelinedMDRQServer", "PipelineTicket",
           "WarmupReport", "serve_pipelined"]
