"""AOT-warmed, double-buffered MDRQ serving pipeline (DESIGN.md §13).

``MDRQServer`` is deliberately synchronous: every flush pays plan + launch +
host sync + host finalize back-to-back on one thread, so the device idles
while Python runs ``np.nonzero`` and the admission loop idles while the
device scans. ``PipelinedMDRQServer`` splits the flush along the seam the
core layer now exposes (``MDRQEngine.launch_batch`` -> ``PendingBatch``):

  * **device stage** (admission thread): plan the window and issue every
    bucket's fused launch — jax dispatch is async, so this returns while the
    device still computes. The in-flight ``PendingBatch`` crosses to the
    finalizer through a *bounded* backlog queue (the double buffer: batch
    k+1 launches while batch k executes/finalizes).
  * **finalize stage** (dedicated thread): the one counted
    ``ops.device_get`` per bucket + the spec's host finalizers + ticket
    resolution. Per-batch launch/host-sync budgets are identical to the
    synchronous path — the stages are the same work, relocated.

**AOT warmup**: at construction (and after every ``compact``) the server
pre-compiles the executables the hot path will need — every pow2 query
bucket up to ``max_batch``, for every warm path, under the server's spec,
through ``ops.aot_capture()`` — so steady-state serving *provably* never
retraces (``ops.trace_log()`` stays empty; data-shape-dependent visit
buckets on tree/VA paths are the documented residual and fall back to jit).

**Admission control**: ``submit`` sheds with a typed ``Overloaded`` ticket
once ``(backlog depth + 1) x EWMA batch seconds`` exceeds
``latency_budget_s`` — the server degrades by refusing work it cannot serve
in time instead of growing an unbounded queue. Sheds are visible in
``ServerStats.shed_counts`` and ``mdrq_server_shed_total``.

Threading contract (enforced by mdrqlint's ``thread-boundary`` rule):
device values cross threads only *inside* a ``PendingBatch`` riding the
backlog queue; ``ops.device_get`` runs only on the finalizer thread; stage
membership is declared with the ``@device_stage`` / ``@finalizer_stage``
decorators. The two threads share no locks — each ``ServerStats`` field has
exactly one writer thread (admission: ``shed_counts``/``flush_reasons``;
finalizer: everything else), and the queue provides the ordering.

The synchronous ``MDRQServer`` remains the default and the deterministic
test surface; ``serve_pipelined(engine)`` is the opt-in factory.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Optional

import numpy as np

from repro import numerics, obs
from repro.obs import tracing as obs_tracing
from repro.core import MDRQEngine, RangeQuery
from repro.core import types as T
from repro.core.engine import PendingBatch
from repro.kernels import ops
from repro.serve.mdrq_server import MDRQServer, Ticket


def device_stage(fn):
    """Mark a function as device-stage: runs on the admission thread, may
    launch device work, must NOT sync it (no ``ops.device_get``) and must
    not park device values on ``self`` — in-flight payloads cross to the
    finalizer only through the backlog queue (mdrqlint: thread-boundary)."""
    fn.__mdrq_stage__ = "device"
    return fn


def finalizer_stage(fn):
    """Mark a function as finalize-stage: runs on the finalizer thread and
    owns the counted ``ops.device_get`` syncs (mdrqlint: thread-boundary)."""
    fn.__mdrq_stage__ = "finalize"
    return fn


class Overloaded(RuntimeError):
    """The server shed this query at admission: the backlog's estimated
    drain time exceeded the latency budget. Retry later or elsewhere."""


@dataclasses.dataclass
class PipelineTicket(Ticket):
    """Event-backed ticket for pipelined serving.

    ``result()`` raises ``Overloaded`` for shed queries, re-raises the
    window's failure if its finalize raised, and otherwise blocks until the
    finalizer thread resolves the window this ticket flushed with.
    """

    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    _inflight: bool = False
    _shed: bool = False
    _error: Optional[BaseException] = None

    @property
    def shed(self) -> bool:
        return self._shed

    def result(self, timeout: Optional[float] = None):
        if self._shed:
            raise Overloaded(
                "query shed at admission: backlog exceeds the latency "
                "budget (see ServerStats.shed_counts)")
        if not self._done and not self._inflight:
            self._server.flush()
        if not self._event.wait(timeout):
            raise TimeoutError(f"pipelined result not ready in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


@dataclasses.dataclass
class _Window:
    """One flushed window in flight between the stages."""

    pending: list    # [(RangeQuery, PipelineTicket, t_submit)], flush order
    reason: str
    batch: PendingBatch
    t_flush: float         # device-stage start (queue latency anchor)
    launch_seconds: float  # device-stage wall (plan + dispatch)


@dataclasses.dataclass(frozen=True)
class WarmupReport:
    """What one AOT warmup pass advertised and compiled.

    ``keys`` is exactly the set of ``ops`` AOT-cache keys this pass added —
    the advertised executable set tests assert against; ``n_compiled`` can
    be smaller than ``n_runs`` when shapes coincide across paths."""

    paths: tuple[str, ...]
    bucket_sizes: tuple[int, ...]
    dim_counts: tuple[int, ...]
    spec_kind: str
    n_runs: int
    n_compiled: int
    seconds: float
    keys: tuple


def _warm_batch(n_q: int, n_dims: int, m: int) -> T.QueryBatch:
    """A (n_q, m) warmup batch constraining the first ``n_dims`` dims.

    Constrained dims carry the widest *finite* f32 bounds (finite so they
    count as constrained; widest so tree/VA warmups traverse their largest
    visit bucket); the rest are +-inf match-alls. Shapes — the only thing an
    AOT executable is specialized on — match real traffic exactly.
    """
    lo = np.full((n_q, m), -np.inf, np.float32)
    up = np.full((n_q, m), np.inf, np.float32)
    lo[:, :n_dims] = numerics.finite_min(np.float32)
    up[:, :n_dims] = numerics.finite_max(np.float32)
    return T.QueryBatch(lo, up)


class PipelinedMDRQServer(MDRQServer):
    """Double-buffered MDRQ server: overlapped device/finalize stages, AOT
    warmup, bounded backlog, and admission-control shedding.

    Drop-in for ``MDRQServer`` (same submit/poll/flush/ingest surface) with
    extras: ``warmup()``, ``drain()``, ``close()`` (or use it as a context
    manager), ``latency_budget_s``. Ticket ``result()`` calls block on the
    finalizer thread instead of running the batch inline.
    """

    ticket_cls = PipelineTicket

    def __init__(
        self,
        engine: MDRQEngine,
        max_batch: int = 128,
        max_wait_s: float = 2e-3,
        method: str = "auto",
        spec=None,
        mode: Optional[str] = None,
        query_log_capacity: int = 512,
        *,
        backlog: int = 4,
        latency_budget_s: float = 0.25,
        warmup: bool = True,
    ):
        super().__init__(engine, max_batch=max_batch, max_wait_s=max_wait_s,
                         method=method, spec=spec, mode=mode,
                         query_log_capacity=query_log_capacity)
        if backlog < 1:
            raise ValueError(f"backlog must be >= 1, got {backlog}")
        self.latency_budget_s = latency_budget_s
        # The double buffer: in-flight windows between the stages. ``put``
        # blocks when full — backpressure on the admission thread, so device
        # work can never run unboundedly ahead of host finalization.
        self._backlog: "queue.Queue[Optional[_Window]]" = \
            queue.Queue(maxsize=backlog)
        self._ewma_batch_s = 0.0   # finalizer-thread-only writer
        self._wall_t0: Optional[float] = None
        self._closed = False
        self._warmup_enabled = bool(warmup)
        self.last_warmup: Optional[WarmupReport] = None
        self._finalizer = threading.Thread(
            target=self._finalize_loop, name="mdrq-finalizer", daemon=True)
        self._finalizer.start()
        if warmup:
            self.warmup()

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self) -> "PipelinedMDRQServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def drain(self) -> None:
        """Flush the pending window and block until every in-flight window
        has finalized (the backlog is empty and all tickets resolved)."""
        self.flush()
        self._backlog.join()

    def close(self) -> None:
        """Drain, then stop the finalizer thread. Idempotent."""
        if self._closed:
            return
        self.drain()
        self._closed = True
        self._backlog.put(None)   # stop sentinel
        self._finalizer.join()

    def reset_stats(self) -> None:
        """Fresh stats AND a fresh wall-clock anchor: ``wall_seconds`` must
        measure the next pass only, not everything since construction. Call
        only between passes (after ``drain()``), never with windows in
        flight — the finalizer thread writes stats concurrently otherwise."""
        super().reset_stats()
        self._wall_t0 = None

    # -- AOT warmup ----------------------------------------------------------
    def warmup(self) -> WarmupReport:
        """Pre-compile the hot path's executables -> ``WarmupReport``.

        Sweeps every pow2 bucket size up to ``max_batch`` for every warm
        path (all plannable paths under ``method="auto"``, else the explicit
        path), under the server's spec and the engine's *current* delta
        snapshot, inside ``ops.aot_capture()`` — each jitted op a run hits
        is lowered + compiled once and cached by (op, shapes, statics). The
        vertical scan additionally sweeps pow2 constrained-dim counts (its
        shapes vary with ``next_pow2(max mq)``). Steady-state traffic whose
        shapes were advertised here dispatches straight to compiled
        executables: zero retraces, counter-asserted via ``ops.trace_log``.
        Re-run automatically after ``compact`` (new data shapes).
        """
        t0 = time.perf_counter()
        engine = self.engine
        paths = engine.paths
        m = engine.dataset.m
        dview = engine.delta.snapshot()
        delta_arg = None if dview.is_empty else dview
        if self.method == "auto":
            names = tuple(n for n, p in paths.items()
                          if getattr(p, "plannable", True))
        else:
            names = (self.method,)
        sizes, b = [], 1
        top = T.next_pow2(self.max_batch)
        while b <= top:
            sizes.append(b)
            b *= 2
        dim_counts = tuple(sorted({min(T.next_pow2(k), m)
                                   for k in range(1, m + 1)}))
        before = set(ops.aot_cache_keys())
        n_runs = 0
        with obs_tracing.span("warmup", paths=len(names)):
            with ops.aot_capture():
                for name in names:
                    path = paths[name]
                    dcs = dim_counts if name == "scan_vertical" else (m,)
                    for d in dcs:
                        for bsz in sizes:
                            engine._path_query_batch(
                                path, _warm_batch(bsz, d, m), self.spec,
                                delta=delta_arg)
                            n_runs += 1
        keys = tuple(k for k in ops.aot_cache_keys() if k not in before)
        self.last_warmup = WarmupReport(
            paths=names, bucket_sizes=tuple(sizes), dim_counts=dim_counts,
            spec_kind=self.spec.kind, n_runs=n_runs, n_compiled=len(keys),
            seconds=time.perf_counter() - t0, keys=keys)
        return self.last_warmup

    def compact(self):
        """Compact the engine, then re-warm: the swapped-in version's device
        arrays have new shapes, so the old executables no longer apply."""
        out = super().compact()
        if self._warmup_enabled:
            self.warmup()
        return out

    # -- admission control ---------------------------------------------------
    def _should_shed(self) -> bool:
        # (windows not yet finalized + the one this query would join) x the
        # EWMA batch cost ~= time until this query's result; shed when that
        # exceeds the budget. EWMA 0.0 until the first window completes —
        # cold start never sheds.
        if self._ewma_batch_s <= 0.0:
            return False
        est = (self._backlog.unfinished_tasks + 1) * self._ewma_batch_s
        return est > self.latency_budget_s

    @device_stage
    def submit(self, q: RangeQuery) -> Ticket:
        """Admission: shed with an ``Overloaded`` ticket when the backlog's
        estimated drain time exceeds the budget, else enqueue as usual."""
        if self._closed:
            raise RuntimeError("server is closed")
        if self._wall_t0 is None:
            self._wall_t0 = time.perf_counter()
        if self._should_shed():
            ticket = self.ticket_cls(self, spec=self.spec)
            ticket._shed = True
            self.stats.shed_counts["overloaded"] = \
                self.stats.shed_counts.get("overloaded", 0) + 1
            obs.registry().counter(
                "mdrq_server_shed_total",
                help="queries shed at admission, by reason",
                reason="overloaded").inc()
            return ticket
        return super().submit(q)

    # -- the device stage ----------------------------------------------------
    @device_stage
    def flush(self, reason: str = "forced") -> int:
        """Device stage of a flush: plan + launch the window, hand the
        in-flight ``PendingBatch`` to the finalizer via the backlog.

        On a launch failure the window is re-queued in order with its
        deadline clock re-anchored — tickets stay resolvable by a later
        flush, exactly like the synchronous server's exception path.
        """
        if not self._pending:
            return 0
        pending, self._pending = self._pending, []
        queries = [q for q, _, _ in pending]
        t0 = time.perf_counter()
        try:
            with obs_tracing.span("flush", reason=reason,
                                  n_queries=len(pending), stage="device"):
                pb = self.engine.launch_batch(queries, method=self.method,
                                              spec=self.spec)
        except Exception:
            self._pending = pending + self._pending
            self._oldest_t = pending[0][2]
            raise
        launch_s = time.perf_counter() - t0
        for _, ticket, _ in pending:
            ticket._inflight = True
        win = _Window(pending=pending, reason=reason, batch=pb,
                      t_flush=t0, launch_seconds=launch_s)
        self._backlog.put(win)   # blocks when full: backpressure
        self.stats.flush_reasons[reason] = \
            self.stats.flush_reasons.get(reason, 0) + 1
        obs.registry().counter(
            "mdrq_server_flushes_total",
            help="server batch flushes, by trigger", reason=reason).inc()
        return len(pending)

    # -- the finalize stage --------------------------------------------------
    @finalizer_stage
    def _finalize_loop(self) -> None:
        """Finalizer thread: drain windows, sync + finalize + resolve.

        A window whose finalize raises poisons only its own tickets (the
        exception re-raises from each ``result()``); later windows keep
        serving — per-window fault isolation.
        """
        while True:
            win = self._backlog.get()
            if win is None:   # stop sentinel from close()
                self._backlog.task_done()
                return
            t0 = time.perf_counter()
            try:
                with obs_tracing.span("pipeline_finalize",
                                      n_queries=len(win.pending),
                                      stage="finalize"):
                    results = win.batch.finalize()
                for (_, ticket, _), res in zip(win.pending, results):
                    ticket._result = res
                    ticket._done = True
                self._record_window(win, results,
                                    time.perf_counter() - t0)
            except Exception as e:
                for _, ticket, _ in win.pending:
                    ticket._error = e
            finally:
                for _, ticket, _ in win.pending:
                    ticket._event.set()
                self._backlog.task_done()

    @finalizer_stage
    def _record_window(self, win: _Window, results: list,
                       fin_s: float) -> None:
        """Stats + query log for one finalized window (finalizer thread is
        the sole writer of every field it touches here)."""
        stats = self.stats
        bs = win.batch.stats
        kind = self.spec.kind
        methods = win.batch.methods or [self.method] * len(win.pending)
        for (q, _, t_submit), res, meth in zip(win.pending, results, methods):
            queue_s = win.t_flush - t_submit
            # execute latency is the *device-stage* wall — under overlap the
            # whole-flush wall of the sync server would double-count the
            # finalize time of the previous window
            stats.observe_latency(kind, queue_s, win.launch_seconds)
            self.query_log.offer(obs.QueryLogEntry(
                lower=q.lower, upper=q.upper, spec_kind=kind, method=meth,
                result_size=self.spec.result_size(res),
                queue_seconds=queue_s, execute_seconds=win.launch_seconds,
                flush_reason=win.reason, batch_size=len(win.pending)))
        stats.n_queries += len(win.pending)
        stats.spec_counts[kind] = \
            stats.spec_counts.get(kind, 0) + len(win.pending)
        stats.n_batches += 1
        stats.busy_seconds += win.launch_seconds + fin_s
        stats.plan_seconds += bs.plan_seconds
        stats.finalize_seconds += fin_s
        stats.n_results += bs.n_results
        for meth, c in win.batch.method_counts.items():
            stats.method_counts[meth] = stats.method_counts.get(meth, 0) + c
        # wall anchor: first submit -> this finalize; qps divides by this
        if self._wall_t0 is not None:
            stats.wall_seconds = time.perf_counter() - self._wall_t0
        # EWMA of one window's full pipeline cost, for admission control
        total = win.launch_seconds + fin_s
        self._ewma_batch_s = (total if self._ewma_batch_s <= 0.0
                              else 0.8 * self._ewma_batch_s + 0.2 * total)


def serve_pipelined(engine: MDRQEngine, **kwargs) -> PipelinedMDRQServer:
    """Factory: an AOT-warmed, double-buffered server over ``engine``.

    ``with serve_pipelined(engine) as srv: ...`` warms up at construction
    and drains + stops the finalizer thread on exit. Keyword arguments are
    ``PipelinedMDRQServer``'s (``max_batch``, ``backlog``,
    ``latency_budget_s``, ``spec``, ``warmup=False`` to skip warmup, ...).
    """
    return PipelinedMDRQServer(engine, **kwargs)
