"""Serving steps: jit'd prefill and single-token decode.

``serve_step`` is the function the decode dry-run cells lower: one new token
against a KV/SSM/ring cache of ``seq_len`` — cache donated, so steady-state
decode allocates nothing.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def make_serve_step(model) -> Callable:
    """(params, cache, tokens(B,1), pos(B,)) -> (logits, cache')."""

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step


def make_prefill(model) -> Callable:
    """(params, batch) -> (last-token logits, aux)."""

    def prefill(params, batch):
        return model.prefill(params, batch)

    return prefill


def greedy_sample(logits: jax.Array, vocab_size: int) -> jax.Array:
    """(B, 1, V_pad) -> (B, 1) argmax over the un-padded vocabulary."""
    v = logits[..., :vocab_size]
    return jnp.argmax(v, axis=-1).astype(jnp.int32)
