"""Throughput-oriented MDRQ serving front end.

The paper evaluates analytical *streams* of range queries (GMRQB, §6) but its
engine — like the seed engine here — answers one query per launch, paying the
full dispatch + host-sync tax each time. ``MDRQServer`` is the batching layer
on top of ``MDRQEngine.query_batch``: incoming queries accumulate into a
pending window and flush as one fused batch when either trigger fires —

  * the window reaches ``max_batch`` queries, or
  * the oldest pending query has waited ``max_wait_s`` (latency bound).

The design is deliberately synchronous (no threads): ``submit`` returns a
``Ticket`` immediately, deadlines are checked on every submit — and on
``poll()``, the idle-stream flush path an admission loop calls between
arrivals — and ``Ticket.result()`` forces a flush of whatever is pending, so
behaviour is deterministic under test while mirroring the admission loop a
real deployment would run. Throughput (queries/sec — the primary metric of the multi-query
literature, e.g. "Learning Multi-dimensional Indexes") accumulates in
``ServerStats``.

The server is typed by a ``types.ResultSpec``: tickets resolve to whatever
the spec's host finalizer produces — sorted id arrays (``Ids()``, default),
int counts (``Count()``), bool masks, top-k id arrays, or float aggregates —
with the reduction running on device so reduced shapes never pay the
per-query host-side ``nonzero`` that dominates large result sets.
``ServerStats`` buckets served queries by spec kind. The legacy
``mode="ids"|"count"`` strings keep working with a DeprecationWarning.

Observability (DESIGN.md §10): every flush records *why* it fired ("size" |
"deadline" | "forced") — in ``ServerStats.flush_reasons``, in the global
metrics registry (``mdrq_server_flushes_total{reason=...}``), and on every
retained entry of the bounded reservoir-sampled ``query_log`` — so
deadline-triggered idle-stream flushes are distinguishable from
size-triggered ones after the fact. Per-query queue latency
(submit -> flush start) and execute latency land in per-spec-kind
histograms; ``ServerStats.latency_percentiles(kind)`` reports p50/p95/p99.

Serve-while-ingest: ``append`` / ``delete`` / ``compact`` ride the same
admission loop. Each write drains the pending window first (a flush tagged
``reason="ingest"``), then lands in the engine's delta segment — so request
order determines visibility deterministically, queries keep flushing as one
fused launch per batch, and a ``compact`` swaps the engine's version without
the server holding any lock. Ingest traffic is visible in
``ServerStats.ingest_counts``, ``mdrq_ingest_total{op=...}``, and as
``spec_kind="ingest"`` query-log entries.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Union

import numpy as np

from repro import obs
from repro.obs import tracing as obs_tracing
from repro.core import MDRQEngine, RangeQuery
from repro.core.types import ResultSpec, resolve_spec


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted query; ``result()`` blocks (flushes) if needed.

    ``spec`` records the result shape this ticket resolves to: ``result()``
    returns sorted ids under ``Ids()``, an int under ``Count()``, an (n,)
    bool mask under ``Mask()``, value-ordered top-k ids under ``TopK``, and
    a float under ``Agg`` (NaN for an empty match set on min/max).
    """

    _server: "MDRQServer"
    spec: Optional[ResultSpec] = None
    _result: Any = None
    _done: bool = False

    def result(self) -> Union[np.ndarray, int, float]:
        if not self._done:
            self._server.flush()
        assert self._done, "flush did not resolve this ticket"
        return self._result


@dataclasses.dataclass
class ServerStats:
    """Cumulative serving statistics (the throughput report)."""

    n_queries: int = 0
    n_batches: int = 0
    busy_seconds: float = 0.0
    # planning share of busy_seconds (the engine's BatchStats.plan_seconds
    # summed over flushes) — how much of the window went to the batch planner
    plan_seconds: float = 0.0
    # host-finalize share (pipelined mode: the finalizer thread's stage wall)
    finalize_seconds: float = 0.0
    # wall clock from first submit to last finalize (pipelined mode only;
    # 0.0 on the synchronous server). Under overlap, summing per-stage times
    # double-counts concurrent work — qps must anchor to real elapsed time.
    wall_seconds: float = 0.0
    n_results: int = 0
    # queries shed by admission control, by reason ("overloaded")
    shed_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    # access-path buckets summed over every flushed batch
    method_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    # served queries bucketed by result-spec kind ("ids", "count", "topk", ...)
    spec_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    # flushes bucketed by trigger ("size" | "deadline" | "forced" | "ingest")
    flush_reasons: dict[str, int] = dataclasses.field(default_factory=dict)
    # ingest operations served through the window ("append"/"delete"/"compact")
    ingest_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    # per-spec-kind latency histograms: queue (submit -> flush start) and
    # execute (the query's batch execution wall time), observed per query
    queue_latency: dict[str, obs.Histogram] = dataclasses.field(
        default_factory=dict)
    execute_latency: dict[str, obs.Histogram] = dataclasses.field(
        default_factory=dict)

    @property
    def qps(self) -> float:
        """Sustained throughput. Synchronous serving divides by busy time
        (the window only runs while a flush does); pipelined serving divides
        by wall clock — device and finalize stages overlap, so their sum
        exceeds elapsed time and would overstate throughput."""
        denom = self.wall_seconds if self.wall_seconds > 0 else self.busy_seconds
        return self.n_queries / denom if denom > 0 else 0.0

    @property
    def mean_batch_size(self) -> float:
        return self.n_queries / self.n_batches if self.n_batches else 0.0

    @staticmethod
    def _latency_hist(table: dict, stage: str, kind: str) -> obs.Histogram:
        h = table.get(kind)
        if h is None:
            h = table[kind] = obs.Histogram(f"mdrq_{stage}_seconds",
                                            {"kind": kind})
        return h

    def observe_latency(self, kind: str, queue_s: float,
                        execute_s: float) -> None:
        """Record one query's queue + execute latency under its spec kind."""
        self._latency_hist(self.queue_latency, "queue", kind).observe(queue_s)
        self._latency_hist(self.execute_latency, "execute",
                           kind).observe(execute_s)

    def latency_percentiles(self, kind: str) -> dict[str, dict[str, float]]:
        """p50/p95/p99 queue + execute latency (seconds) for one spec kind;
        empty dicts before any query of that kind was served."""
        out: dict[str, dict[str, float]] = {}
        for name, table in (("queue", self.queue_latency),
                            ("execute", self.execute_latency)):
            h = table.get(kind)
            out[name] = h.percentiles((50, 95, 99)) if h is not None else {}
        return out


class MDRQServer:
    """Accumulates queries into batches and drives ``MDRQEngine.query_batch``."""

    # Ticket type ``submit`` hands out — the pipelined subclass swaps in its
    # event-backed ticket without re-implementing admission.
    ticket_cls = Ticket

    def __init__(
        self,
        engine: MDRQEngine,
        max_batch: int = 128,
        max_wait_s: float = 2e-3,
        method: str = "auto",
        spec: Optional[ResultSpec] = None,
        mode: Optional[str] = None,
        query_log_capacity: int = 512,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.method = method
        self.spec = resolve_spec(spec, mode).validate(engine.dataset.m)
        self.stats = ServerStats()
        # bounded uniform sample of everything ever served (obs.QueryLog) —
        # the drift audit's and any layout learner's workload input
        self.query_log = obs.QueryLog(capacity=query_log_capacity)
        self._pending: list[tuple[RangeQuery, Ticket, float]] = []
        self._oldest_t: float = 0.0

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def reset_stats(self) -> None:
        """Fresh ``ServerStats`` (benchmark passes: drop warmup traffic)."""
        self.stats = ServerStats()

    def submit(self, q: RangeQuery) -> Ticket:
        """Enqueue one query; flushes when a batching trigger fires."""
        if q.m != self.engine.dataset.m:
            # reject poison queries before they enter the window — inside a
            # batch they would fail every co-batched query's flush
            raise ValueError(
                f"query dims {q.m} != dataset dims {self.engine.dataset.m}")
        ticket = self.ticket_cls(self, spec=self.spec)
        now = time.perf_counter()
        if not self._pending:
            self._oldest_t = now
        self._pending.append((q, ticket, now))
        if len(self._pending) >= self.max_batch:
            self.flush(reason="size")
        elif now - self._oldest_t >= self.max_wait_s:
            self.flush(reason="deadline")
        return ticket

    def poll(self) -> int:
        """Deadline check for an *idle* stream: flush iff the oldest pending
        query has waited past ``max_wait_s``.

        The latency bound otherwise only fires on the next ``submit`` — with
        no further arrivals, pending queries would sit past their deadline
        with no flush path short of ``Ticket.result()``. An admission loop
        calls this on its idle ticks. Returns the flushed batch size (0 when
        nothing is due). Flushes from here are ``reason="deadline"`` — they
        carry that tag into the query log and the flush trace event, so idle-
        stream deadline flushes are distinguishable from size-triggered ones.
        """
        if (self._pending
                and time.perf_counter() - self._oldest_t >= self.max_wait_s):
            return self.flush(reason="deadline")
        return 0

    def flush(self, reason: str = "forced") -> int:
        """Execute everything pending as one batch; returns its size.

        ``reason`` names the trigger ("size" | "deadline" | "forced" |
        "ingest" — a write draining the window first) and is
        recorded in ``stats.flush_reasons``, in the registry counter
        ``mdrq_server_flushes_total{reason=...}``, on every retained query-log
        entry, and as a ``flush`` trace event when a tracer is active.
        """
        if not self._pending:
            return 0
        pending, self._pending = self._pending, []
        queries = [q for q, _, _ in pending]
        t0 = time.perf_counter()
        try:
            with obs_tracing.span("flush", reason=reason,
                                  n_queries=len(pending)):
                results = self.engine.query_batch(queries, method=self.method,
                                                  spec=self.spec)
        except Exception:
            # don't lose co-batched queries: put them back (in order) so
            # their tickets remain resolvable after the caller handles the
            # error — and re-anchor the deadline clock to the oldest
            # re-queued query, or the next submit's deadline check would
            # measure from whatever ``_oldest_t`` happened to hold
            self._pending = pending + self._pending
            self._oldest_t = pending[0][2]
            raise
        dt = time.perf_counter() - t0
        for (_, ticket, _), res in zip(pending, results):
            ticket._result = res
            ticket._done = True
        kind = self.spec.kind
        batch_stats = self.engine.last_batch_stats
        methods = batch_stats.methods or [self.method] * len(pending)
        for (q, _, t_submit), res, meth in zip(pending, results, methods):
            queue_s = t0 - t_submit
            self.stats.observe_latency(kind, queue_s, dt)
            self.query_log.offer(obs.QueryLogEntry(
                lower=q.lower, upper=q.upper, spec_kind=kind, method=meth,
                result_size=self.spec.result_size(res),
                queue_seconds=queue_s, execute_seconds=dt,
                flush_reason=reason, batch_size=len(pending)))
        self.stats.n_queries += len(pending)
        self.stats.spec_counts[kind] = \
            self.stats.spec_counts.get(kind, 0) + len(pending)
        self.stats.n_batches += 1
        self.stats.busy_seconds += dt
        self.stats.plan_seconds += batch_stats.plan_seconds
        self.stats.n_results += batch_stats.n_results
        for m, c in batch_stats.method_counts.items():
            self.stats.method_counts[m] = self.stats.method_counts.get(m, 0) + c
        self.stats.flush_reasons[reason] = \
            self.stats.flush_reasons.get(reason, 0) + 1
        obs.registry().counter(
            "mdrq_server_flushes_total",
            help="server batch flushes, by trigger", reason=reason).inc()
        return len(pending)

    # -- the ingest plane ---------------------------------------------------
    # Writes ride the same admission loop as queries. Each ingest call first
    # flushes the pending window (reason="ingest"), so results respect
    # submission order: a query submitted before an append/delete never sees
    # it, one submitted after always does — deterministic interleaving
    # without any cross-request locking in the server itself.
    def append(self, rows) -> np.ndarray:
        """Append rows ((k, m) array-like) -> their assigned int64 ids."""
        return self._ingest("append", lambda: self.engine.append(rows))

    def delete(self, ids) -> int:
        """Tombstone ids -> count of newly deleted rows."""
        return self._ingest("delete", lambda: self.engine.delete(ids))

    def compact(self) -> np.ndarray:
        """Compact the engine's delta -> the old-id -> new-id map."""
        return self._ingest("compact", lambda: self.engine.compact())

    def _ingest(self, op: str, fn):
        self.flush(reason="ingest")
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        size = int(out.size) if isinstance(out, np.ndarray) else int(out)
        # ingest rows share the query log (bound-less entries, spec_kind
        # "ingest") so the audit layer sees writes interleaved with reads
        nan_bounds = np.full((self.engine.dataset.m,), np.nan, np.float32)
        self.query_log.offer(obs.QueryLogEntry(
            lower=nan_bounds, upper=nan_bounds, spec_kind="ingest",
            method=op, result_size=size, queue_seconds=0.0,
            execute_seconds=dt, flush_reason="ingest", batch_size=1))
        self.stats.ingest_counts[op] = self.stats.ingest_counts.get(op, 0) + 1
        obs.registry().counter("mdrq_ingest_total",
                               help="server ingest operations, by op",
                               op=op).inc()
        return out

    def serve_all(self, queries: list[RangeQuery]
                  ) -> list[Union[np.ndarray, int]]:
        """Drive a whole workload through the batching window; results come
        back positionally aligned with the input (benchmark convenience)."""
        tickets = []
        for q in queries:
            tickets.append(self.submit(q))
            # the admission-loop shape: poll between arrivals. submit's own
            # deadline check makes this a near-no-op here, but a real loop
            # with gaps between arrivals relies on exactly this call site.
            self.poll()
        self.flush()
        return [t.result() for t in tickets]
