"""Parameter trees with logical sharding axes.

Every parameter is created as a ``Param(value, axes)`` where ``axes`` names
the *logical* axis of each array dimension ("embed", "heads", "ff", "vocab",
"experts", "layers", ...). ``split_tree`` separates values from axes;
``pspec_tree`` maps logical names to mesh axes through a rules table — the
one place the DP/TP/EP layout is decided (and the main §Perf hillclimbing
lever).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class Param:
    """A parameter value tagged with logical axis names.

    Registered as a pytree node (axes are static aux data) so Param trees pass
    transparently through jit / grad / eval_shape / scan.
    """
    value: Any          # jax.Array | ShapeDtypeStruct
    axes: tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Param(children[0], axes),
)


# Logical-axis -> mesh-axis rules. None = replicate. The default TP layout:
# heads/ff/vocab/experts shard over "model"; everything else replicated
# (DP gradients sync via psum, ZeRO-1 shards optimizer state over "data").
DEFAULT_RULES: dict[str, Optional[str]] = {
    "layers": None,
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_ff": None,
    "state": None,
    "rnn": "model",
    "conv": None,
}


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_tree(tree):
    """Param tree -> (values tree, axes tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def pspec_tree(axes_tree, rules: dict[str, Optional[str]] | None = None):
    """Axes tree -> PartitionSpec tree via the rules table."""
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def to_pspec(axes):
        return P(*(rules.get(a) if a is not None else None for a in axes))

    return jax.tree.map(to_pspec, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def dense_init(key, shape, axes, dtype, scale: float | None = None) -> Param:
    """Truncated-normal init with 1/sqrt(fan_in) default scale."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    v = (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * s)
    return Param(v.astype(dtype), axes)


def zeros_init(shape, axes, dtype) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


def sharding_tree(params_tree, mesh, rules: dict[str, Optional[str]] | None = None):
    """Param tree -> matching tree of NamedSharding (jit in_shardings)."""
    from jax.sharding import NamedSharding
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def f(p: Param):
        return NamedSharding(mesh, P(*(rules.get(a) for a in p.axes)))

    return jax.tree.map(f, params_tree, is_leaf=is_param)


def abstract_like(tree):
    """Param tree -> same tree with ShapeDtypeStruct values (no allocation)."""
    return jax.tree.map(
        lambda p: Param(jax.ShapeDtypeStruct(p.value.shape, p.value.dtype), p.axes),
        tree, is_leaf=is_param)


def count_params(values_tree) -> int:
    return sum(int(np.prod(v.shape)) for v in jax.tree.leaves(values_tree))


def stack_layer_params(per_layer: list):
    """Stack a list of identical param trees along a new 'layers' axis."""
    def stack(*ps):
        return Param(jnp.stack([p.value for p in ps]), ("layers",) + ps[0].axes)
    return jax.tree.map(stack, *per_layer, is_leaf=is_param)
