"""Model registry: ModelConfig -> runnable model + sharding policy + specs.

The per-arch TP policy (DESIGN.md §5): with a fixed 16-wide `model` mesh axis,
attention sharding adapts to head divisibility —

  policy A: heads and kv_heads both divide 16     -> shard both (full TP attn)
  policy B: only heads divide 16                  -> shard q heads, replicate kv
  policy C: heads don't divide 16                 -> replicate attention,
            TP carries the FFN / experts / vocab (the parameter bulk)

FFN (d_ff), experts, vocab (padded to 256) and SSM/RNN inner dims divide 16
for every assigned architecture, so those always shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models.encdec import EncDecLM
from repro.models.params import DEFAULT_RULES
from repro.models.transformer import DecoderLM, vocab_padded

TP = 16  # model-axis width of the production mesh


def build_model(cfg):
    if cfg.family == "audio" and cfg.encoder_layers:
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def attn_policy(cfg, tp: int = TP) -> str:
    if cfg.family == "ssm":
        return "A"  # ssm heads checked below
    if cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0:
        return "A"
    if cfg.n_heads % tp == 0:
        return "B"
    return "C"


def sharding_rules(cfg, tp: int = TP) -> dict[str, Optional[str]]:
    rules = dict(DEFAULT_RULES)
    pol = attn_policy(cfg, tp)
    if cfg.family == "ssm":
        h_ssm = cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim
        rules["heads"] = "model" if h_ssm % tp == 0 else None
        rules["kv_heads"] = None
    elif pol == "B":
        rules["kv_heads"] = None
    elif pol == "C":
        rules["heads"] = None
        rules["kv_heads"] = None
    return rules


# ---------------------------------------------------------------------------
# shape applicability (spec-mandated skips) and input specs
# ---------------------------------------------------------------------------
def shape_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    seq, batch, kind = SHAPES[shape_name]
    if shape_name == "long_500k":
        bounded = (cfg.family in ("ssm", "hybrid")
                   or cfg.sliding_window is not None)
        if not bounded:
            return False, ("pure full attention: 500k decode needs an O(500k)-"
                           "resident KV cache built by a quadratic prefill "
                           "(DESIGN.md §4)")
    return True, ""


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """Everything dryrun/train/serve need for one (arch x shape) cell."""
    arch: str
    shape: str
    kind: str                 # train | prefill | decode
    inputs: dict              # name -> ShapeDtypeStruct
    input_pspecs: dict        # name -> PartitionSpec
    cache_specs: Any = None   # decode only: pytree of ShapeDtypeStruct
    cache_pspecs: Any = None


def _token_specs(cfg, seq: int, batch: int, kind: str, ba) -> tuple[dict, dict]:
    """Token/label/frontend-stub specs for train/prefill."""
    dt_emb = jnp.dtype(cfg.param_dtype)
    inputs: dict = {}
    pspecs: dict = {}
    if cfg.family == "audio" and cfg.encoder_layers:
        enc_len = max(8, seq // 4)
        inputs["enc_embeds"] = jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model), dt_emb)
        pspecs["enc_embeds"] = P(ba, None, None)
        inputs["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        pspecs["tokens"] = P(ba, None)
        if kind == "train":
            inputs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
            pspecs["labels"] = P(ba, None)
        return inputs, pspecs
    text_len = seq - cfg.n_prefix_embeds
    assert text_len > 0, (seq, cfg.n_prefix_embeds)
    inputs["tokens"] = jax.ShapeDtypeStruct((batch, text_len), jnp.int32)
    pspecs["tokens"] = P(ba, None)
    if cfg.n_prefix_embeds:
        inputs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_prefix_embeds, cfg.d_model), dt_emb)
        pspecs["prefix_embeds"] = P(ba, None, None)
    if kind == "train":
        # labels cover the full (prefix + text) output positions minus prefix
        inputs["labels"] = jax.ShapeDtypeStruct((batch, text_len), jnp.int32)
        pspecs["labels"] = P(ba, None)
    return inputs, pspecs


def cache_pspecs_for(cfg, cache_specs, batch: int, multi_pod: bool, rules):
    """PartitionSpec tree matching an init_cache pytree."""
    ba = batch_axes(multi_pod)
    b_spec = ba if batch > 1 else None
    heads_rule = rules.get("heads")
    kv_rule = rules.get("kv_heads")

    def spec_for(path_leaf, arr):
        # leaf names: k/v (L,B,slots,KV,hd); k_scale/v_scale (L,B,slots,KV,1);
        # kmin/kmax (L,B,nb,KV,hd); ssm (L,B,H,hd,state); conv_* (L,B,W,C);
        # h (L,B,dr); pos (B,)
        nd = arr.ndim
        if nd == 1:
            return P(b_spec)
        if nd == 5 and path_leaf in ("k", "v", "xk", "xv", "k_scale", "v_scale",
                                     "kmin", "kmax"):
            if kv_rule == "model":
                return P(None, b_spec, None, "model", None)
            # kv replicated over model: shard cache slots/blocks over model
            slots = arr.shape[2]
            slot_axes = "model" if slots % TP == 0 else None
            return P(None, b_spec, slot_axes, None, None)
        if nd == 5:  # ssm state (L,B,H,hd,state)
            return P(None, b_spec, heads_rule, None, None)
        if nd == 4:  # conv state (L,B,W,C) or group-stacked (G,B,w,dr)
            return P(None, b_spec, None, "model")
        if nd == 3:  # h (L,B,dr)
            return P(None, b_spec, "model")
        if nd == 2:
            return P(None, b_spec)
        return P(*([None] * nd))

    def walk(tree):
        if isinstance(tree, dict):
            return {k: (spec_for(k, v) if hasattr(v, "ndim") else walk(v))
                    for k, v in tree.items()}
        return tree

    return walk(cache_specs)


def make_cell(arch: str, shape_name: str, multi_pod: bool = False,
              cfg=None) -> CellSpec:
    """Build the (inputs, pspecs, cache) bundle for one dry-run cell."""
    cfg = cfg if cfg is not None else get_config(arch)
    seq, batch, kind = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")
    ba = batch_axes(multi_pod)
    rules = sharding_rules(cfg)
    model = build_model(cfg)

    if kind in ("train", "prefill"):
        inputs, pspecs = _token_specs(cfg, seq, batch, kind, ba)
        return CellSpec(arch, shape_name, kind, inputs, pspecs)

    # decode: one new token against a cache of seq_len
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.family == "audio" and cfg.encoder_layers:
        cache_specs = jax.eval_shape(
            lambda: model.init_cache(batch, seq, dt, enc_len=max(8, seq // 4)))
    else:
        cache_specs = jax.eval_shape(lambda: model.init_cache(batch, seq, dt))
    inputs = {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    b_spec = ba if batch > 1 else None
    pspecs = {"tokens": P(b_spec, None), "pos": P(b_spec)}
    cache_p = cache_pspecs_for(cfg, cache_specs, batch, multi_pod, rules)
    return CellSpec(arch, shape_name, kind, inputs, pspecs,
                    cache_specs=cache_specs, cache_pspecs=cache_p)
