"""Encoder-decoder backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, S_enc, D) for the encoder (S_enc = seq/4 —
the w2v-BERT conformer stack downsamples ~4x). The transformer backbone is
fully implemented: bidirectional encoder, causal decoder with cross-attention,
scanned layer stacks, decode with self-KV cache + precomputed cross-K/V.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import stack_layer_params
from repro.models.transformer import (vocab_padded, _maybe_remat, _scan_stack,
                                      _scan_with_cache)

F32 = jnp.float32


def enc_layer_init(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def dec_layer_init(key, cfg) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "lnx": L.rmsnorm_init(cfg.d_model),
        "xattn": L.attention_init(ks[1], cfg, cross=True),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[2], cfg),
    }


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: Any

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kd, kemb = jax.random.split(key, 3)
        ekeys = jax.random.split(ke, cfg.encoder_layers)
        dkeys = jax.random.split(kd, cfg.n_layers)
        return {
            "embed": L.embedding_init(kemb, cfg, vocab_padded(cfg)),
            "enc_layers": stack_layer_params([enc_layer_init(k, cfg) for k in ekeys]),
            "enc_ln": L.rmsnorm_init(cfg.d_model),
            "dec_layers": stack_layer_params([dec_layer_init(k, cfg) for k in dkeys]),
            "final_ln": L.rmsnorm_init(cfg.d_model),
        }

    def encode(self, params, enc_embeds):
        cfg = self.cfg
        x = enc_embeds.astype(jnp.dtype(cfg.param_dtype))
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(lp, x):
            x = x + L.mha_train(lp["attn"], L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps),
                                pos, cfg, causal=False)
            x = x + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"].value, x, cfg.norm_eps))
            return x, jnp.zeros((), F32)

        x, _ = _scan_stack(params["enc_layers"], x, _maybe_remat(body, cfg),
                           unroll=not cfg.scan_layers)
        return L.rmsnorm(params["enc_ln"].value, x, cfg.norm_eps)

    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["enc_embeds"])
        y = L.embed(params["embed"], batch["tokens"])
        b, s = y.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(lp, y):
            y = y + L.mha_train(lp["attn"], L.rmsnorm(lp["ln1"].value, y, cfg.norm_eps),
                                pos, cfg, causal=True)
            xk, xv = L.cross_kv(lp["xattn"], enc_out)
            y = y + L.cross_attend(lp["xattn"],
                                   L.rmsnorm(lp["lnx"].value, y, cfg.norm_eps),
                                   xk, xv, cfg)
            y = y + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"].value, y, cfg.norm_eps))
            return y, jnp.zeros((), F32)

        y, aux = _scan_stack(params["dec_layers"], y, _maybe_remat(body, cfg),
                             unroll=not cfg.scan_layers)
        y = L.rmsnorm(params["final_ln"].value, y, cfg.norm_eps)
        return L.unembed(params["embed"], y, cfg.tie_embeddings), aux

    def loss_fn(self, params, batch):
        logits, aux = self.forward(params, batch)
        loss = L.xent_loss(logits, batch["labels"], self.cfg.vocab_size)
        return loss + aux, {"loss": loss, "aux_loss": aux}

    def prefill(self, params, batch):
        logits, aux = self.forward(params, batch)
        return logits[:, -1:, :], aux

    def init_cache(self, batch: int, slots: int, dtype, enc_len: int = 0) -> Any:
        cfg = self.cfg
        hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
        lcount = cfg.n_layers
        enc_len = enc_len or max(1, slots // 4)
        return {
            "k": jnp.zeros((lcount, batch, slots, kv, hd), dtype),
            "v": jnp.zeros((lcount, batch, slots, kv, hd), dtype),
            "xk": jnp.zeros((lcount, batch, enc_len, kv, hd), dtype),
            "xv": jnp.zeros((lcount, batch, enc_len, kv, hd), dtype),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def fill_cross_cache(self, params, cache, enc_embeds):
        """Encode once, precompute per-layer cross K/V into the cache."""
        enc_out = self.encode(params, enc_embeds)

        def per_layer(lp):
            return L.cross_kv(lp["xattn"], enc_out)

        xk, xv = jax.vmap(per_layer)(params["dec_layers"])
        return {**cache, "xk": xk.astype(cache["xk"].dtype),
                "xv": xv.astype(cache["xv"].dtype)}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        y = L.embed(params["embed"], tokens)

        def body(lp, cs, y):
            kc, vc, xk, xv = cs
            yn = L.rmsnorm(lp["ln1"].value, y, cfg.norm_eps)
            a, k2, v2, _ = L.mha_decode(lp["attn"], yn, pos, kc, vc, cfg)
            y = y + a
            y = y + L.cross_attend(lp["xattn"],
                                   L.rmsnorm(lp["lnx"].value, y, cfg.norm_eps),
                                   xk, xv, cfg)
            y = y + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"].value, y, cfg.norm_eps))
            return y, (k2, v2)

        y, (nk, nv) = _scan_with_cache(
            params["dec_layers"],
            (cache["k"], cache["v"], cache["xk"], cache["xv"]),
            y, body, unroll=not cfg.scan_layers)
        y = L.rmsnorm(params["final_ln"].value, y, cfg.norm_eps)
        logits = L.unembed(params["embed"], y, cfg.tie_embeddings)
        return logits, {**cache, "k": nk, "v": nv, "pos": pos + 1}
