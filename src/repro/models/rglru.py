"""RG-LRU recurrent blocks (Griffin / RecurrentGemma — arXiv:2402.19427).

Recurrent sublayer: in-proj -> causal depthwise conv(4) -> RG-LRU -> gated
merge -> out-proj. The RG-LRU update:

    r_t = sigmoid(w_r . x_t + b_r)          (recurrence gate, per channel)
    i_t = sigmoid(w_i . x_t + b_i)          (input gate, per channel)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over the sequence (O(log L) depth);
decoding is the single-step update. Gates are per-channel diagonal (the
upstream implementation uses block-diagonal per-head linear gates; the
diagonal form keeps every sharded axis trivially divisible — noted in
DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Param, dense_init, ones_init, zeros_init
from repro.models.ssm import _causal_dconv, _dconv_step

F32 = jnp.float32
C_MAG = 8.0


def rglru_init(key, cfg) -> dict:
    d = cfg.d_model
    dr = d  # lru width = d_model (RecurrentGemma-2B: 2560)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    # Lambda init so a ~ Uniform(0.9, 0.999) at r=1 (paper App. A)
    u = jax.random.uniform(ks[3], (dr,), F32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / C_MAG))  # softplus^{-1}(-log a / c)
    return {
        "w_in": dense_init(ks[0], (d, dr), ("embed", "rnn"), dt),
        "w_gate": dense_init(ks[1], (d, dr), ("embed", "rnn"), dt),
        "conv": dense_init(ks[2], (4, dr), ("conv", "rnn"), dt, scale=0.5),
        "w_r": ones_init((dr,), ("rnn",), F32),
        "b_r": zeros_init((dr,), ("rnn",), F32),
        "w_i": ones_init((dr,), ("rnn",), F32),
        "b_i": zeros_init((dr,), ("rnn",), F32),
        "lam": Param(lam, ("rnn",)),
        "w_out": dense_init(jax.random.fold_in(key, 7), (dr, d), ("rnn", "embed"), dt),
    }


def _gates(p, x):
    """x: (..., dr) -> (a, gated_input) in f32."""
    xf = x.astype(F32)
    r = jax.nn.sigmoid(xf * p["w_r"].value + p["b_r"].value)
    i = jax.nn.sigmoid(xf * p["w_i"].value + p["b_i"].value)
    log_a = -C_MAG * jax.nn.softplus(p["lam"].value) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * xf)


def rglru_train(p, x, cfg):
    """Full-sequence recurrent sublayer. x: (B, L, D) -> (B, L, D)."""
    u = _causal_dconv(jnp.einsum("bld,de->ble", x, p["w_in"].value), p["conv"].value)
    a, bx = _gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = h.astype(x.dtype)
    gate = jax.nn.gelu(jnp.einsum("bld,de->ble", x, p["w_gate"].value))
    return jnp.einsum("ble,ed->bld", h * gate, p["w_out"].value)


def rglru_init_state(cfg, batch: int, dtype) -> dict:
    dr = cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), F32),
        "conv": jnp.zeros((batch, 3, dr), dtype),
    }


def rglru_decode(p, x1, state, cfg):
    """One-token decode. x1: (B, 1, D)."""
    xin = jnp.einsum("bd,de->be", x1[:, 0, :], p["w_in"].value)
    u, conv_st = _dconv_step(state["conv"], xin, p["conv"].value)
    a, bx = _gates(p, u)
    h = a * state["h"] + bx
    gate = jax.nn.gelu(jnp.einsum("bd,de->be", x1[:, 0, :], p["w_gate"].value))
    y = jnp.einsum("be,ed->bd", h.astype(x1.dtype) * gate, p["w_out"].value)
    return y[:, None, :], {"h": h, "conv": conv_st}
