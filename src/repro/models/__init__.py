"""repro.models — layer library and the 10 assigned architectures."""
from repro.models.registry import build_model, attn_policy, sharding_rules, make_cell
