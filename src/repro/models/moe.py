"""Mixture-of-Experts layer: GShard-style top-k dispatch/combine einsums.

Covers both assigned MoE architectures:
  * arctic-480b    — 128 experts, top-2, plus a *dense residual* FFN computed
    in parallel with the MoE branch every layer (Snowflake Arctic).
  * deepseek-moe-16b — fine-grained 64 routed experts top-6 plus 2 *shared*
    experts that process every token (DeepSeekMoE). Shared experts are
    algebraically a dense SwiGLU of width n_shared * d_ff_expert, so they are
    fused into one dense MLP.

Expert weights carry the "experts" logical axis -> sharded over the `model`
mesh axis (EP); the SPMD partitioner lowers the dispatch/combine einsums into
all-to-alls, which the roofline pass audits. Tokens route within their batch
row (GShard groups) with capacity ``ceil(top_k * S * cf / E)``; overflow
drops (counted in aux metrics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Param, dense_init
from repro.models import layers as L

F32 = jnp.float32


def moe_init(key, cfg) -> dict:
    mo = cfg.moe
    d, e, f = cfg.d_model, mo.n_experts, mo.d_ff_expert
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), ("embed", "experts"), F32),
        "wi_gate": dense_init(ks[1], (e, d, f), ("experts", "embed", "expert_ff"), dt),
        "wi_up": dense_init(ks[2], (e, d, f), ("experts", "embed", "expert_ff"), dt),
        "wo": dense_init(ks[3], (e, f, d), ("experts", "expert_ff", "embed"), dt,
                         scale=f ** -0.5),
    }
    if mo.n_shared:
        p["shared"] = L.mlp_init(ks[4], cfg, d_ff=mo.n_shared * f)
    if mo.dense_residual:
        p["dense"] = L.mlp_init(ks[5], cfg, d_ff=cfg.d_ff)
    return p


def moe_apply(p, x, cfg):
    """Returns (y, aux_loss). x: (B, S, D)."""
    mo = cfg.moe
    b, s, d = x.shape
    e, k = mo.n_experts, mo.top_k
    cap = max(1, int(mo.capacity_factor * k * s / e))

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"].value)
    probs = jax.nn.softmax(logits, axis=-1)                      # (B,S,E) f32
    gates, idx = jax.lax.top_k(probs, k)                         # (B,S,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx, e, dtype=F32)                   # (B,S,K,E)
    # position of each (token, choice) in its expert's queue, in token order
    flat = onehot.reshape(b, s * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, k, e)  # (B,S,K,E)
    pos_tok = jnp.sum(pos * onehot, axis=-1)                     # (B,S,K)
    keep = (pos_tok < cap).astype(F32)                           # capacity drop
    pos_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), cap, dtype=F32)

    dt = x.dtype
    dispatch = jnp.einsum("bske,bskc->bsec", onehot, pos_oh * keep[..., None]).astype(dt)
    combine = jnp.einsum("bske,bskc->bsec", onehot * gates[..., None],
                         pos_oh * keep[..., None]).astype(dt)

    e_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)             # (E,B,C,D)
    g = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", e_in, p["wi_gate"].value))
    u = jnp.einsum("ebcd,edf->ebcf", e_in, p["wi_up"].value)
    e_out = jnp.einsum("ebcf,efd->ebcd", g * u, p["wo"].value)
    y = jnp.einsum("ebcd,bsec->bsd", e_out, combine)

    if "shared" in p:
        y = y + L.mlp(p["shared"], x)
    if "dense" in p:
        y = y + L.mlp(p["dense"], x)

    # Switch-style load-balancing auxiliary loss
    density = jnp.mean(onehot, axis=(0, 1, 2))                   # (E,)
    mean_probs = jnp.mean(probs, axis=(0, 1))                    # (E,)
    aux = e * jnp.sum(density * mean_probs) * mo.router_aux_weight
    return y, aux
