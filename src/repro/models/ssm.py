"""Mamba-2 SSD (state-space duality) blocks — arXiv:2405.21060.

Training path: the chunked SSD algorithm (paper §6) — intra-chunk quadratic
attention-like term + inter-chunk state recurrence via lax.scan. Decode path:
O(1) recurrent state update per token.

Sharding: the inner ("rnn") feature axis and the SSM heads shard over the
`model` mesh axis; projections are kept *separate* (W_z/W_x/W_B/W_C/W_dt
instead of one fused in-projection) so every sharded axis slices on shard
boundaries (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Param, dense_init, ones_init, zeros_init

F32 = jnp.float32


def ssm_init(key, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    h = d_in // s.head_dim
    gn = s.n_groups * s.state_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "w_z": dense_init(ks[0], (d, d_in), ("embed", "rnn"), dt),
        "w_x": dense_init(ks[1], (d, d_in), ("embed", "rnn"), dt),
        "w_B": dense_init(ks[2], (d, gn), ("embed", "state"), dt),
        "w_C": dense_init(ks[3], (d, gn), ("embed", "state"), dt),
        "w_dt": dense_init(ks[4], (d, h), ("embed", "heads"), dt),
        "conv_x": dense_init(ks[5], (s.conv_width, d_in), ("conv", "rnn"), dt, scale=0.5),
        "conv_B": dense_init(ks[6], (s.conv_width, gn), ("conv", "state"), dt, scale=0.5),
        "conv_C": dense_init(ks[7], (s.conv_width, gn), ("conv", "state"), dt, scale=0.5),
        # A in (-1, 0): A = -exp(A_log); init A in [-1, -0.5]
        "A_log": Param(jnp.log(jnp.linspace(0.5, 1.0, h)).astype(F32), ("heads",)),
        "dt_bias": zeros_init((h,), ("heads",), F32),
        "D": ones_init((h,), ("heads",), F32),
        "norm": ones_init((d_in,), ("rnn",), F32),
        "w_out": dense_init(jax.random.fold_in(key, 99), (d_in, d),
                            ("rnn", "embed"), dt),
    }
    return p


def _causal_dconv(x, w):
    """Depthwise causal 1-D conv. x: (B, L, C); w: (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out


def _segsum(x):
    """(..., T) -> (..., T, T) lower-triangular segment sums (SSD decay)."""
    t = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    d = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xh, dth, a, bh, ch, chunk: int):
    """Chunked SSD scan (Dao & Gu 2024, minimal listing, jnp).

    Args:
      xh: (B, L, H, P) inputs (already dt-weighted NOT applied; we apply here).
      dth: (B, L, H) positive step sizes.
      a: (H,) negative continuous-time decay.
      bh, ch: (B, L, H, N) input/output projections (expanded per head).
      chunk: chunk length (L % chunk == 0).

    Returns:
      (B, L, H, P) outputs and final state (B, H, P, N).
    """
    b, l, h, p = xh.shape
    n = bh.shape[-1]
    nc = l // chunk
    xb = (xh * dth[..., None]).reshape(b, nc, chunk, h, p)
    ab = (a[None, None, :] * dth).reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)
    bb = bh.reshape(b, nc, chunk, h, n)
    cb = ch.reshape(b, nc, chunk, h, n)

    a_cs = jnp.cumsum(ab, axis=-1)                    # (B,H,C,Lc)
    decay = jnp.exp(_segsum(ab.astype(F32)))          # (B,H,C,Lc,Lc)

    # intra-chunk (quadratic within chunk)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        cb, bb, decay.astype(xh.dtype), xb)

    # chunk-final states
    decay_states = jnp.exp((a_cs[..., -1:] - a_cs).astype(F32)).astype(xh.dtype)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", bb, decay_states, xb)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1].astype(F32)).astype(xh.dtype)  # (B,H,C)

    def step(s_prev, inp):
        dec_c, st_c = inp  # (B,H), (B,H,P,N)
        s = s_prev * dec_c[..., None, None] + st_c
        return s, s_prev

    s0 = jnp.zeros((b, h, p, n), xh.dtype)
    s_final, s_before = jax.lax.scan(
        step, s0,
        (chunk_decay.transpose(2, 0, 1), states.transpose(1, 0, 2, 3, 4)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)      # (B,C,H,P,N)

    state_decay_out = jnp.exp(a_cs.astype(F32)).astype(xh.dtype)  # (B,H,C,Lc)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cb, s_before, state_decay_out)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, s_final


def ssm_train(p, x, cfg):
    """Full-sequence Mamba-2 mixer. x: (B, L, D) -> (B, L, D)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    z = jnp.einsum("bld,de->ble", x, p["w_z"].value)
    xc = _causal_dconv(jnp.einsum("bld,de->ble", x, p["w_x"].value), p["conv_x"].value)
    bc = _causal_dconv(jnp.einsum("bld,de->ble", x, p["w_B"].value), p["conv_B"].value)
    cc = _causal_dconv(jnp.einsum("bld,de->ble", x, p["w_C"].value), p["conv_C"].value)
    xc, bc, cc = jax.nn.silu(xc), jax.nn.silu(bc), jax.nn.silu(cc)
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, p["w_dt"].value).astype(F32)
        + p["dt_bias"].value)
    a = -jnp.exp(p["A_log"].value)                    # (H,) negative

    bl, l = x.shape[0], x.shape[1]
    xh = xc.reshape(bl, l, h, s.head_dim)
    # expand groups to heads (n_groups=1: broadcast)
    reps = h // s.n_groups
    bh = jnp.repeat(bc.reshape(bl, l, s.n_groups, s.state_dim), reps, axis=2)
    ch = jnp.repeat(cc.reshape(bl, l, s.n_groups, s.state_dim), reps, axis=2)

    y, _ = ssd_chunked(xh, dt.astype(x.dtype), a, bh, ch, min(s.chunk, l))
    y = y + xh * p["D"].value[None, None, :, None].astype(x.dtype)
    y = y.reshape(bl, l, d_in)
    # gated RMSNorm then out-projection (Mamba-2 block tail)
    from repro.models.layers import rmsnorm
    y = rmsnorm(p["norm"].value, y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, p["w_out"].value)


def ssm_init_state(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    gn = s.n_groups * s.state_dim
    w = s.conv_width - 1
    return {
        "ssm": jnp.zeros((batch, h, s.head_dim, s.state_dim), dtype),
        "conv_x": jnp.zeros((batch, w, d_in), dtype),
        "conv_B": jnp.zeros((batch, w, gn), dtype),
        "conv_C": jnp.zeros((batch, w, gn), dtype),
    }


def _dconv_step(state, xnew, w):
    """One causal depthwise conv step. state: (B, W-1, C); xnew: (B, C)."""
    full = jnp.concatenate([state, xnew[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w)
    return y, full[:, 1:, :]


def ssm_decode(p, x1, state, cfg):
    """One-token decode. x1: (B, 1, D); state from ssm_init_state."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    x = x1[:, 0, :]
    z = jnp.einsum("bd,de->be", x, p["w_z"].value)
    xc, st_x = _dconv_step(state["conv_x"], jnp.einsum("bd,de->be", x, p["w_x"].value), p["conv_x"].value)
    bc, st_b = _dconv_step(state["conv_B"], jnp.einsum("bd,de->be", x, p["w_B"].value), p["conv_B"].value)
    cc, st_c = _dconv_step(state["conv_C"], jnp.einsum("bd,de->be", x, p["w_C"].value), p["conv_C"].value)
    xc, bc, cc = jax.nn.silu(xc), jax.nn.silu(bc), jax.nn.silu(cc)
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", x, p["w_dt"].value).astype(F32) + p["dt_bias"].value)
    a = -jnp.exp(p["A_log"].value)
    da = jnp.exp(dt * a[None, :]).astype(x.dtype)                 # (B,H)

    reps = h // s.n_groups
    bh = jnp.repeat(bc.reshape(-1, s.n_groups, s.state_dim), reps, axis=1)
    ch = jnp.repeat(cc.reshape(-1, s.n_groups, s.state_dim), reps, axis=1)
    xh = xc.reshape(-1, h, s.head_dim)

    new_ssm = (state["ssm"] * da[..., None, None]
               + jnp.einsum("bhp,bhn,bh->bhpn", xh, bh, dt.astype(x.dtype)))
    y = jnp.einsum("bhn,bhpn->bhp", ch, new_ssm)
    y = y + xh * p["D"].value[None, :, None].astype(x.dtype)
    y = y.reshape(-1, d_in)
    from repro.models.layers import rmsnorm
    y = rmsnorm(p["norm"].value, y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["w_out"].value)
    new_state = {"ssm": new_ssm, "conv_x": st_x, "conv_B": st_b, "conv_C": st_c}
    return out[:, None, :], new_state
