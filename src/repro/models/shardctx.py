"""Tracing-time sharding context for in-model sharding constraints.

Optimization passes (§Perf) need ``with_sharding_constraint`` inside layer
code, which requires the mesh. The launcher/dry-run sets this context before
tracing; when unset (tests, single-device runs) every constraint is a no-op,
so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: dict = {"mesh": None, "batch_axes": ("data",)}


def set_ctx(mesh: Optional[Mesh], batch_axes: tuple = ("data",)) -> None:
    _CTX["mesh"] = mesh
    _CTX["batch_axes"] = tuple(batch_axes)


@contextlib.contextmanager
def ctx(mesh: Optional[Mesh], batch_axes: tuple = ("data",)):
    prev = dict(_CTX)
    set_ctx(mesh, batch_axes)
    try:
        yield
    finally:
        _CTX.update(prev)


def mesh() -> Optional[Mesh]:
    return _CTX["mesh"]


def batch_axes() -> tuple:
    return _CTX["batch_axes"]


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint when a mesh is set; identity otherwise."""
    m = _CTX["mesh"]
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))


def batch_model_axes() -> Optional[tuple]:
    """Mesh axes for 2D batch sharding (batch over data axes + model), or
    None when no mesh is set."""
    m = _CTX["mesh"]
    if m is None:
        return None
    return tuple(_CTX["batch_axes"]) + ("model",)
