"""Shared layer library: RMSNorm, RoPE, GQA attention (train/decode, SWA,
qk-norm, cross), SwiGLU MLP, embedding/unembed.

Conventions:
  * params are ``Param(value, logical_axes)`` trees (see params.py);
  * weights/activations in cfg dtype (bf16), norm scales and softmax/norm
    internals in f32 (mixed precision);
  * long-sequence attention is query-chunked (lax.scan over query blocks) so
    the (S, T) score tensor never materializes at 32k+ — the XLA-level
    equivalent of flash attention's streaming softmax, adequate for dry-run
    roofline math and CPU execution alike;
  * decode caches: full (B, S_max, KV, hd) or ring buffers of ``window`` slots
    for SWA/local attention (RoPE is applied at write time with absolute
    positions, so reads need no re-rotation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import numerics
from repro.models.params import Param, dense_init, ones_init
from repro.models import shardctx

F32 = jnp.float32
# large negative for masks, dtype-derived so it stays finite after bf16 casts
NEG = numerics.mask_fill(jnp.bfloat16)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm_init(d: int) -> Param:
    return ones_init((d,), ("embed",), F32)


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def headwise_rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Qwen3-style per-head RMS norm over head_dim; x: (..., hd)."""
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (B, S, H, hd), positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=F32) / half))
    ang = positions[..., None].astype(F32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def attention_init(key, cfg, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), ("embed", "heads", "head_dim"), dt),
        "wk": dense_init(ks[1], (d, kv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": dense_init(ks[2], (d, kv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": dense_init(ks[3], (h, hd, d), ("heads", "head_dim", "embed"), dt,
                         scale=(h * hd) ** -0.5),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = ones_init((hd,), ("head_dim",), F32)
        p["k_norm"] = ones_init((hd,), ("head_dim",), F32)
    return p


def _qkv(p, x, positions, cfg, *, rope_qk: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].value)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].value)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].value)
    if cfg.qk_norm and "q_norm" in p:
        q = headwise_rmsnorm(p["q_norm"].value, q, cfg.norm_eps)
        k = headwise_rmsnorm(p["k_norm"].value, k, cfg.norm_eps)
    if rope_qk:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_kv: int, scores_f32: bool = True):
    """Grouped scaled-dot-product attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); mask: (B|1, Sq, Skv) bool.
    scores_f32=False keeps the score tensor in bf16 with an f32 running max /
    denominator (flash-style numerics at XLA level) — §Perf memory lever.
    """
    b, sq, h, hd = q.shape
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k)
    scale = jnp.asarray(hd ** -0.5, scores.dtype)
    if scores_f32:
        scores = scores.astype(F32) * scale
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    else:
        scores = scores * scale
        neg = jnp.asarray(numerics.mask_fill(scores.dtype), scores.dtype)
        scores = jnp.where(mask[:, None, None, :, :], scores, neg)
        m = jnp.max(scores.astype(F32), axis=-1, keepdims=True)
        e = jnp.exp((scores.astype(F32) - m)).astype(q.dtype)
        denom = jnp.sum(e.astype(F32), axis=-1, keepdims=True)
        w = (e / jnp.maximum(denom, 1e-30).astype(q.dtype))
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(b, sq, h, hd)


def _train_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(B, Sq, Skv) mask from absolute positions."""
    d = q_pos[:, :, None] - k_pos[:, None, :]
    m = jnp.ones(d.shape, bool)
    if causal:
        m &= d >= 0
    if window is not None:
        m &= d < window
    return m


def mha_train(
    p, x, positions, cfg, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: Optional[int] = None,
) -> jax.Array:
    """Full-sequence self-attention; query-chunked when S > q_chunk.

    cfg.attn_batch_shard (§Perf, policy-C archs): reshard the batch over
    (data..., model) around the attention so replicated-head compute splits
    over the full mesh instead of the data axis only.
    """
    q_chunk = q_chunk or cfg.q_chunk
    bm = shardctx.batch_model_axes()
    shard2d = (cfg.attn_batch_shard and bm is not None
               and x.shape[0] % __import__("math").prod(
                   shardctx.mesh().shape[a] for a in bm) == 0)
    if shard2d:
        from jax.sharding import PartitionSpec as P
        x = shardctx.constrain(x, P(bm, None, None))
        positions = shardctx.constrain(positions, P(bm, None))
    q, k, v = _qkv(p, x, positions, cfg)
    b, s = x.shape[:2]
    if s <= q_chunk:
        mask = _train_mask(positions, positions, causal, window)
        out = _sdpa(q, k, v, mask, cfg.n_kv_heads, cfg.attn_scores_f32)
    else:
        assert s % q_chunk == 0, (s, q_chunk)
        nc = s // q_chunk
        qc = q.reshape(b, nc, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(b, nc, q_chunk).transpose(1, 0, 2)

        def step(_, inp):
            qi, pi = inp
            mask = _train_mask(pi, positions, causal, window)
            return None, _sdpa(qi, k, v, mask, cfg.n_kv_heads,
                               cfg.attn_scores_f32)

        _, outs = jax.lax.scan(step, None, (qc, pc))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, *q.shape[2:])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].value)
    if shard2d:
        from jax.sharding import PartitionSpec as P
        y = shardctx.constrain(y, P(shardctx.batch_axes(), None, None))
    return y


# -- decode -----------------------------------------------------------------
@dataclasses.dataclass
class KVCache:
    """Per-layer-stacked KV cache. ``window`` => ring buffer semantics."""
    k: jax.Array  # (L, B, S_slots, KV, hd)
    v: jax.Array
    window: Optional[int] = None


def _ring_slot(pos: jax.Array, window: int) -> jax.Array:
    return jnp.mod(pos, window)


def decode_key_positions(pos: jax.Array, n_slots: int, window: Optional[int]):
    """Absolute position held by each cache slot at decode step ``pos``.

    pos: (B,) int32 — position of the token being decoded (0-based); slots
    holding nothing yet get position -1 (masked).
    Full cache: slot s holds position s if s <= pos.
    Ring cache: slot s holds the latest position p <= pos with p % window == s.
    """
    slots = jnp.arange(n_slots)[None, :]  # (1, S)
    if window is None:
        kpos = jnp.where(slots <= pos[:, None], slots, -1)
    else:
        w = jnp.mod(pos[:, None], window)
        kpos = pos[:, None] - jnp.mod(w - slots, window)
        kpos = jnp.where(kpos < 0, -1, kpos)
    return kpos  # (B, S_slots)


def quantize_kv(x: jax.Array):
    """(B, 1, KV, hd) -> (int8 codes, per-(token, head) f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _sdpa_pruned(q, k_sel, v_sel, mask_sel, n_kv: int, scores_f32: bool):
    """Decode attention over per-kv-head selected keys.

    q: (B, 1, H, hd); k_sel/v_sel: (B, KV, T', hd); mask_sel: (B, KV, T').
    """
    b, sq, h, hd = q.shape
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, hd)
    scores = jnp.einsum("bskgh,bkth->bkgst", qg, k_sel).astype(F32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask_sel[:, :, None, None, :], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bkth->bskgh", w, v_sel)
    return out.reshape(b, sq, h, hd)


def mha_decode(
    p, x1, pos, k_cache, v_cache, cfg, *, window: Optional[int] = None,
    extras: Optional[dict] = None,
):
    """Single-token decode with cache update.

    x1: (B, 1, D); pos: (B,) absolute positions; k_cache/v_cache:
    (B, S_slots, KV, hd) — int8 when cfg.kv_cache_int8 (then ``extras`` holds
    per-token scales). cfg.kv_block_prune > 0 enables zone-map block pruning
    (§Perf / DESIGN.md: the paper's R-tree MBR prune applied to key blocks —
    per-block min/max key coordinates bound the q.k score; only the
    top-``kv_block_prune`` blocks are read).

    Returns (y1, k_cache', v_cache', extras').
    """
    q, k, v = _qkv(p, x1, pos[:, None], cfg)
    n_slots = k_cache.shape[1]
    slot = pos if window is None else _ring_slot(pos, window)
    extras = dict(extras or {})

    def upd(cache, new, trailing=2):
        def one(c, n, s):
            return jax.lax.dynamic_update_slice(
                c, n.astype(c.dtype), (s,) + (0,) * trailing)
        return jax.vmap(one)(cache, new, slot)

    if cfg.kv_cache_int8:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = upd(k_cache, kq)
        v_cache = upd(v_cache, vq)
        extras["k_scale"] = upd(extras["k_scale"], ks)
        extras["v_scale"] = upd(extras["v_scale"], vs)
    else:
        k_cache = upd(k_cache, k)
        v_cache = upd(v_cache, v)

    if cfg.kv_block_prune:
        assert window is None, "block pruning targets full caches"
        bs = cfg.kv_block_size
        nb = n_slots // bs
        bidx = slot // bs
        # zone maps: running per-block min/max of (rope'd) keys
        def zupd(z, new, op):
            def one(zc, n, bi):
                cur = jax.lax.dynamic_slice(zc, (bi, 0, 0), (1,) + zc.shape[1:])
                return jax.lax.dynamic_update_slice(
                    zc, op(cur, n.astype(zc.dtype)), (bi, 0, 0))
            return jax.vmap(one)(z, new, bidx)

        extras["kmin"] = zupd(extras["kmin"], k, jnp.minimum)
        extras["kmax"] = zupd(extras["kmax"], k, jnp.maximum)

    kpos = decode_key_positions(pos, n_slots, window)
    mask = (kpos >= 0) & (kpos <= pos[:, None])  # (B, S_slots)

    if cfg.kv_block_prune:
        keep = min(cfg.kv_block_prune, nb)
        # score upper bound per (q head, block): sum_d max(q_d*min_d, q_d*max_d)
        qh = q[:, 0].astype(F32)                                  # (B, H, hd)
        g = cfg.n_heads // cfg.n_kv_heads
        qg = qh.reshape(qh.shape[0], cfg.n_kv_heads, g, qh.shape[-1])
        kmin = extras["kmin"].astype(F32)                         # (B, nb, KV, hd)
        kmax = extras["kmax"].astype(F32)
        # sum_d max(q_d*kmin_d, q_d*kmax_d) = q+.kmax + q-.kmin  (exact bound)
        qpos = jnp.maximum(qg, 0.0)
        qneg = jnp.minimum(qg, 0.0)
        ub = (jnp.einsum("bkgh,bnkh->bkgn", qpos, kmax)
              + jnp.einsum("bkgh,bnkh->bkgn", qneg, kmin)).max(axis=2)  # (B,KV,nb)
        # blocks with no valid key yet are never selected
        blk_valid = mask.reshape(mask.shape[0], nb, bs).any(-1)   # (B, nb)
        ub = jnp.where(blk_valid[:, None, :], ub, -jnp.inf)
        # always keep the block being written (recency)
        cur = jax.nn.one_hot(bidx, nb, dtype=jnp.bool_)[:, None, :]
        ub = jnp.where(cur, jnp.inf, ub)
        if cfg.kv_prune_groups:
            # shard-local selection: top-(keep/G) inside each contiguous block
            # group; groups align with the model-axis slot shards, so the
            # block gather never crosses devices (§Perf arctic iteration 3)
            G = cfg.kv_prune_groups
            assert nb % G == 0, f"blocks {nb} must divide into {G} groups"
            nbg = nb // G
            kg = max(1, keep // G)
            ubg = ub.reshape(ub.shape[0], ub.shape[1], G, nbg)
            _, topg = jax.lax.top_k(ubg, kg)                      # (B,KV,G,kg)
            offs = (jnp.arange(G) * nbg)[None, None, :, None]
            top = (topg + offs).reshape(ub.shape[0], ub.shape[1], G * kg)
            keep = G * kg
        else:
            _, top = jax.lax.top_k(ub, keep)                      # (B, KV, keep)

        def gather_blocks(cache):
            b = cache.shape[0]
            cb = cache.reshape(b, nb, bs, cache.shape[2], cache.shape[3])
            cb = cb.transpose(0, 3, 1, 2, 4)                      # (B,KV,nb,bs,hd)
            sel = jnp.take_along_axis(cb, top[:, :, :, None, None], axis=2)
            return sel.reshape(b, cache.shape[2], keep * bs, cache.shape[3])

        k_sel = gather_blocks(k_cache)
        v_sel = gather_blocks(v_cache)
        if cfg.kv_cache_int8:
            ks_sel = gather_blocks(extras["k_scale"])
            vs_sel = gather_blocks(extras["v_scale"])
            k_sel = k_sel.astype(x1.dtype) * ks_sel.astype(x1.dtype)
            v_sel = v_sel.astype(x1.dtype) * vs_sel.astype(x1.dtype)
        mb = mask.reshape(mask.shape[0], nb, bs)                  # (B, nb, bs)
        mask_sel = jnp.take_along_axis(
            jnp.broadcast_to(mb[:, None], (mb.shape[0], cfg.n_kv_heads, nb, bs)),
            top[:, :, :, None], axis=2).reshape(mask.shape[0], cfg.n_kv_heads,
                                                keep * bs)
        out = _sdpa_pruned(q, k_sel.astype(x1.dtype), v_sel.astype(x1.dtype),
                           mask_sel, cfg.n_kv_heads, cfg.attn_scores_f32)
    else:
        if cfg.kv_cache_int8:
            kf = k_cache.astype(x1.dtype) * extras["k_scale"].astype(x1.dtype)
            vf = v_cache.astype(x1.dtype) * extras["v_scale"].astype(x1.dtype)
        else:
            kf, vf = k_cache, v_cache
        out = _sdpa(q, kf, vf, mask[:, None, :], cfg.n_kv_heads,
                    cfg.attn_scores_f32)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].value)
    return y, k_cache, v_cache, extras


# -- cross-attention (encoder-decoder) ---------------------------------------
def cross_kv(p, enc_out):
    """Precompute cross K/V from encoder output (cached for decode)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].value)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].value)
    return k, v


def cross_attend(p, x, k, v, cfg, enc_mask=None):
    """Cross-attention: no RoPE, no causality; enc_mask: (B, S_enc) or None."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].value)
    b, sq = x.shape[:2]
    skv = k.shape[1]
    mask = jnp.ones((b, sq, skv), bool) if enc_mask is None else \
        jnp.broadcast_to(enc_mask[:, None, :], (b, sq, skv))
    out = _sdpa(q, k, v, mask, cfg.n_kv_heads)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].value)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp_init(key, cfg, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(ks[0], (d, f), ("embed", "ff"), dt),
        "wi_up": dense_init(ks[1], (d, f), ("embed", "ff"), dt),
        "wo": dense_init(ks[2], (f, d), ("ff", "embed"), dt),
    }


def mlp(p, x):
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi_gate"].value))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].value)
    return jnp.einsum("bsf,fd->bsd", g * u, p["wo"].value)


# --------------------------------------------------------------------------
# embedding / unembed
# --------------------------------------------------------------------------
def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def embedding_init(key, cfg, vocab_pad: int) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    # Gemma-style scaling: table std d^-1/2 (keeps tied-unembed logits O(1)),
    # embedding output multiplied by sqrt(d) to restore unit activation scale.
    p = {"table": dense_init(key, (vocab_pad, cfg.d_model), ("vocab", "embed"),
                             dt, scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(
            jax.random.fold_in(key, 1), (cfg.d_model, vocab_pad),
            ("embed", "vocab"), dt)
    return p


def embed(p, tokens):
    x = jnp.take(p["table"].value, tokens, axis=0)
    return x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)


def unembed(p, x, tie: bool) -> jax.Array:
    if tie:
        return jnp.einsum("bsd,vd->bsv", x, p["table"].value).astype(F32)
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"].value).astype(F32)


def xent_loss(logits: jax.Array, labels: jax.Array, vocab_size: int) -> jax.Array:
    """Mean token cross-entropy; logits may be vocab-padded (labels < vocab).

    Padded vocabulary rows are masked to -inf so they carry no probability
    mass (their embedding rows are random-init and untrained).
    """
    logits = logits.astype(F32)
    if logits.shape[-1] > vocab_size:
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(vocab_ids < vocab_size, logits, NEG)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
