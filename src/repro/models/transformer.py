"""Decoder-only LM assembly for the dense / moe / ssm / hybrid / vlm / audio
(decoder) families.

Layer stacks are scanned (``lax.scan`` over stacked per-layer params) so HLO
stays compact at 512-way SPMD; remat wraps the per-layer body. Hybrid
(Griffin) stacks scan over (rec, rec, attn) *groups* plus a small scanned
tail, matching RecurrentGemma's 26 = 8*3 + 2 pattern exactly.

Three entry points per model (built in registry.py):
  * ``loss_fn(params, batch)``            -> (loss, metrics)        [train]
  * ``prefill(params, batch)``            -> (logits, cache)        [prefill]
  * ``decode_step(params, cache, tokens, pos)`` -> (logits, cache)  [decode]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import numerics
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.params import Param, stack_layer_params
from repro.models import shardctx

F32 = jnp.float32
VOCAB_MULT = 256  # pad vocab to a multiple of this (divisible by model axis)


def vocab_padded(cfg) -> int:
    return L.round_up(cfg.vocab_size, VOCAB_MULT)


# ---------------------------------------------------------------------------
# per-layer blocks
# ---------------------------------------------------------------------------
def dense_layer_init(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def moe_layer_init(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "moe": M.moe_init(ks[1], cfg),
    }


def ssm_layer_init(key, cfg) -> dict:
    return {"ln1": L.rmsnorm_init(cfg.d_model), "ssm": S.ssm_init(key, cfg)}


def rec_layer_init(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "rec": R.rglru_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def _attn_window(cfg) -> Optional[int]:
    return cfg.sliding_window


def dense_layer_train(lp, x, positions, cfg, window=None):
    x = x + L.mha_train(lp["attn"], L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps),
                        positions, cfg, window=window)
    x = x + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"].value, x, cfg.norm_eps))
    return x, jnp.zeros((), F32)


def moe_layer_train(lp, x, positions, cfg):
    x = x + L.mha_train(lp["attn"], L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps),
                        positions, cfg, window=_attn_window(cfg))
    y, aux = M.moe_apply(lp["moe"], L.rmsnorm(lp["ln2"].value, x, cfg.norm_eps), cfg)
    return x + y, aux


def ssm_layer_train(lp, x, positions, cfg):
    x = x + S.ssm_train(lp["ssm"], L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps), cfg)
    return x, jnp.zeros((), F32)


def rec_layer_train(lp, x, positions, cfg):
    x = x + R.rglru_train(lp["rec"], L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps), cfg)
    x = x + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"].value, x, cfg.norm_eps))
    return x, jnp.zeros((), F32)


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------
def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat != "none" else fn


def _tree_slice(t, i):
    return jax.tree.map(lambda a: a[i], t)


def _tree_stack(ts):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ts)


def _scan_with_cache(params_stacked, cache_stacked, x, body, unroll: bool):
    """Layer loop threading x and emitting per-layer new cache.

    body(layer_params, cache_slice, x) -> (x, new_cache_slice).
    """
    if unroll:
        n = jax.tree.leaves(params_stacked)[0].shape[0]
        outs = []
        for i in range(n):
            x, nc = body(_tree_slice(params_stacked, i),
                         _tree_slice(cache_stacked, i), x)
            outs.append(nc)
        return x, _tree_stack(outs)

    def step(x, inp):
        lp, cs = inp
        return body(lp, cs, x)

    return jax.lax.scan(step, x, (params_stacked, cache_stacked))


def _scan_stack(stacked, x, body, unroll: bool = False):
    """Apply a stacked-layer body L times.

    ``unroll=False`` (default): lax.scan — compact HLO, production path.
    ``unroll=True``: python loop — used by the dry-run cost extraction because
    XLA's cost analysis counts a while-loop body once instead of trip-count
    times (measured; see EXPERIMENTS.md §Roofline methodology).
    """
    if unroll:
        n = jax.tree.leaves(stacked)[0].shape[0]
        aux = jnp.zeros((), F32)
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], stacked)
            x, aux_l = body(lp, x)
            aux = aux + aux_l
        return x, aux

    def step(carry, lp):
        x, aux = carry
        y, aux_l = body(lp, x)
        return (y, aux + aux_l), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), F32)), stacked)
    return x, aux


@dataclasses.dataclass(frozen=True)
class DecoderLM:
    """Family-dispatching decoder-only LM."""

    cfg: Any

    # -- init ---------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        kemb, klayers, ktail = jax.random.split(key, 3)
        params: dict = {
            "embed": L.embedding_init(kemb, cfg, vocab_padded(cfg)),
            "final_ln": L.rmsnorm_init(cfg.d_model),
        }
        if cfg.family == "hybrid":
            n_groups, tail = divmod(cfg.n_layers, 3)
            gkeys = jax.random.split(klayers, n_groups)
            groups = [self._group_init(k) for k in gkeys]
            params["groups"] = stack_layer_params(groups)
            if tail:
                tkeys = jax.random.split(ktail, tail)
                params["tail"] = stack_layer_params(
                    [rec_layer_init(k, cfg) for k in tkeys])
        else:
            layer_init = {"dense": dense_layer_init, "moe": moe_layer_init,
                          "ssm": ssm_layer_init, "vlm": dense_layer_init,
                          "audio": dense_layer_init}[cfg.family]
            lkeys = jax.random.split(klayers, cfg.n_layers)
            params["layers"] = stack_layer_params(
                [layer_init(k, cfg) for k in lkeys])
        return params

    def _group_init(self, key) -> dict:
        ks = jax.random.split(key, 3)
        cfg = self.cfg
        return {
            "rec1": rec_layer_init(ks[0], cfg),
            "rec2": rec_layer_init(ks[1], cfg),
            "attn": dense_layer_init(ks[2], cfg),
        }

    # -- train forward ------------------------------------------------------
    def _embed_inputs(self, params, batch):
        """Token (+ optional modality-prefix) embedding -> (x, positions)."""
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        if cfg.n_prefix_embeds:
            pre = batch["prefix_embeds"].astype(x.dtype)  # (B, P, D) stub frontend
            x = jnp.concatenate([pre, x], axis=1)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        return x, positions

    def forward(self, params, batch):
        """(B, S) tokens -> (B, S_total, vocab_pad) logits, aux loss."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x, aux = self._run_stack(params, x, positions)
        x = L.rmsnorm(params["final_ln"].value, x, cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg.tie_embeddings)
        return logits, aux

    def _run_stack(self, params, x, positions):
        cfg = self.cfg
        if cfg.seq_shard_resid and shardctx.mesh() is not None:
            # §Perf: residual stream seq-sharded over `model` between blocks;
            # the partitioner then gathers whichever side (weights vs
            # activations) is cheaper per einsum — audited via the HLO.
            from jax.sharding import PartitionSpec as P
            ba = shardctx.batch_axes()
            if x.shape[1] % shardctx.mesh().shape["model"] == 0:
                x = shardctx.constrain(x, P(ba, "model", None))
        if cfg.family == "hybrid":
            def group_body(lp, x):
                x, a1 = rec_layer_train(lp["rec1"], x, positions, cfg)
                x, a2 = rec_layer_train(lp["rec2"], x, positions, cfg)
                x, a3 = dense_layer_train(lp["attn"], x, positions, cfg,
                                          window=cfg.local_window)
                return x, a1 + a2 + a3
            x, aux = _scan_stack(params["groups"], x,
                                 _maybe_remat(group_body, cfg),
                                 unroll=not cfg.scan_layers)
            if "tail" in params:
                def tail_body(lp, x):
                    return rec_layer_train(lp, x, positions, cfg)
                x, aux2 = _scan_stack(params["tail"], x,
                                      _maybe_remat(tail_body, cfg),
                                      unroll=not cfg.scan_layers)
                aux = aux + aux2
        else:
            body_fn = {
                "dense": lambda lp, x: dense_layer_train(lp, x, positions, cfg,
                                                         window=_attn_window(cfg)),
                "vlm": lambda lp, x: dense_layer_train(lp, x, positions, cfg),
                "audio": lambda lp, x: dense_layer_train(lp, x, positions, cfg),
                "moe": lambda lp, x: moe_layer_train(lp, x, positions, cfg),
                "ssm": lambda lp, x: ssm_layer_train(lp, x, positions, cfg),
            }[cfg.family]
            x, aux = _scan_stack(params["layers"], x, _maybe_remat(body_fn, cfg),
                                 unroll=not cfg.scan_layers)
        return x, aux

    def loss_fn(self, params, batch):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.n_prefix_embeds:  # loss only on the text suffix
            logits = logits[:, cfg.n_prefix_embeds:, :]
        loss = L.xent_loss(logits, batch["labels"], cfg.vocab_size)
        total = loss + aux
        return total, {"loss": loss, "aux_loss": aux}

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch: int, slots: int, dtype) -> Any:
        cfg = self.cfg
        hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads

        def kv_slots(window):
            return min(slots, window) if window else slots

        if cfg.family == "ssm":
            st = S.ssm_init_state(cfg, batch, dtype)
            return {"layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), st),
                "pos": jnp.zeros((batch,), jnp.int32)}
        if cfg.family == "hybrid":
            n_groups, tail = divmod(cfg.n_layers, 3)
            w = kv_slots(cfg.local_window)
            rec = R.rglru_init_state(cfg, batch, dtype)
            group = {
                "rec1": rec, "rec2": jax.tree.map(jnp.copy, rec),
                "k": jnp.zeros((batch, w, kv, hd), dtype),
                "v": jnp.zeros((batch, w, kv, hd), dtype),
            }
            cache = {"groups": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), group)}
            if tail:
                cache["tail"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (tail,) + a.shape), rec)
            cache["pos"] = jnp.zeros((batch,), jnp.int32)
            return cache
        w = kv_slots(cfg.sliding_window)
        kv_dt = jnp.int8 if cfg.kv_cache_int8 else dtype
        cache = {
            "k": jnp.zeros((cfg.n_layers, batch, w, kv, hd), kv_dt),
            "v": jnp.zeros((cfg.n_layers, batch, w, kv, hd), kv_dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
        if cfg.kv_cache_int8:
            cache["k_scale"] = jnp.zeros((cfg.n_layers, batch, w, kv, 1), F32)
            cache["v_scale"] = jnp.zeros((cfg.n_layers, batch, w, kv, 1), F32)
        if cfg.kv_block_prune:
            nb = w // cfg.kv_block_size
            # zone-map "+infinity": dtype-derived so it survives bf16 casts
            big = jnp.asarray(numerics.finite_max(jnp.bfloat16), F32)
            cache["kmin"] = jnp.full((cfg.n_layers, batch, nb, kv, hd), big, F32)
            cache["kmax"] = jnp.full((cfg.n_layers, batch, nb, kv, hd), -big, F32)
        return cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1) int32; pos: (B,) absolute positions."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        unroll = not cfg.scan_layers
        if cfg.family == "ssm":
            def body(lp, st, x):
                xn = L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps)
                y, st2 = S.ssm_decode(lp["ssm"], xn, st, cfg)
                return x + y, st2
            x, new_states = _scan_with_cache(params["layers"], cache["layers"],
                                             x, body, unroll)
            new_cache = {"layers": new_states, "pos": pos + 1}
        elif cfg.family == "hybrid":
            def rec_dec(lp, x, st):
                xn = L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps)
                y, st2 = R.rglru_decode(lp["rec"], xn, st, cfg)
                x = x + y
                x = x + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"].value, x, cfg.norm_eps))
                return x, st2

            def attn_dec(lp, x, k, v):
                xn = L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps)
                y, k2, v2, _ = L.mha_decode(lp["attn"], xn, pos, k, v, cfg,
                                            window=cfg.local_window)
                x = x + y
                x = x + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"].value, x, cfg.norm_eps))
                return x, k2, v2

            def gbody(lp, st, x):
                x, s1 = rec_dec(lp["rec1"], x, st["rec1"])
                x, s2 = rec_dec(lp["rec2"], x, st["rec2"])
                x, k2, v2 = attn_dec(lp["attn"], x, st["k"], st["v"])
                return x, {"rec1": s1, "rec2": s2, "k": k2, "v": v2}

            x, new_groups = _scan_with_cache(params["groups"], cache["groups"],
                                             x, gbody, unroll)
            new_cache = {"groups": new_groups, "pos": pos + 1}
            if "tail" in params:
                def tbody(lp, st, x):
                    return rec_dec(lp, x, st)
                x, new_tail = _scan_with_cache(params["tail"], cache["tail"],
                                               x, tbody, unroll)
                new_cache["tail"] = new_tail
        else:
            window = _attn_window(cfg)

            extra_keys = [k for k in ("k_scale", "v_scale", "kmin", "kmax")
                          if k in cache]

            def body(lp, cs, x):
                xn = L.rmsnorm(lp["ln1"].value, x, cfg.norm_eps)
                y, k2, v2, ex2 = L.mha_decode(
                    lp["attn"], xn, pos, cs["k"], cs["v"], cfg, window=window,
                    extras={k: cs[k] for k in extra_keys})
                x = x + y
                xn2 = L.rmsnorm(lp["ln2"].value, x, cfg.norm_eps)
                if cfg.family == "moe":
                    y2, _ = M.moe_apply(lp["moe"], xn2, cfg)
                else:
                    y2 = L.mlp(lp["mlp"], xn2)
                out_cs = {"k": k2, "v": v2}
                out_cs.update({k: ex2[k] for k in extra_keys})
                return x + y2, out_cs

            layer_cache = {k: cache[k] for k in ["k", "v"] + extra_keys}
            x, ncache = _scan_with_cache(params["layers"], layer_cache,
                                         x, body, unroll)
            new_cache = dict(ncache)
            new_cache["pos"] = pos + 1

        x = L.rmsnorm(params["final_ln"].value, x, cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg.tie_embeddings)
        return logits, new_cache

    def prefill(self, params, batch):
        """Inference forward over the full prompt -> (last-token logits, aux).

        cfg.prefill_last_only (§Perf): unembed ONLY the final position — the
        (B, S, vocab) logits tensor (and its flops) never exist. The baseline
        path computes full logits then slices, which XLA does not narrow.
        """
        cfg = self.cfg
        if not cfg.prefill_last_only:
            logits, aux = self.forward(params, batch)
            return logits[:, -1:, :], aux
        x, positions = self._embed_inputs(params, batch)
        x, aux = self._run_stack(params, x, positions)
        x = L.rmsnorm(params["final_ln"].value, x[:, -1:, :], cfg.norm_eps)
        return L.unembed(params["embed"], x, cfg.tie_embeddings), aux
