"""Metrics registry: named counters / gauges / fixed-bucket histograms.

The measurement substrate of the observability layer (DESIGN.md §10). Three
metric kinds, all plain host-side Python (never inside jit):

  * ``Counter``   — monotone event counts. The kernel launch / host-sync
    accounting in ``kernels.ops`` is a *client* of this registry (family
    ``mdrq_launches_total{op=...}``), not a separate global: every budget a
    test asserts and every span's launch attribution read the same numbers.
  * ``Gauge``     — last-write-wins instantaneous values.
  * ``Histogram`` — fixed log-spaced buckets with cumulative counts, the
    Prometheus histogram shape. Percentiles (p50/p95/p99 of serving latency)
    interpolate within the containing bucket, so their error is bounded by
    one bucket ratio (``LATENCY_BUCKET_RATIO``) — cheap enough to record on
    every flush, honest enough for the ``ServerStats`` report.

Metrics are keyed by (name, sorted label items): ``registry().counter("x",
op="scan")`` and ``op="tree"`` are two series of one family, exactly the
Prometheus data model, so the text exporter is a straight serialization.

Exporters: ``to_jsonl()`` (one JSON object per line — machine-readable, the
``BENCH_*.json`` trajectory and any log shipper parse it back) and
``to_prometheus()`` (the text exposition format).

This module imports nothing from the rest of ``repro`` — it is the leaf the
kernel layer, the engine, and the server all hang their instruments on.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Iterable, Optional

_LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, str]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclasses.dataclass
class Counter:
    """Monotone event counter."""

    name: str
    labels: dict[str, str]
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n

    def reset(self) -> None:
        self.value = 0.0


@dataclasses.dataclass
class Gauge:
    """Last-write-wins instantaneous value."""

    name: str
    labels: dict[str, str]
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0


# Default latency buckets: log-spaced from 1us to ~2 minutes. The ratio is
# the percentile error bound — within-bucket interpolation can never be off
# by more than one bucket, so p50/p95/p99 are exact to ~1.35x.
LATENCY_BUCKET_RATIO = 1.35
LATENCY_BUCKETS: tuple[float, ...] = tuple(
    1e-6 * LATENCY_BUCKET_RATIO ** k for k in range(62))


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``bounds[i]`` is the inclusive upper edge of bucket i; observations above
    the last edge land in the +Inf overflow bucket. ``sum``/``count``/``min``
    /``max`` ride along so means and exact extremes survive the bucketing.
    """

    __slots__ = ("name", "labels", "bounds", "counts", "count", "sum",
                 "_min", "_max")

    def __init__(self, name: str, labels: dict[str, str],
                 bounds: Optional[Iterable[float]] = None):
        self.name = name
        self.labels = labels
        self.bounds = tuple(bounds) if bounds is not None else LATENCY_BUCKETS
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError("histogram bucket bounds must be sorted")
        self.counts = [0] * (len(self.bounds) + 1)  # +1: +Inf overflow
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, x: float) -> None:
        x = float(x)
        # binary search: bisect over the sorted edges
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if x <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.sum += x
        self._min = min(self._min, x)
        self._max = max(self._max, x)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Estimated p-th percentile (0 < p <= 100), interpolated within the
        containing bucket and clamped to the observed [min, max]."""
        if not 0.0 < p <= 100.0:
            raise ValueError(f"percentile must be in (0, 100], got {p}")
        if self.count == 0:
            return float("nan")
        target = p / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo_edge = self.bounds[i - 1] if i > 0 else 0.0
                hi_edge = self.bounds[i] if i < len(self.bounds) else self._max
                frac = (target - cum) / c
                est = lo_edge + frac * (hi_edge - lo_edge)
                return min(max(est, self._min), self._max)
            cum += c
        return self._max

    def percentiles(self, ps: Iterable[float] = (50, 95, 99)
                    ) -> dict[str, float]:
        """{"p50": ..., "p95": ..., "p99": ...} — the ServerStats report."""
        return {f"p{g:g}": self.percentile(g) for g in ps}

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf


class MetricsRegistry:
    """Name+labels -> metric store with JSONL / Prometheus exporters.

    ``counter``/``gauge``/``histogram`` get-or-create: hot paths hold the
    returned object (one dict lookup per lookup, zero per increment).
    ``reset()`` zeroes values but keeps the objects, so cached references in
    ``kernels.ops`` and long-lived spans stay live across test resets.
    """

    def __init__(self):
        self._metrics: dict[tuple[str, _LabelKey], object] = {}
        self._help: dict[str, str] = {}

    def _get(self, cls, name: str, labels: dict[str, str], **kw):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name=name, labels=dict(labels), **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r}{labels} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        if help:
            self._help.setdefault(name, help)
        return self._get(Counter, name, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        if help:
            self._help.setdefault(name, help)
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, help: str = "",
                  bounds: Optional[Iterable[float]] = None,
                  **labels) -> Histogram:
        if help:
            self._help.setdefault(name, help)
        return self._get(Histogram, name, labels, bounds=bounds)

    # -- introspection -----------------------------------------------------
    def series(self, name: str) -> list:
        """All metrics of one family (every label combination), in
        registration order."""
        return [m for (n, _), m in self._metrics.items() if n == name]

    def family_total(self, name: str) -> float:
        """Summed value of a counter/gauge family across all label sets."""
        return float(sum(m.value for m in self.series(name)))

    def counter_values(self, name: str, label: str) -> dict[str, float]:
        """{label value -> count} for one counter family keyed by ``label``
        (e.g. per-op launch counts) — the span layer's attribution source.

        Zero-valued series are omitted (matching ``kernels.ops.counters``):
        ``reset()`` keeps counter objects alive so cached references stay
        live, and a series another code path touched before the reset should
        not reappear here as a spurious ``0.0`` entry.
        """
        return {m.labels.get(label, ""): m.value
                for m in self.series(name) if m.value}

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()

    # -- exporters ---------------------------------------------------------
    def snapshot(self) -> list[dict]:
        """One JSON-able dict per metric (the JSONL exporter's rows)."""
        rows = []
        for (name, _), m in self._metrics.items():
            row: dict = {"name": name, "labels": dict(m.labels)}
            if isinstance(m, Histogram):
                cum = 0
                buckets = []
                for edge, c in zip(self.bounds_of(m), m.counts):
                    cum += c
                    if c:  # sparse: only non-empty buckets ship
                        buckets.append([edge, cum])
                row.update(type="histogram", count=m.count, sum=m.sum,
                           buckets=buckets, **m.percentiles())
            else:
                row.update(
                    type="counter" if isinstance(m, Counter) else "gauge",
                    value=m.value)
            rows.append(row)
        return rows

    @staticmethod
    def bounds_of(h: Histogram) -> list[float]:
        return list(h.bounds) + [math.inf]

    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(r) for r in self.snapshot())

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one family header, then every
        labeled series; histograms as _bucket/_sum/_count)."""
        out: list[str] = []
        seen: set[str] = set()
        for (name, _), m in self._metrics.items():
            if name not in seen:
                seen.add(name)
                kind = ("histogram" if isinstance(m, Histogram)
                        else "counter" if isinstance(m, Counter) else "gauge")
                if name in self._help:
                    out.append(f"# HELP {name} {self._help[name]}")
                out.append(f"# TYPE {name} {kind}")
            for line in _prom_lines(name, m):
                out.append(line)
        return "\n".join(out) + "\n"


def _prom_labels(labels: dict[str, str], extra: str = "") -> str:
    items = [f'{k}="{v}"' for k, v in sorted(labels.items())]
    if extra:
        items.append(extra)
    return "{" + ",".join(items) + "}" if items else ""


def _prom_num(x: float) -> str:
    if x == math.inf:
        return "+Inf"
    return repr(int(x)) if float(x).is_integer() and abs(x) < 1e15 else repr(x)


def _prom_lines(name: str, m) -> list[str]:
    if isinstance(m, Histogram):
        lines = []
        cum = 0
        for edge, c in zip(MetricsRegistry.bounds_of(m), m.counts):
            cum += c
            if c or edge == math.inf:  # sparse buckets; always emit +Inf
                le = _prom_labels(m.labels, f'le="{_prom_num(edge)}"')
                lines.append(f"{name}_bucket{le} {cum}")
        lab = _prom_labels(m.labels)
        lines.append(f"{name}_sum{lab} {_prom_num(m.sum)}")
        lines.append(f"{name}_count{lab} {m.count}")
        return lines
    return [f"{name}{_prom_labels(m.labels)} {_prom_num(m.value)}"]


# The process-wide default registry. Everything in-tree records here; tests
# reset it per test via the autouse fixture in tests/conftest.py.
_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY
