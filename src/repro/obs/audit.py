"""Drift auditing: estimated vs observed selectivity/cost from query traces.

The planner's break-even machinery is only as good as its inputs — the
independence-assumption histograms and the calibrated machine constants —
and both drift: data distributions shift under ingest, and the constants
were fitted on some other machine (or never fitted at all). The paper's
analytic-model lineage (arxiv 1609.01319) is explicit that a cost model
needs a measured feedback loop; this module is that loop, fed from
*production traces* rather than dedicated benchmarks.

``audit(traces)`` buckets ``QueryTrace`` records into (access path x
estimated-selectivity decile) cells and compares, per cell, the planner's
estimates against what actually happened: mean estimated vs observed
selectivity (where the result shape makes the realized match fraction
derivable — ids/count/mask), and mean estimated cost vs measured seconds.
Cells whose observed/estimated selectivity ratio leaves the tolerance band
are flagged ``drifted`` — a skewed histogram shows up as a run of drifted
cells on one path before it ever mis-routes enough queries to notice in a
benchmark.

``calibration_samples(traces, model)`` turns the same traces into the
``(method, modeled_bytes, measured_seconds)`` triples ``Planner.calibrate``
fits machine constants from — so miscalibration detected by the audit is
*repaired* through the existing ``CalibrationReport`` plumbing, closing the
loop: trace -> audit -> calibrate -> better plans.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

from repro.obs.tracing import BatchTrace, QueryTrace


def _flatten(traces) -> list[QueryTrace]:
    if isinstance(traces, (BatchTrace, QueryTrace)):
        traces = [traces]
    out: list[QueryTrace] = []
    for t in traces:
        if isinstance(t, BatchTrace):
            out.extend(t.queries)
        elif isinstance(t, QueryTrace):
            out.append(t)
        else:
            raise TypeError(f"expected QueryTrace/BatchTrace, got {type(t)}")
    return out


def _decile(sel: float) -> int:
    """Estimated-selectivity decile 0..9 (decile 0 = [0, 0.1), ... )."""
    return min(9, max(0, int(sel * 10.0)))


@dataclasses.dataclass
class AuditCell:
    """One (path x estimated-selectivity decile) aggregation cell."""

    method: str
    decile: int                    # of the *estimated* selectivity
    n_queries: int
    n_observed: int                # queries with a derivable observed sel
    mean_est_sel: float
    mean_obs_sel: float            # NaN when nothing was derivable
    sel_ratio: float               # observed / estimated (NaN if unobserved)
    mean_est_cost: float           # planner seconds (NaN for explicit runs)
    mean_seconds: float            # measured per-query seconds
    cost_ratio: float              # measured / estimated (NaN if unplanned)
    drifted: bool

    def __str__(self) -> str:
        flag = " DRIFT" if self.drifted else ""
        return (f"{self.method:>14s} d{self.decile} n={self.n_queries:<5d} "
                f"sel est={self.mean_est_sel:.3e} obs={self.mean_obs_sel:.3e} "
                f"(x{self.sel_ratio:.2f})  cost est={self.mean_est_cost:.3e}s "
                f"meas={self.mean_seconds:.3e}s (x{self.cost_ratio:.2f})"
                f"{flag}")


@dataclasses.dataclass
class DriftReport:
    """Outcome of one audit pass over a trace set."""

    cells: list[AuditCell]
    n_traces: int
    n_unobserved: int              # traces without a derivable observed sel
    sel_tolerance: float
    cost_tolerance: Optional[float]

    @property
    def drifted(self) -> list[AuditCell]:
        return [c for c in self.cells if c.drifted]

    @property
    def ok(self) -> bool:
        return not self.drifted

    def summary(self) -> str:
        head = (f"drift audit: {self.n_traces} traces, {len(self.cells)} "
                f"(path x sel-decile) cells, {len(self.drifted)} drifted "
                f"(sel tolerance x{self.sel_tolerance:g})")
        return "\n".join([head] + [f"  {c}" for c in self.cells])


def audit(traces: Iterable, sel_tolerance: float = 4.0,
          cost_tolerance: Optional[float] = None,
          min_queries: int = 1) -> DriftReport:
    """Aggregate traces into (path x sel-decile) cells and flag drift.

    A cell drifts when its mean observed selectivity is more than
    ``sel_tolerance``x off the mean estimate (either direction), or — when
    ``cost_tolerance`` is given — when measured seconds leave the analogous
    band around the planner's cost estimate (off by default: absolute CPU
    wall time vs the TPU-roofline model is a calibration question, which is
    what ``calibration_samples`` + ``Planner.calibrate`` are for). Cells
    with fewer than ``min_queries`` observed queries are reported but never
    flagged (one noisy query is not drift).
    """
    flat = _flatten(traces)
    groups: dict[tuple[str, int], list[QueryTrace]] = {}
    n_unobserved = 0
    for t in flat:
        groups.setdefault((t.method, _decile(t.est_selectivity)), []).append(t)
        if t.obs_selectivity is None:
            n_unobserved += 1

    cells = []
    for (method, dec), ts in sorted(groups.items()):
        obs = [t for t in ts if t.obs_selectivity is not None]
        est_sel = sum(t.est_selectivity for t in ts) / len(ts)
        obs_sel = (sum(t.obs_selectivity for t in obs) / len(obs)
                   if obs else math.nan)
        # ratio on floored estimates: est_sel is already clamped >= 1/n by
        # the histograms, but guard anyway (a zero estimate must read as
        # "infinitely drifted", not a ZeroDivisionError)
        sel_ratio = (obs_sel / est_sel if est_sel > 0 else math.inf) \
            if obs else math.nan
        planned = [t for t in ts if not math.isnan(t.est_cost)]
        est_cost = (sum(t.est_cost for t in planned) / len(planned)
                    if planned else math.nan)
        seconds = sum(t.seconds for t in ts) / len(ts)
        cost_ratio = (seconds / est_cost if est_cost and est_cost > 0
                      else math.nan) if planned else math.nan
        drifted = False
        if len(obs) >= min_queries and not math.isnan(sel_ratio):
            drifted = not (1.0 / sel_tolerance <= sel_ratio <= sel_tolerance)
        if (not drifted and cost_tolerance is not None
                and len(planned) >= min_queries
                and not math.isnan(cost_ratio)):
            drifted = not (1.0 / cost_tolerance <= cost_ratio
                           <= cost_tolerance)
        cells.append(AuditCell(
            method=method, decile=dec, n_queries=len(ts), n_observed=len(obs),
            mean_est_sel=est_sel, mean_obs_sel=obs_sel, sel_ratio=sel_ratio,
            mean_est_cost=est_cost, mean_seconds=seconds,
            cost_ratio=cost_ratio, drifted=drifted))
    return DriftReport(cells=cells, n_traces=len(flat),
                       n_unobserved=n_unobserved,
                       sel_tolerance=sel_tolerance,
                       cost_tolerance=cost_tolerance)


def calibration_samples(traces: Iterable, model
                        ) -> list[tuple[str, float, float]]:
    """Traces -> ``Planner.calibrate`` samples, closing the feedback loop.

    Each trace contributes ``(method, modeled_bytes, measured_seconds)``:
    the bytes the cost model says that query's execution moved (per query,
    under its realized bucket amortization — ``CostModel.modeled_bytes``)
    against the seconds the trace actually measured for it. Feeding the
    result to ``Planner.calibrate`` refits ``sec_per_byte`` /
    ``dispatch_overhead`` from production traffic, and the returned
    ``CalibrationReport`` says which constants the fit repaired.

    Selectivity-dependent paths use the *observed* selectivity where the
    trace has one (that is the whole point: the estimate may be the thing
    that drifted) and fall back to the estimate otherwise.
    """
    samples = []
    for t in _flatten(traces):
        sel = t.obs_selectivity if t.obs_selectivity is not None \
            else t.est_selectivity
        nbytes = model.modeled_bytes(t.method, sel=sel, mq=t.mq,
                                     bucket=t.bucket_size)
        if nbytes is not None:
            samples.append((t.method, float(nbytes), float(t.seconds)))
    return samples
