"""repro.obs — the observability subsystem (DESIGN.md §10).

Four pieces, layered bottom-up:

  * ``obs.metrics``  — counters / gauges / fixed-bucket histograms in one
    registry with JSONL + Prometheus exporters. The kernel layer's
    launch/host-sync counters are one backend of this registry.
  * ``obs.tracing``  — the span API (``obs.span("kernel", path=...)``) with
    device-sync-aware close, plus the ``QueryTrace``/``BatchTrace`` records
    ``MDRQEngine.query_batch(..., trace=True)`` emits.
  * ``obs.querylog`` — the bounded reservoir-sampled query log
    ``MDRQServer`` keeps (the learned-path training input).
  * ``obs.audit``    — estimated-vs-observed drift report per (path x
    selectivity-decile) cell, and the bridge from traces to
    ``Planner.calibrate``.

Import as ``from repro import obs`` and use ``obs.span`` / ``obs.registry``
/ ``obs.audit`` directly; the submodules stay importable for the full
surface. This package never imports engine/kernel code at module level —
it is the leaf everything else instruments itself with.
"""
from repro.obs.audit import (AuditCell, DriftReport, audit,
                             calibration_samples)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               registry)
from repro.obs.querylog import QueryLog, QueryLogEntry
from repro.obs.tracing import (NULL_SPAN, BatchTrace, QueryTrace, Span,
                               Tracer, enabled, span)

__all__ = [
    "AuditCell", "DriftReport", "audit", "calibration_samples",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
    "QueryLog", "QueryLogEntry",
    "NULL_SPAN", "BatchTrace", "QueryTrace", "Span", "Tracer", "enabled",
    "span",
]
