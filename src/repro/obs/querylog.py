"""Bounded, reservoir-sampled query log — the workload sample a learned
access path trains on.

Flood (arxiv 1912.01668) learns its grid layout from the query workload;
``MDRQServer`` keeps exactly that input here: a fixed-capacity uniform
sample over everything ever served (classic reservoir sampling, so the
memory bound holds under unbounded traffic while every query keeps an equal
chance of being retained). Entries also record *how* each query was served —
chosen path, realized result size, queue/execute latency, and which trigger
flushed its batch — so the log doubles as the drift audit's raw material and
distinguishes deadline (idle-stream) flushes from size-triggered ones.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class QueryLogEntry:
    """One served query, as the workload-learning and audit layers see it."""

    lower: np.ndarray              # (m,) query bounds
    upper: np.ndarray
    spec_kind: str                 # result shape served
    method: str                    # access path that executed it
    result_size: int               # realized result magnitude
    queue_seconds: float           # submit -> flush start
    execute_seconds: float         # its batch's execution wall time
    flush_reason: str              # "size" | "deadline" | "forced"
    batch_size: int                # queries co-flushed with it


class QueryLog:
    """Fixed-capacity uniform reservoir over served queries.

    ``offer`` is O(1); after ``n_seen > capacity`` each new entry replaces a
    uniformly random slot with probability ``capacity / n_seen`` — the
    standard reservoir invariant, so ``entries`` is always a uniform sample
    of everything offered. Seeded for reproducibility.
    """

    def __init__(self, capacity: int = 512, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.entries: list[QueryLogEntry] = []
        self.n_seen = 0
        self._rng = random.Random(seed)

    def offer(self, entry: QueryLogEntry) -> bool:
        """Offer one entry; returns True when it was retained."""
        self.n_seen += 1
        if len(self.entries) < self.capacity:
            self.entries.append(entry)
            return True
        j = self._rng.randrange(self.n_seen)
        if j < self.capacity:
            self.entries[j] = entry
            return True
        return False

    def __len__(self) -> int:
        return len(self.entries)

    def by_reason(self, reason: str) -> list[QueryLogEntry]:
        """Entries whose batch was flushed by ``reason`` — e.g. the idle-
        stream ``"deadline"`` flushes, distinguishable from ``"size"``."""
        return [e for e in self.entries if e.flush_reason == reason]

    def bounds(self) -> Optional[tuple[np.ndarray, np.ndarray]]:
        """Stacked (S, m) lower/upper bounds of the sample — the tensor a
        layout learner consumes. None while empty."""
        if not self.entries:
            return None
        return (np.stack([e.lower for e in self.entries]),
                np.stack([e.upper for e in self.entries]))
