"""Query-path tracing: lightweight spans + per-query ``QueryTrace`` records.

The span API is deliberately tiny (DESIGN.md §10):

    with obs.span("plan"):
        ...
    with obs.span("kernel", path="scan") as sp:
        out = launch(...)
        sp.block_on(out)          # device-sync-aware close

Spans are **host-side** objects — they never enter jit. A span wrapping a
kernel launch would otherwise stop its clock at *dispatch* (jax is async):
``Span.block_on`` registers device values and the close blocks on them
(``jax.block_until_ready``), so a kernel span measures device time, not how
fast Python returned. This is also why spans must not be opened *inside*
jit-traced Python: that code runs once at trace time and never again, so the
span would time tracing, not execution ("no trace-time capture"). Wrap the
jitted call, never the jitted body.

Cost when disabled is one module-global load and an ``is None`` check:
``span(...)`` returns the shared ``NULL_SPAN`` singleton — no object is
allocated on the hot path, which is what keeps ``trace=False`` execution at
zero overhead.

Launch/host-sync attribution: every span snapshots the metrics registry's
``mdrq_launches_total`` family at open and close (the same counters
``kernels.ops`` bumps and tests assert budgets on), so a span knows exactly
how many kernel launches and host syncs happened under it — wall-clock
measurements on CPU cannot see either.

``QueryTrace``/``BatchTrace`` are the records ``MDRQEngine.query_batch(...,
trace=True)`` produces: per query, the planner's chosen path, realized
bucket, estimated selectivity and cost, the realized result count (and the
observed selectivity where the spec makes it derivable), plus the bucket's
measured seconds / launches / host syncs. The drift audit (``obs.audit``)
and Flood-style layout learning both consume exactly these records.
"""
from __future__ import annotations

import dataclasses
import threading as _threading
import time
from typing import Any, Optional

from repro.obs import metrics as _metrics

# The one counter family the kernel layer bumps (see kernels/ops.py); the
# device->host sync pseudo-op lives in the same family under this op label.
LAUNCH_FAMILY = "mdrq_launches_total"
HOST_SYNC_OP = "host_sync"


def _launch_snapshot() -> tuple[float, float]:
    """(kernel launches, host syncs) since process start, from the registry."""
    launches = 0.0
    syncs = 0.0
    for m in _metrics.registry().series(LAUNCH_FAMILY):
        if m.labels.get("op") == HOST_SYNC_OP:
            syncs += m.value
        else:
            launches += m.value
    return launches, syncs


class Span:
    """One timed region. Context manager; closes device-sync-aware."""

    __slots__ = ("name", "attrs", "seconds", "children", "launches",
                 "host_syncs", "_tracer", "_t0", "_c0", "_pending")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.seconds = 0.0
        self.children: list[Span] = []
        self.launches = 0
        self.host_syncs = 0
        self._tracer = tracer
        self._t0 = 0.0
        self._c0 = (0.0, 0.0)
        self._pending: list = []

    def set(self, **attrs) -> "Span":
        """Attach attributes after open (result counts, bucket sizes, ...)."""
        self.attrs.update(attrs)
        return self

    def block_on(self, x) -> None:
        """Register a device value the span close must block on, so the span
        measures device completion rather than async dispatch."""
        self._pending.append(x)

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self._c0 = _launch_snapshot()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._pending:
            import jax
            jax.block_until_ready(self._pending)
            self._pending = []
        self.seconds = time.perf_counter() - self._t0
        c1 = _launch_snapshot()
        self.launches = int(c1[0] - self._c0[0])
        self.host_syncs = int(c1[1] - self._c0[1])
        self._tracer._pop(self)

    def find(self, name: str) -> list["Span"]:
        """All descendant spans (and self) with the given name, pre-order."""
        out = [self] if self.name == name else []
        for c in self.children:
            out.extend(c.find(name))
        return out

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.seconds * 1e6:.0f}us, "
                f"launches={self.launches}, host_syncs={self.host_syncs}, "
                f"attrs={self.attrs})")


class _NullSpan:
    """The disabled-tracing singleton: every method is a no-op. ``span()``
    returns this exact object when no tracer is active, so the hot path
    allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def set(self, **attrs) -> "_NullSpan":
        return self

    def block_on(self, x) -> None:
        return None


NULL_SPAN = _NullSpan()

# The active tracer, *per thread*. The pipelined server (DESIGN.md §13) runs
# a dedicated finalizer thread; a process-global tracer would let that
# thread's spans interleave into the admission thread's span stack and
# corrupt the tree. Thread-local means: a Tracer installed on one thread
# sees exactly that thread's spans; other threads' span() calls return
# NULL_SPAN. (An async server would swap this for a contextvar.)
_TLS = _threading.local()


def enabled() -> bool:
    return getattr(_TLS, "tracer", None) is not None


def span(name: str, **attrs):
    """Open a span under the calling thread's active tracer, or the no-op
    singleton when tracing is disabled on this thread."""
    t = getattr(_TLS, "tracer", None)
    if t is None:
        return NULL_SPAN
    return Span(t, name, attrs)


def current() -> Optional["Tracer"]:
    return getattr(_TLS, "tracer", None)


class Tracer:
    """Collects a span tree. ``with Tracer() as t:`` installs it as the
    active tracer (nesting restores the previous one on exit)."""

    def __init__(self):
        self.spans: list[Span] = []   # root spans, in open order
        self._stack: list[Span] = []
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> "Tracer":
        self._prev = getattr(_TLS, "tracer", None)
        _TLS.tracer = self
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        _TLS.tracer = self._prev
        self._prev = None

    def _push(self, s: Span) -> None:
        (self._stack[-1].children if self._stack else self.spans).append(s)
        self._stack.append(s)

    def _pop(self, s: Span) -> None:
        if self._stack and self._stack[-1] is s:
            self._stack.pop()

    def find(self, name: str) -> list[Span]:
        out = []
        for s in self.spans:
            out.extend(s.find(name))
        return out


# =============================================================================
# Query-trace records (what the engine emits under trace=True)
# =============================================================================

@dataclasses.dataclass(slots=True)
class QueryTrace:
    """One query's observed execution, planner estimates included.

    ``seconds``/``launches``/``host_syncs`` are the query's *amortized share*
    of its fused launch bucket (bucket totals divided by ``bucket_size``) —
    the same amortization the cost model prices, so estimated and measured
    costs are directly comparable. ``obs_selectivity`` is the realized
    match fraction where the result shape makes it derivable (ids / count /
    mask), else None.
    """

    index: int                     # position in the submitted batch
    method: str                    # access path executed
    bucket_size: int               # realized fused-launch bucket
    est_selectivity: float         # planner estimate (histograms)
    est_cost: float                # planner cost estimate, seconds (NaN when
    #                                the method was explicit, not planned)
    spec_kind: str                 # result shape served
    mq: int                        # constrained dims (audit's bytes model)
    result_size: int               # realized result magnitude (spec-typed)
    obs_selectivity: Optional[float]
    seconds: float                 # measured wall share of the bucket
    launches: float                # kernel launches / bucket_size
    host_syncs: float              # host syncs / bucket_size


@dataclasses.dataclass
class BatchTrace:
    """One ``query_batch(trace=True)`` execution: per-query records plus the
    batch-level plan/execute breakdown and the raw span tree."""

    n: int                         # dataset objects (obs selectivity divisor)
    n_queries: int
    spec_kind: str
    plan_seconds: float
    seconds: float
    queries: list[QueryTrace]
    spans: list[Span]

    def by_method(self) -> dict[str, list[QueryTrace]]:
        out: dict[str, list[QueryTrace]] = {}
        for t in self.queries:
            out.setdefault(t.method, []).append(t)
        return out
