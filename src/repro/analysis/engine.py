"""mdrqlint rule engine: findings, suppressions, baseline, project runner.

The engine is deliberately tiny and dependency-free (stdlib ``ast`` only):
rules receive a parsed ``FileContext`` and return ``Finding`` records; the
runner splits them into *active* / *suppressed* (a ``# mdrqlint: disable=``
comment on the finding's line, comma-separated for multiple rules) /
*baselined* (listed in the checked-in ``baseline.json`` — accepted legacy
debt, keyed by (file, rule, message) so entries survive unrelated line
drift).

v2 (whole-program): the runner parses every file first, builds one
``callgraph.CallGraph`` over the set, and hands each rule a ``FileContext``
carrying the shared ``ProjectContext`` — so rules can resolve imports,
aliases, counted-op registrations, and method receivers across module
boundaries instead of stopping at the file edge. Baseline entries that no
longer match any finding are *stale*: they fail the run (CI-enforced — a
stale entry is a fixed bug still wearing its waiver) until
``--prune-baseline`` drops them.

Exit codes: 0 clean; 1 findings or stale baseline entries; 2 parse errors.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Optional

from repro.analysis.callgraph import CallGraph

_SUPPRESS_RE = re.compile(r"#\s*mdrqlint:\s*disable=([\w,\- ]+)")

DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic: ``file:line rule message``."""

    file: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.file}:{self.line} {self.rule} {self.message}"

    def baseline_key(self) -> str:
        # line numbers excluded: baseline entries survive unrelated edits
        return f"{self.file}::{self.rule}::{self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ProjectContext:
    """The whole-program view shared by every rule in one run.

    ``graph`` is the project call graph (symbol tables, import/alias
    resolution, counted-op registry, class method resolution); ``cache`` is
    scratch space for project-wide analyses that should run once per run,
    not once per file (e.g. the cross-module taint fixpoint).
    """

    files: "list[FileContext]"
    graph: CallGraph
    cache: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FileContext:
    """One parsed source file, handed to every rule."""

    path: Path
    posix: str  # posix path string; rules scope themselves by substring
    text: str
    tree: ast.AST
    project: Optional[ProjectContext] = None

    @classmethod
    def parse(cls, path: Path) -> "FileContext":
        text = path.read_text()
        return cls(path=path, posix=path.as_posix(), text=text,
                   tree=ast.parse(text, filename=str(path)))

    def segment(self, node: ast.AST) -> str:
        return ast.get_source_segment(self.text, node) or ""

    @property
    def module(self) -> str:
        from repro.analysis.callgraph import module_name
        return module_name(self.path)


class Rule:
    """Base class: subclasses set ``rule_id`` and implement ``check``."""

    rule_id: str = ""
    doc: str = ""

    def check(self, ctx: FileContext) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST, message: str) -> Finding:
        return Finding(file=ctx.posix, line=getattr(node, "lineno", 0),
                       rule=self.rule_id, message=message)


def parse_suppressions(text: str) -> dict[int, set[str]]:
    """Map line number -> set of rule ids disabled on that line.

    ``# mdrqlint: disable=host-sync,sentinel`` disables both rules on the
    line; ``disable=all`` disables every rule.
    """
    out: dict[int, set[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def iter_py_files(paths: Iterable[Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(f for f in sorted(p.rglob("*.py"))
                         if "__pycache__" not in f.parts)
    return files


@dataclasses.dataclass
class Report:
    """Partitioned lint results for one run.

    ``errors`` are files the engine could not parse (exit code 2 — a broken
    tree is not a clean tree, and not a finding either); ``stale_baseline``
    are accepted-debt keys matching no current finding (exit code 1 until
    pruned — the debt is paid, drop the waiver).
    """

    active: list[Finding] = dataclasses.field(default_factory=list)
    suppressed: list[Finding] = dataclasses.field(default_factory=list)
    baselined: list[Finding] = dataclasses.field(default_factory=list)
    errors: list[Finding] = dataclasses.field(default_factory=list)
    stale_baseline: list[str] = dataclasses.field(default_factory=list)
    n_files: int = 0

    @property
    def exit_code(self) -> int:
        if self.errors:
            return 2
        return 1 if (self.active or self.stale_baseline) else 0

    def to_json(self) -> dict:
        return {
            "findings": [f.to_json() for f in self.active],
            "suppressed": [f.to_json() for f in self.suppressed],
            "baselined": [f.to_json() for f in self.baselined],
            "errors": [f.to_json() for f in self.errors],
            "stale_baseline": list(self.stale_baseline),
            "n_files": self.n_files,
        }

    def format(self) -> str:
        lines = [f.format() for f in self.errors]
        lines += [f.format() for f in self.active]
        for key in self.stale_baseline:
            lines.append(f"stale baseline entry (no matching finding — run "
                         f"--prune-baseline): {key}")
        lines.append(
            f"mdrqlint: {len(self.active)} finding(s) "
            f"({len(self.suppressed)} suppressed, "
            f"{len(self.baselined)} baselined, "
            f"{len(self.stale_baseline)} stale baseline entr(y/ies), "
            f"{len(self.errors)} parse error(s)) in {self.n_files} file(s)")
        return "\n".join(lines)


def load_baseline(path: Optional[Path] = None) -> set[str]:
    path = Path(path) if path is not None else DEFAULT_BASELINE
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("accepted", []))


def write_baseline(report: Report, path: Optional[Path] = None) -> Path:
    """Accept every current finding (active + baselined) as legacy debt."""
    path = Path(path) if path is not None else DEFAULT_BASELINE
    keys = sorted({f.baseline_key()
                   for f in report.active + report.baselined})
    path.write_text(json.dumps({"accepted": keys}, indent=2) + "\n")
    return path


def prune_baseline(report: Report, path: Optional[Path] = None) -> Path:
    """Drop stale baseline entries, keeping only keys that still match."""
    path = Path(path) if path is not None else DEFAULT_BASELINE
    keys = sorted({f.baseline_key() for f in report.baselined})
    path.write_text(json.dumps({"accepted": keys}, indent=2) + "\n")
    return path


def build_project(files: Iterable[Path]) -> tuple[ProjectContext,
                                                  list[Finding]]:
    """Parse every file once and build the shared whole-program context."""
    ctxs: list[FileContext] = []
    errors: list[Finding] = []
    for path in files:
        try:
            ctxs.append(FileContext.parse(path))
        except SyntaxError as e:
            errors.append(Finding(
                file=path.as_posix(), line=e.lineno or 0, rule="parse-error",
                message=f"could not parse: {e.msg}"))
    project = ProjectContext(
        files=ctxs, graph=CallGraph.build([(c.path, c.tree) for c in ctxs]))
    for ctx in ctxs:
        ctx.project = project
    return project, errors


def run(paths: Iterable[Path], rules: Iterable[Rule],
        baseline: Optional[set[str]] = None) -> Report:
    """Lint ``paths`` with ``rules``; partition findings against baseline."""
    baseline = baseline or set()
    report = Report()
    files = iter_py_files(paths)
    report.n_files = len(files)
    project, report.errors = build_project(files)
    matched_keys: set[str] = set()
    for ctx in project.files:
        suppressions = parse_suppressions(ctx.text)
        for rule in rules:
            for f in rule.check(ctx):
                disabled = suppressions.get(f.line, set())
                if f.rule in disabled or "all" in disabled:
                    report.suppressed.append(f)
                elif f.baseline_key() in baseline:
                    matched_keys.add(f.baseline_key())
                    report.baselined.append(f)
                else:
                    report.active.append(f)
    report.stale_baseline = sorted(baseline - matched_keys)
    report.active.sort()
    report.suppressed.sort()
    report.baselined.sort()
    report.errors.sort()
    return report
