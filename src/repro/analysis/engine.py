"""mdrqlint rule engine: findings, suppressions, baseline, file runner.

The engine is deliberately tiny and dependency-free (stdlib ``ast`` only):
rules receive a parsed ``FileContext`` and return ``Finding`` records; the
runner splits them into *active* / *suppressed* (a ``# mdrqlint: disable=``
comment on the finding's line) / *baselined* (listed in the checked-in
``baseline.json`` — accepted legacy debt, keyed by (file, rule, message) so
entries survive unrelated line drift).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Optional

_SUPPRESS_RE = re.compile(r"#\s*mdrqlint:\s*disable=([\w,\- ]+)")

DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic: ``file:line rule message``."""

    file: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.file}:{self.line} {self.rule} {self.message}"

    def baseline_key(self) -> str:
        # line numbers excluded: baseline entries survive unrelated edits
        return f"{self.file}::{self.rule}::{self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FileContext:
    """One parsed source file, handed to every rule."""

    path: Path
    posix: str  # posix path string; rules scope themselves by substring
    text: str
    tree: ast.AST

    @classmethod
    def parse(cls, path: Path) -> "FileContext":
        text = path.read_text()
        return cls(path=path, posix=path.as_posix(), text=text,
                   tree=ast.parse(text, filename=str(path)))

    def segment(self, node: ast.AST) -> str:
        return ast.get_source_segment(self.text, node) or ""


class Rule:
    """Base class: subclasses set ``rule_id`` and implement ``check``."""

    rule_id: str = ""
    doc: str = ""

    def check(self, ctx: FileContext) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST, message: str) -> Finding:
        return Finding(file=ctx.posix, line=getattr(node, "lineno", 0),
                       rule=self.rule_id, message=message)


def parse_suppressions(text: str) -> dict[int, set[str]]:
    """Map line number -> set of rule ids disabled on that line."""
    out: dict[int, set[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def iter_py_files(paths: Iterable[Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(f for f in sorted(p.rglob("*.py"))
                         if "__pycache__" not in f.parts)
    return files


@dataclasses.dataclass
class Report:
    """Partitioned lint results for one run."""

    active: list[Finding] = dataclasses.field(default_factory=list)
    suppressed: list[Finding] = dataclasses.field(default_factory=list)
    baselined: list[Finding] = dataclasses.field(default_factory=list)
    n_files: int = 0

    @property
    def exit_code(self) -> int:
        return 1 if self.active else 0

    def to_json(self) -> dict:
        return {
            "findings": [f.to_json() for f in self.active],
            "suppressed": [f.to_json() for f in self.suppressed],
            "baselined": [f.to_json() for f in self.baselined],
            "n_files": self.n_files,
        }

    def format(self) -> str:
        lines = [f.format() for f in self.active]
        lines.append(
            f"mdrqlint: {len(self.active)} finding(s) "
            f"({len(self.suppressed)} suppressed, "
            f"{len(self.baselined)} baselined) in {self.n_files} file(s)")
        return "\n".join(lines)


def load_baseline(path: Optional[Path] = None) -> set[str]:
    path = Path(path) if path is not None else DEFAULT_BASELINE
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("accepted", []))


def write_baseline(report: Report, path: Optional[Path] = None) -> Path:
    """Accept every current finding (active + baselined) as legacy debt."""
    path = Path(path) if path is not None else DEFAULT_BASELINE
    keys = sorted({f.baseline_key()
                   for f in report.active + report.baselined})
    path.write_text(json.dumps({"accepted": keys}, indent=2) + "\n")
    return path


def run(paths: Iterable[Path], rules: Iterable[Rule],
        baseline: Optional[set[str]] = None) -> Report:
    """Lint ``paths`` with ``rules``; partition findings against baseline."""
    baseline = baseline or set()
    report = Report()
    files = iter_py_files(paths)
    report.n_files = len(files)
    for path in files:
        try:
            ctx = FileContext.parse(path)
        except SyntaxError as e:
            report.active.append(Finding(
                file=path.as_posix(), line=e.lineno or 0, rule="parse-error",
                message=f"could not parse: {e.msg}"))
            continue
        suppressions = parse_suppressions(ctx.text)
        for rule in rules:
            for f in rule.check(ctx):
                disabled = suppressions.get(f.line, set())
                if f.rule in disabled or "all" in disabled:
                    report.suppressed.append(f)
                elif f.baseline_key() in baseline:
                    report.baselined.append(f)
                else:
                    report.active.append(f)
    report.active.sort()
    report.suppressed.sort()
    report.baselined.sort()
    return report
