"""Project-wide symbol table and call graph for mdrqlint v2 (DESIGN.md §12).

PR 8's rules were per-file: taint stopped at module boundaries, counted-op
registrations were only visible in the module that made them, and method
calls on adapter objects were conservatively opaque. This module gives every
project-scoped analysis (cross-module host-sync taint, the budget certifier,
the kernel-contract pack) one shared view of the tree:

  * **modules** — every ``.py`` file parsed once, named by its package path
    (``src/repro/core/scan.py`` -> ``repro.core.scan``; the package root is
    found by walking ``__init__.py`` parents, so test fixture trees resolve
    the same way the shipped tree does);
  * **imports** — ``import x.y as z`` / ``from pkg import name as alias`` /
    relative ``from . import ops`` all normalize to fully-qualified targets,
    and re-exports chain through ``__init__.py`` (``repro.core.MDRQEngine``
    canonicalizes to ``repro.core.engine.MDRQEngine``), cycle-safe;
  * **counted ops** — every ``X = ops.counted("name", ...)(impl)`` binding
    and ``@ops.counted("name", ...)`` decorator in the project, resolved to
    both the public binding and the impl function, so a call through any
    alias (``from repro.kernels import ops as o; o.multi_scan_reduce(...)``)
    is recognized as the counted launch it is;
  * **classes** — methods, resolved base classes, and ``self.attr`` types
    inferred from ``__init__`` construction sites, so ``self._scan.query(q)``
    resolves to ``ColumnarScan.query`` where the constructor argument's class
    is statically known.

Everything here is stdlib ``ast`` — the CI lint job has no jax installed and
the budget certifier (``analysis.budget``) must run there.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterable, Optional


def _dotted(node: Optional[ast.AST]) -> Optional[str]:
    """'jax.jit' for Attribute chains, 'x' for Name, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _is_namespace_level(d: Path) -> bool:
    """Whether ``d`` is a PEP 420 namespace-package level: an ``__init__``-
    less directory sitting directly on a source root (``src/`` or a project
    root bearing ``pyproject.toml``/``setup.py``/``.git``). The shipped tree
    is exactly this shape — ``src/repro/`` has no ``__init__.py``."""
    name = d.name
    if not name.isidentifier() or name in ("src", "lib", "tests"):
        return False
    parent = d.parent
    if parent == d:
        return False
    return parent.name == "src" or any(
        (parent / marker).exists()
        for marker in ("pyproject.toml", "setup.py", ".git"))


def module_name(path: Path) -> str:
    """Dotted module name by walking ``__init__.py`` parents.

    ``src/repro/core/scan.py`` -> ``repro.core.scan`` (the ``repro`` level
    is a namespace package — see ``_is_namespace_level``); a top-level
    script with no package parent keeps its stem (``benchmarks/common.py``
    -> ``benchmarks.common`` only because ``benchmarks/`` sits on the
    project root).
    """
    parts = [] if path.stem == "__init__" else [path.stem]
    d = path.parent
    while (d / "__init__.py").exists():
        parts.insert(0, d.name)
        parent = d.parent
        if parent == d:
            break
        d = parent
    while _is_namespace_level(d):
        parts.insert(0, d.name)
        d = d.parent
    return ".".join(parts) or path.stem


@dataclasses.dataclass
class FunctionInfo:
    """One function or method definition."""

    qual: str                    # repro.core.scan.ColumnarScan.launch_batch
    name: str
    module: str
    cls: Optional[str]           # owning class name, or None
    node: ast.AST                # FunctionDef / AsyncFunctionDef
    decorators: tuple[str, ...]  # dotted decorator names (unresolved text)


@dataclasses.dataclass
class ClassInfo:
    """One class: methods, bases, and inferred ``self.attr`` types."""

    qual: str
    name: str
    module: str
    node: ast.ClassDef
    bases: tuple[str, ...]                  # dotted base names (module-local)
    methods: dict[str, FunctionInfo]
    attr_types: dict[str, str]              # self.<attr> -> class qual


@dataclasses.dataclass
class ModuleInfo:
    """One parsed module's symbol table."""

    name: str
    path: Path
    posix: str
    tree: ast.AST
    imports: dict[str, str]          # local name -> fully-qualified target
    functions: dict[str, FunctionInfo]
    classes: dict[str, ClassInfo]
    counted: dict[str, str]          # local binding/impl name -> op name


@dataclasses.dataclass(frozen=True)
class CallSite:
    """One resolved call edge."""

    caller: str            # caller qual
    callee: str            # resolved callee qual (or raw dotted if unresolved)
    resolved: bool
    line: int


class CallGraph:
    """The project view: modules, functions, classes, counted ops, edges."""

    def __init__(self) -> None:
        self.modules: dict[str, ModuleInfo] = {}
        self.functions: dict[str, FunctionInfo] = {}
        self.classes: dict[str, ClassInfo] = {}
        self.counted_ops: dict[str, str] = {}   # qual -> op name

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, files: Iterable[tuple[Path, ast.AST]]) -> "CallGraph":
        """Build from (path, parsed-tree) pairs (trees parse once upstream)."""
        g = cls()
        for path, tree in files:
            g._add_module(path, tree)
        g._resolve_attr_types()
        return g

    def _add_module(self, path: Path, tree: ast.AST) -> None:
        name = module_name(path)
        mod = ModuleInfo(name=name, path=path, posix=path.as_posix(),
                         tree=tree, imports={}, functions={}, classes={},
                         counted={})
        self._collect_imports(mod)
        self._collect_defs(mod)
        self._collect_counted(mod)
        self.modules[name] = mod
        for fn in mod.functions.values():
            self.functions[fn.qual] = fn
        for ci in mod.classes.values():
            self.classes[ci.qual] = ci
            for m in ci.methods.values():
                self.functions[m.qual] = m
        for local, op in mod.counted.items():
            self.counted_ops[f"{name}.{local}"] = op

    def _collect_imports(self, mod: ModuleInfo) -> None:
        pkg = mod.name if mod.path.stem == "__init__" \
            else mod.name.rpartition(".")[0]
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.imports[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
                    if a.asname is None:
                        # ``import x.y`` binds x but makes x.y addressable
                        mod.imports.setdefault(a.name, a.name)
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:  # relative: anchor at this module's package
                    anchor = pkg.split(".")
                    anchor = anchor[: len(anchor) - (node.level - 1)]
                    base = ".".join(anchor + ([node.module]
                                              if node.module else []))
                for a in node.names:
                    if a.name == "*":
                        continue
                    mod.imports[a.asname or a.name] = f"{base}.{a.name}"

    def _collect_defs(self, mod: ModuleInfo) -> None:
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                mod.functions[node.name] = FunctionInfo(
                    qual=f"{mod.name}.{node.name}", name=node.name,
                    module=mod.name, cls=None, node=node,
                    decorators=tuple(_dotted(d.func if isinstance(d, ast.Call)
                                             else d) or ""
                                     for d in node.decorator_list))
            elif isinstance(node, ast.ClassDef):
                cq = f"{mod.name}.{node.name}"
                methods: dict[str, FunctionInfo] = {}
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        methods[item.name] = FunctionInfo(
                            qual=f"{cq}.{item.name}", name=item.name,
                            module=mod.name, cls=node.name, node=item,
                            decorators=tuple(
                                _dotted(d.func if isinstance(d, ast.Call)
                                        else d) or ""
                                for d in item.decorator_list))
                mod.classes[node.name] = ClassInfo(
                    qual=cq, name=node.name, module=mod.name, node=node,
                    bases=tuple(_dotted(b) or "" for b in node.bases),
                    methods=methods, attr_types={})

    def _collect_counted(self, mod: ModuleInfo) -> None:
        """``X = counted("op", ...)(impl)`` bindings and ``@counted`` defs.

        Any callee whose dotted name ends in ``counted`` qualifies (covers
        ``counted``, ``_counted``, ``ops.counted``, and aliased imports like
        ``o.counted``) — the op name is the first string literal argument.
        """
        def op_of(call: ast.Call) -> Optional[str]:
            name = _dotted(call.func) or ""
            if not name.rsplit(".", 1)[-1].rstrip("_").lstrip("_") \
                    == "counted":
                return None
            for a in call.args:
                if isinstance(a, ast.Constant) and isinstance(a.value, str):
                    return a.value
            return None

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call) \
                    and isinstance(node.value.func, ast.Call):
                op = op_of(node.value.func)
                if op is None:
                    continue
                for tgt in node.targets:
                    n = _dotted(tgt)
                    if n:
                        mod.counted[n] = op
                for a in node.value.args:   # the wrapped impl fn
                    n = _dotted(a)
                    if n:
                        mod.counted[n] = op
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for d in node.decorator_list:
                    if isinstance(d, ast.Call):
                        op = op_of(d)
                        if op is not None:
                            mod.counted[node.name] = op

    def _resolve_attr_types(self) -> None:
        """Infer ``self.attr`` class types from ``__init__`` bodies.

        ``self._scan = scan`` alone is opaque, but ``self._index = index``
        next to a registration site ``BlockedIndexPath(BlockedIndex(...))``
        is not something we chase — the inference here is the direct form:
        ``self.attr = SomeClass(...)`` where ``SomeClass`` resolves to a
        project class, and ``self.attr = arg`` where the parameter carries a
        class annotation. Explicit bindings for the known adapter classes
        live in ``analysis.budget`` (config, not inference).
        """
        for ci in self.classes.values():
            init = ci.methods.get("__init__")
            if init is None:
                continue
            ann: dict[str, str] = {}
            args = init.node.args
            for a in list(args.args) + list(args.kwonlyargs):
                if a.annotation is not None:
                    d = _dotted(a.annotation)
                    if d:
                        q = self.resolve(ci.module, d)
                        if q in self.classes:
                            ann[a.arg] = q
            for node in ast.walk(init.node):
                if not isinstance(node, ast.Assign):
                    continue
                for tgt in node.targets:
                    if not (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        continue
                    v = node.value
                    if isinstance(v, ast.Call):
                        d = _dotted(v.func)
                        q = self.resolve(ci.module, d) if d else None
                        if q in self.classes:
                            ci.attr_types[tgt.attr] = q
                    elif isinstance(v, ast.Name) and v.id in ann:
                        ci.attr_types[tgt.attr] = ann[v.id]

    # -- resolution ---------------------------------------------------------
    def resolve(self, module: str, dotted: str,
                _seen: Optional[frozenset] = None) -> Optional[str]:
        """Resolve a dotted name as used in ``module`` to a project qual.

        Follows import aliases and ``__init__.py`` re-export chains (cycle
        safe). Returns None for builtins / third-party names.
        """
        mod = self.modules.get(module)
        if mod is None or not dotted:
            return None
        head, _, rest = dotted.partition(".")
        if head in mod.imports:
            return self.canonicalize(
                mod.imports[head] + (f".{rest}" if rest else ""), _seen)
        if head in mod.functions or head in mod.classes \
                or head in mod.counted:
            return self.canonicalize(f"{module}.{dotted}", _seen)
        return None

    def canonicalize(self, qual: str,
                     _seen: Optional[frozenset] = None) -> Optional[str]:
        """Follow re-export chains until ``qual`` names a real definition."""
        _seen = _seen or frozenset()
        if qual in _seen:
            return None  # import cycle: stop, stay unresolved
        _seen = _seen | {qual}
        # longest module prefix owning this qual
        parts = qual.split(".")
        for i in range(len(parts), 0, -1):
            prefix = ".".join(parts[:i])
            mod = self.modules.get(prefix)
            if mod is None:
                continue
            rest = parts[i:]
            if not rest:
                return prefix  # the module itself
            head = rest[0]
            if head in mod.functions or head in mod.classes \
                    or head in mod.counted:
                return qual
            if head in mod.imports:
                target = mod.imports[head] + \
                    ("." + ".".join(rest[1:]) if rest[1:] else "")
                return self.canonicalize(target, _seen)
            return qual  # module exists but symbol is dynamic; keep literal
        return qual if any(qual.startswith(m + ".") or qual == m
                           for m in self.modules) else None

    def lookup_method(self, class_qual: str, meth: str,
                      _seen: Optional[frozenset] = None
                      ) -> Optional[FunctionInfo]:
        """Resolve ``meth`` on ``class_qual``, walking base classes."""
        _seen = _seen or frozenset()
        if class_qual in _seen:
            return None
        ci = self.classes.get(class_qual)
        if ci is None:
            return None
        if meth in ci.methods:
            return ci.methods[meth]
        for b in ci.bases:
            bq = self.resolve(ci.module, b)
            if bq:
                hit = self.lookup_method(bq, meth, _seen | {class_qual})
                if hit is not None:
                    return hit
        return None

    def counted_op(self, module: str, dotted: str) -> Optional[str]:
        """The op name if ``dotted`` (as used in ``module``) is counted."""
        q = self.resolve(module, dotted)
        return self.counted_ops.get(q) if q else None

    def is_device_get(self, module: str, dotted: str) -> bool:
        """Whether ``dotted`` resolves to the counted ``ops.device_get``."""
        if dotted.rsplit(".", 1)[-1] != "device_get":
            return False
        q = self.resolve(module, dotted)
        # unresolved ``ops.device_get`` in a fixture still counts by shape
        return q is None or q.endswith(".device_get")

    # -- call edges (for tests and future rules) ----------------------------
    def callees(self, fn: FunctionInfo) -> list[CallSite]:
        """Best-effort resolved call edges out of one function."""
        out: list[CallSite] = []
        ci = self.classes.get(f"{fn.module}.{fn.cls}") if fn.cls else None
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if not d:
                continue
            target: Optional[str] = None
            if d.startswith("self.") and ci is not None:
                rest = d[len("self."):]
                head, _, meth = rest.partition(".")
                if not meth:
                    hit = self.lookup_method(ci.qual, head)
                    target = hit.qual if hit else None
                elif head in ci.attr_types and "." not in meth:
                    hit = self.lookup_method(ci.attr_types[head], meth)
                    target = hit.qual if hit else None
            else:
                target = self.resolve(fn.module, d)
            out.append(CallSite(caller=fn.qual, callee=target or d,
                                resolved=target is not None,
                                line=node.lineno))
        return out
