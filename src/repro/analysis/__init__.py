"""mdrqlint — AST-based static checks for this repo's runtime invariants.

The paper's headline result (scans beat MDIS on modern hardware) holds here
only because every hot path preserves hand-maintained invariants: one kernel
launch + one *counted* host sync per batch, dtype-correct padding sentinels,
lock-disciplined version swaps, frozen (jit-static-arg-safe) registry
entries. Runtime counter asserts (PRs 1-7) only fire on the paths a test
happens to exercise; mdrqlint checks the same invariants syntactically over
the whole tree at review time — PR 6's backend-cache bug and PR 3's bf16
``+inf`` sentinel bug are exactly the class it would have caught.

Usage::

    python -m repro.analysis src tests            # lint, exit 1 on findings
    python -m repro.analysis --json report.json   # machine-readable report
    python -m repro.analysis --write-baseline     # accept current findings

Per-line suppression: append ``# mdrqlint: disable=<rule>[,<rule>...]`` (or
``disable=all``) to the offending line. Accepted legacy debt lives in the
checked-in ``baseline.json`` next to this package; CI fails only on *new*
unsuppressed findings. Rules and the invariants they encode are tabulated in
DESIGN.md §12.
"""
from repro.analysis.engine import (Finding, Report, Rule, load_baseline,
                                   run, write_baseline)
from repro.analysis.rules import ALL_RULES

__all__ = ["ALL_RULES", "Finding", "Report", "Rule", "load_baseline", "run",
           "write_baseline"]
