"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean; 1 findings or stale baseline entries; 2 parse/usage
errors. CI runs ``python -m repro.analysis src tests benchmarks examples``
next to ruff (``make lint-mdrq``) and ``--budget-check BUDGET.json`` in the
same job (``make budget-cert`` regenerates the certificate).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.engine import (DEFAULT_BASELINE, build_project,
                                   iter_py_files, load_baseline,
                                   prune_baseline, run, write_baseline)
from repro.analysis.rules import ALL_RULES

DEFAULT_PATHS = ["src", "tests", "benchmarks", "examples"]


def _budget(paths: list[str], out: str | None, check: str | None) -> int:
    """Certify launch/sync budgets; write or diff the certificate."""
    from repro.analysis import budget

    files = iter_py_files([Path(p) for p in paths])
    project, errors = build_project(files)
    if errors:
        for e in errors:
            print(e.format())
        return 2
    try:
        if check is not None:
            drift = budget.check(project.graph, Path(check))
            if drift:
                print(f"mdrqlint: budget certificate {check} is stale "
                      f"({len(drift)} difference(s)) — regenerate with "
                      "`make budget-cert` and review the diff:")
                for line in drift:
                    print(f"  {line}")
                return 1
            print(f"mdrqlint: budget certificate {check} matches the source")
            return 0
        cert = budget.certify(project.graph)
        text = budget.render(cert)
        if out is None or out == "-":
            print(text, end="")
        else:
            Path(out).write_text(text)
            print(f"mdrqlint: wrote budget certificate to {out}")
        return 0
    except budget.BudgetError as e:
        print(f"mdrqlint: budget certification failed: {e}")
        return 2


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="mdrqlint: whole-program static checks for launch/"
                    "host-sync/sentinel/lock/registry/kernel-contract "
                    "invariants, plus the launch/sync budget certifier "
                    "(DESIGN.md §12)")
    ap.add_argument("paths", nargs="*", default=DEFAULT_PATHS,
                    help="files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write the full report as JSON")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="drop stale baseline entries (keys matching no "
                         "current finding)")
    ap.add_argument("--budget", metavar="FILE", nargs="?", const="-",
                    default=None,
                    help="derive the static launch/sync budget certificate "
                         "and write it to FILE (stdout if omitted)")
    ap.add_argument("--budget-check", metavar="FILE", default=None,
                    help="diff the checked-in budget certificate against a "
                         "fresh derivation; exit 1 on drift")
    ap.add_argument("--list-rules", action="store_true",
                    help="print rule ids and the invariants they encode")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.rule_id}: {rule.doc}")
        return 0

    if args.budget is not None or args.budget_check is not None:
        # certification scans src only: budgets are a property of the
        # package, not of tests/benchmarks driving it
        paths = args.paths if args.paths != DEFAULT_PATHS else ["src"]
        return _budget(paths, args.budget, args.budget_check)

    baseline_path = Path(args.baseline) if args.baseline else None
    report = run([Path(p) for p in args.paths], ALL_RULES,
                 baseline=load_baseline(baseline_path))

    if args.write_baseline:
        path = write_baseline(report, baseline_path)
        print(f"mdrqlint: wrote {len(report.active) + len(report.baselined)} "
              f"accepted finding(s) to {path}")
        return 0
    if args.prune_baseline:
        path = prune_baseline(report, baseline_path)
        print(f"mdrqlint: pruned {len(report.stale_baseline)} stale "
              f"entr(y/ies) from {path} "
              f"({len(report.baselined)} kept)")
        return 0

    if args.json:
        Path(args.json).write_text(
            json.dumps(report.to_json(), indent=2) + "\n")
    print(report.format())
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
