"""CLI: ``python -m repro.analysis [paths...]``.

Exit status 0 iff no unsuppressed, unbaselined findings. CI runs
``python -m repro.analysis src tests`` next to ruff (``make lint-mdrq``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.engine import (DEFAULT_BASELINE, load_baseline, run,
                                   write_baseline)
from repro.analysis.rules import ALL_RULES


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="mdrqlint: static checks for launch/host-sync/sentinel/"
                    "lock/registry invariants (DESIGN.md §12)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to lint (default: src tests)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write the full report as JSON")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--list-rules", action="store_true",
                    help="print rule ids and the invariants they encode")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.rule_id}: {rule.doc}")
        return 0

    baseline_path = Path(args.baseline) if args.baseline else None
    report = run([Path(p) for p in args.paths], ALL_RULES,
                 baseline=load_baseline(baseline_path))

    if args.write_baseline:
        path = write_baseline(report, baseline_path)
        print(f"mdrqlint: wrote {len(report.active) + len(report.baselined)} "
              f"accepted finding(s) to {path}")
        return 0

    if args.json:
        Path(args.json).write_text(
            json.dumps(report.to_json(), indent=2) + "\n")
    print(report.format())
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
