"""Pallas kernel-contract rules (mdrqlint v2, DESIGN.md §12).

The kernels under ``repro/kernels/`` all follow the same physical contract:
padded array extents divide their tiles (the grid is exact, no partial
tiles), accumulators state their dtype instead of inheriting numpy's
defaults, and every jitted body opens with the ``ops.note_trace`` probe that
makes retraces observable. Each clause has burned us before — PR 3's
``-3.4e38``-rounds-to-``-inf`` bf16 bug was exactly a dtype assumption
crossing a ``pallas_call`` signature — so each is a rule:

``kernel-tile``
    every ``a // b`` appearing in a ``grid=`` (directly, via a same-function
    ``grid = (...)`` assignment, or inside a ``PrefetchScalarGridSpec``)
    must be backed by an ``assert a % b == 0`` in the same function. A grid
    built from an inexact division silently drops the remainder tile — the
    scan returns wrong answers only for the tail objects, the worst kind of
    wrong.

``kernel-dtype``
    inside a kernel body (a function passed to ``pallas_call``), array
    creations (``jnp.zeros/ones/full/empty``) must pass an explicit dtype —
    a defaulted f32 accumulator silently downcasts on store when the out ref
    is narrower; and ``inf`` fills must state a wide dtype (use
    ``numerics.mask_fill(ref.dtype)`` for a finite sentinel).

``note-trace``
    every jit-decorated function (and same-module defs bound via
    ``X = jax.jit(f)``) opens with ``ops.note_trace("...")`` as its first
    non-docstring statement — the trace-time probe the AOT warmup's
    zero-retrace assertion (DESIGN.md §13) is built on.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import FileContext, Finding, Rule
from repro.analysis.rules import (_dotted, _has_jit_decorator, _in_repro,
                                  _is_jit_expr)


def _mod_assert_pairs(fn: ast.AST) -> set[tuple[str, str]]:
    """All ``(a, b)`` with an ``assert ... a % b == 0 ...`` in ``fn``."""
    pairs: set[tuple[str, str]] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assert):
            continue
        for cmp in ast.walk(node.test):
            if isinstance(cmp, ast.Compare) \
                    and isinstance(cmp.left, ast.BinOp) \
                    and isinstance(cmp.left.op, ast.Mod) \
                    and len(cmp.ops) == 1 \
                    and isinstance(cmp.ops[0], ast.Eq) \
                    and isinstance(cmp.comparators[0], ast.Constant) \
                    and cmp.comparators[0].value == 0:
                pairs.add((ast.unparse(cmp.left.left),
                           ast.unparse(cmp.left.right)))
    return pairs


def _grid_exprs(fn: ast.AST) -> list[ast.expr]:
    """Every expression passed as ``grid=`` inside ``fn``, with one level of
    ``grid = (...)`` local-assignment indirection resolved."""
    assigns: dict[str, ast.expr] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            assigns[node.targets[0].id] = node.value
    out: list[ast.expr] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "grid":
                    e = kw.value
                    if isinstance(e, ast.Name) and e.id in assigns:
                        e = assigns[e.id]
                    out.append(e)
    return out


class KernelTileRule(Rule):
    rule_id = "kernel-tile"
    doc = ("Every floor division in a Pallas grid needs a matching "
           "divisibility assert in the same function — an inexact grid "
           "silently drops the remainder tile (wrong answers for tail "
           "objects only).")

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_repro(ctx.posix) or "/kernels/" not in ctx.posix:
            return []
        findings: list[Finding] = []
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            grids = _grid_exprs(fn)
            if not grids:
                continue
            pairs = _mod_assert_pairs(fn)
            for g in grids:
                for div in ast.walk(g):
                    if isinstance(div, ast.BinOp) \
                            and isinstance(div.op, ast.FloorDiv):
                        a = ast.unparse(div.left)
                        b = ast.unparse(div.right)
                        if (a, b) not in pairs:
                            findings.append(self.finding(
                                ctx, div, f"grid uses '{a} // {b}' without "
                                f"'assert {a} % {b} == 0' in "
                                f"'{fn.name}' — an inexact grid drops the "
                                "remainder tile"))
        return findings


def _kernel_body_names(tree: ast.AST) -> set[str]:
    """Names of functions passed (possibly via ``functools.partial``) as the
    kernel argument of a ``pallas_call``."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and (_dotted(node.func) or "").endswith("pallas_call")
                and node.args):
            continue
        k = node.args[0]
        if isinstance(k, ast.Call) and \
                (_dotted(k.func) or "").rsplit(".", 1)[-1] == "partial" \
                and k.args:
            k = k.args[0]
        n = _dotted(k)
        if n:
            out.add(n.rsplit(".", 1)[-1])
    return out


_CREATORS = {"zeros": 2, "ones": 2, "empty": 2, "full": 3}
_INF_NAMES = {"np.inf", "jnp.inf", "math.inf", "inf"}
_WIDE_DTYPES = {"np.float32", "jnp.float32", "np.float64", "jnp.float64",
                "float", "F32", "F64", "FLOAT32", "FLOAT64"}


def _is_inf(e: ast.AST) -> bool:
    if isinstance(e, ast.UnaryOp) and isinstance(e.op, (ast.USub, ast.UAdd)):
        return _is_inf(e.operand)
    return _dotted(e) in _INF_NAMES


class KernelDtypeRule(Rule):
    rule_id = "kernel-dtype"
    doc = ("Array creations inside Pallas kernel bodies must state their "
           "dtype (a defaulted accumulator silently downcasts on store), "
           "and inf fills must state a wide one (PR 3's bf16 sentinel bug "
           "shape, inside the pallas_call signature).")

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_repro(ctx.posix) or "/kernels/" not in ctx.posix:
            return []
        kernels = _kernel_body_names(ctx.tree)
        if not kernels:
            return []
        findings: list[Finding] = []
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and fn.name in kernels:
                findings.extend(self._check_kernel(ctx, fn))
        return findings

    def _check_kernel(self, ctx: FileContext, fn: ast.AST) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func) or ""
            short = name.rsplit(".", 1)[-1]
            if short in _CREATORS:
                n_for_dtype = _CREATORS[short]
                has_dtype = len(node.args) >= n_for_dtype or any(
                    k.arg == "dtype" for k in node.keywords)
                if not has_dtype:
                    findings.append(self.finding(
                        ctx, node, f"'{short}' without an explicit dtype in "
                        f"kernel body '{fn.name}' — a defaulted accumulator "
                        "dtype silently downcasts when stored to a narrower "
                        "ref; state it (match the out ref)"))
            if short in ("full", "full_like"):
                vals = list(node.args) + [k.value for k in node.keywords]
                if any(_is_inf(v) for v in vals):
                    dtypes = [_dotted(v) for v in vals]
                    if not any(d in _WIDE_DTYPES for d in dtypes if d):
                        findings.append(self.finding(
                            ctx, node, f"inf fill without a wide dtype in "
                            f"kernel body '{fn.name}' — use "
                            "numerics.mask_fill(ref.dtype) for a finite "
                            "sentinel or state an f32 dtype"))
        return findings


class NoteTraceRule(Rule):
    rule_id = "note-trace"
    doc = ("Every jitted body's first statement is ops.note_trace('op') — "
           "the trace-time probe the serving pipeline's zero-retrace "
           "assertion (AOT warmup, DESIGN.md §13) is built on.")

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_repro(ctx.posix) or "/analysis/" in ctx.posix:
            return []
        defs: dict[str, ast.AST] = {}
        jitted: dict[str, ast.AST] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs[node.name] = node
                if _has_jit_decorator(node):
                    jitted[node.name] = node
        # X = jax.jit(f) / functools.partial(jax.jit, ...)(f) bindings over
        # same-module defs are jit entry points too
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and _is_jit_expr(node.value):
                for a in node.value.args:
                    n = _dotted(a)
                    if n in defs and n not in ("jax.jit", "jit"):
                        jitted[n] = defs[n]
        findings: list[Finding] = []
        for name, fn in sorted(jitted.items()):
            body = list(fn.body)
            if body and isinstance(body[0], ast.Expr) \
                    and isinstance(body[0].value, ast.Constant) \
                    and isinstance(body[0].value.value, str):
                body = body[1:]  # docstring
            first = body[0] if body else None
            ok = (isinstance(first, ast.Expr)
                  and isinstance(first.value, ast.Call)
                  and (_dotted(first.value.func) or ""
                       ).rsplit(".", 1)[-1] == "note_trace")
            if not ok:
                findings.append(self.finding(
                    ctx, fn, f"jitted body '{name}' does not open with "
                    "ops.note_trace(...) — retraces of this body are "
                    "invisible to the AOT warmup's zero-retrace assertion"))
        return findings


CONTRACT_RULES = (KernelTileRule(), KernelDtypeRule(), NoteTraceRule())
