"""Static launch/host-sync budget certifier (mdrqlint v2, DESIGN.md §12).

The repo's core performance claim is a *budget*: every warm serving path
costs a fixed number of counted kernel launches and host syncs per batch
window (e.g. scan = one fused ``multi_scan_reduce`` + one ``device_get``;
the two-phase tree paths = prune + visit launches with the mid-stage
survivor sync + the payload sync). Runtime tests assert these numbers
against the ``mdrq_launches_total`` counters — *after* the code runs. This
module derives the same numbers **statically**, by abstract interpretation
over the project call graph, and writes them to a checked-in ``BUDGET.json``
that CI regenerates and diffs: a source edit that adds a launch or sync to a
serving path changes the certificate and fails the build before any test
executes. A deliberate budget change ships with its regenerated certificate
in the same diff — that is the escape hatch, and it is reviewable.

How the interpreter works (stdlib ``ast`` only — the CI lint job has no
jax):

  * Abstract values: ``None``/``True``/``False`` literals, tuples, known
    class instances (``VInstance``), closures (``VFunc``), factories, module
    refs, and two unknowns — ``OPAQUE`` (unknown but non-None: the result of
    a counted launch, a delta view's device arrays) and ``UNKNOWN``.
  * Every call is resolved through the ``CallGraph``: counted ops bump the
    launch tally, ``ops.device_get`` bumps the sync tally, project functions
    and methods are interpreted recursively (cycle/depth guarded),
    ``repro.obs`` is opaque by contract (tracing/metrics must never launch).
  * Branches on *known* conditions (``if partial:``, ``if delta is not None
    and not delta.is_empty:``, ``if dcm is None:``) follow that branch;
    branches on unknown conditions interpret both futures and keep the
    **max-cost** one (ties prefer the guard-skipping continuation) — the
    certificate is the warm-path worst case, which for these kernels is also
    the common case (the cheap arms are empty-input corners).
  * Loops and comprehensions run once: the certificate's unit is *per
    bucket* — per fused launch group — matching how the runtime counters are
    asserted.

Entry points are configured, not discovered: each registered access path
adapter × {frozen, live-delta} context, plus the engine split protocol
(``MDRQEngine.launch_batch`` / ``query_batch`` own-cost, ``PendingBatch.
finalize`` per bucket) and the pipelined server's stage functions. Adapter
receiver types (``ColumnarScanPath._scan`` is a ``ColumnarScan``) are
explicit config here — ``self._scan = scan`` with an unannotated parameter
is not inferable, and config that certifies wrong numbers fails the runtime
cross-validation test immediately.
"""
from __future__ import annotations

import ast
import collections
import dataclasses
import json
from pathlib import Path
from typing import Optional

from repro.analysis.callgraph import CallGraph, FunctionInfo


# -- abstract values ----------------------------------------------------------

class _V:
    """Base abstract value."""


@dataclasses.dataclass(frozen=True)
class VConst(_V):
    value: object


@dataclasses.dataclass(frozen=True)
class VUnknown(_V):
    pass


@dataclasses.dataclass(frozen=True)
class VOpaque(_V):
    """Unknown value statically known to be non-None (a counted launch's
    payload, a delta view's device arrays, an obs span)."""


NONE = VConst(None)
TRUE = VConst(True)
FALSE = VConst(False)
UNKNOWN = VUnknown()
OPAQUE = VOpaque()


@dataclasses.dataclass
class VTuple(_V):
    items: tuple


@dataclasses.dataclass
class VInstance(_V):
    cls: str                    # class qual ("__delta__" for the pseudo-view)
    attrs: dict


@dataclasses.dataclass
class VFactory(_V):
    """A zero-arg callable returning an instance of ``cls`` (the vertical
    scan path's lazy ``scan_ref``)."""
    cls: str


@dataclasses.dataclass
class VRef(_V):
    """An unresolved dotted name (module alias, global) — resolved against
    the call graph at call time."""
    dotted: str


@dataclasses.dataclass
class VFunc(_V):
    """A local ``def``/``lambda`` closure: body + defining scope."""
    node: ast.AST
    module: str
    cls: Optional[str]
    env: dict


def _is_none(v: _V) -> Optional[bool]:
    """None-ness: True / False / None (unknown)."""
    if isinstance(v, VConst):
        return v.value is None
    if isinstance(v, VUnknown):
        return None
    return False  # tuples, instances, closures, refs, OPAQUE


def _truth(v: _V) -> Optional[bool]:
    """Truthiness: True / False / None (unknown)."""
    if isinstance(v, VConst):
        return bool(v.value)
    if isinstance(v, VTuple):
        return len(v.items) > 0
    if isinstance(v, (VInstance, VFactory, VFunc, VRef)):
        return True
    return None  # OPAQUE, UNKNOWN


_RET = "ret"          # exec_block signal tag
_MAX_DEPTH = 24

# Host-side shape plumbing interpreted by contract instead of recursion:
# ``validate_mode``/``resolve_spec`` return their spec argument, ``.validate``
# returns its receiver. (All are pure host-side checks.)
_RETURNS_ARG0 = {"validate_mode", "resolve_spec"}
_RETURNS_RECEIVER = {"validate"}


class BudgetError(Exception):
    """An entry point could not be certified (config/source drift)."""


class _Interp:
    """One abstract execution: accumulates launch/sync tallies."""

    def __init__(self, graph: CallGraph):
        self.graph = graph
        self.launches: collections.Counter = collections.Counter()
        self.host_syncs = 0
        self._stack: list[str] = []

    # -- cost bookkeeping ---------------------------------------------------
    def _snap(self):
        return self.launches.copy(), self.host_syncs

    def _restore(self, snap):
        self.launches, self.host_syncs = snap[0].copy(), snap[1]

    def _score(self, snap) -> int:
        return (sum(self.launches.values()) - sum(snap[0].values())) \
            + (self.host_syncs - snap[1])

    # -- function interpretation --------------------------------------------
    def call_function(self, fi: FunctionInfo, self_val: Optional[_V],
                      args: list, kwargs: dict) -> _V:
        if fi.module.startswith("repro.obs"):
            return OPAQUE  # tracing/metrics are cost-free by contract
        if fi.qual in self._stack or len(self._stack) >= _MAX_DEPTH:
            return UNKNOWN
        env = self._bind(fi.node.args, fi,
                         ([self_val] if fi.cls is not None
                          and self_val is not None else []) + list(args),
                         dict(kwargs))
        self._stack.append(fi.qual)
        try:
            r = self.exec_block(list(fi.node.body), env, fi)
        finally:
            self._stack.pop()
        return r[1] if r is not None else NONE

    def call_closure(self, f: VFunc, args: list, kwargs: dict) -> _V:
        key = f"<closure@{f.module}:{getattr(f.node, 'lineno', 0)}>"
        if key in self._stack or len(self._stack) >= _MAX_DEPTH:
            return UNKNOWN
        fi = FunctionInfo(qual=key, name=getattr(f.node, "name", "<lambda>"),
                          module=f.module, cls=f.cls, node=f.node,
                          decorators=())
        env = dict(f.env)
        env.update(self._bind(f.node.args, fi, list(args), dict(kwargs)))
        self._stack.append(key)
        try:
            if isinstance(f.node, ast.Lambda):
                return self.eval(f.node.body, env, fi)
            r = self.exec_block(list(f.node.body), env, fi)
        finally:
            self._stack.pop()
        return r[1] if r is not None else NONE

    def _bind(self, a: ast.arguments, fi: FunctionInfo, vals: list,
              kwargs: dict) -> dict:
        env: dict = {}
        names = [p.arg for p in list(a.posonlyargs) + list(a.args)]
        n_required = len(names) - len(a.defaults)
        for i, nm in enumerate(names):
            if i < len(vals):
                env[nm] = vals[i]
            elif nm in kwargs:
                env[nm] = kwargs.pop(nm)
            elif i >= n_required:
                env[nm] = self._default(a.defaults[i - n_required])
            else:
                env[nm] = UNKNOWN
        for p, d in zip(a.kwonlyargs, a.kw_defaults):
            if p.arg in kwargs:
                env[p.arg] = kwargs.pop(p.arg)
            else:
                env[p.arg] = self._default(d) if d is not None else UNKNOWN
        if a.vararg:
            env[a.vararg.arg] = UNKNOWN
        if a.kwarg:
            env[a.kwarg.arg] = UNKNOWN
        return env

    @staticmethod
    def _default(d: Optional[ast.AST]) -> _V:
        if isinstance(d, ast.Constant):
            return VConst(d.value)
        # non-literal defaults (T.IDS, module constants): defined objects
        return OPAQUE

    # -- statements ---------------------------------------------------------
    def exec_block(self, stmts: list, env: dict, fi: FunctionInfo):
        for i, s in enumerate(stmts):
            if isinstance(s, ast.Return):
                return (_RET, self.eval(s.value, env, fi)
                        if s.value is not None else NONE)
            if isinstance(s, ast.Raise):
                return (_RET, NONE)
            if isinstance(s, ast.If):
                t = _truth(self.eval(s.test, env, fi))
                if t is True:
                    r = self.exec_block(list(s.body), env, fi)
                elif t is False:
                    r = self.exec_block(list(s.orelse), env, fi)
                else:
                    rest = stmts[i + 1:]
                    return self._fork([list(s.body) + rest,
                                       list(s.orelse) + rest], env, fi)
                if r is not None:
                    return r
            elif isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                v = (self.eval(s.value, env, fi)
                     if getattr(s, "value", None) is not None else UNKNOWN)
                if isinstance(s, ast.AugAssign):
                    v = UNKNOWN  # x += y: the combined value is opaque
                for t in (s.targets if isinstance(s, ast.Assign)
                          else [s.target]):
                    self._bind_target(t, v, env, fi)
            elif isinstance(s, ast.Expr):
                self.eval(s.value, env, fi)
            elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                env[s.name] = VFunc(node=s, module=fi.module, cls=fi.cls,
                                    env=dict(env))
            elif isinstance(s, (ast.For, ast.AsyncFor)):
                self.eval(s.iter, env, fi)
                self._bind_target(s.target, UNKNOWN, env, fi)
                r = self.exec_block(list(s.body), env, fi)  # body once
                if r is not None:
                    return r
                r = self.exec_block(list(s.orelse), env, fi)
                if r is not None:
                    return r
            elif isinstance(s, ast.While):
                self.eval(s.test, env, fi)
                r = self.exec_block(list(s.body), env, fi)  # body once
                if r is not None:
                    return r
            elif isinstance(s, (ast.With, ast.AsyncWith)):
                for item in s.items:
                    v = self.eval(item.context_expr, env, fi)
                    if item.optional_vars is not None:
                        self._bind_target(
                            item.optional_vars,
                            v if not isinstance(v, VUnknown) else OPAQUE,
                            env, fi)
                r = self.exec_block(list(s.body), env, fi)
                if r is not None:
                    return r
            elif isinstance(s, ast.Try):
                r = self.exec_block(list(s.body), env, fi)
                if r is None:
                    r = self.exec_block(list(s.orelse), env, fi)
                rf = self.exec_block(list(s.finalbody), env, fi)
                if r is not None or rf is not None:
                    return rf if rf is not None else r
            # Import/Assert/Pass/Break/Continue/Global/Nonlocal/Delete:
            # no cost, no bindings the analysis needs (function-level imports
            # are already in the module symbol table via ast.walk)
        return None

    def _fork(self, options: list, env: dict, fi: FunctionInfo):
        """Interpret alternative futures; commit the max-cost one.

        Ties prefer the *last* option — for a two-armed ``if`` that is the
        guard-skipping continuation, so equal-cost early-return corners
        (empty visit lists, empty batches) never displace the main path's
        op names in the certificate.
        """
        base = self._snap()
        best = None
        for stmts in options:
            self._restore(base)
            e = dict(env)
            r = self.exec_block(stmts, e, fi)
            cand = (self._score(base), self._snap(), e, r)
            if best is None or cand[0] >= best[0]:
                best = cand
        _, snap, e, r = best
        self._restore(snap)
        env.clear()
        env.update(e)
        return r

    def _bind_target(self, t: ast.AST, v: _V, env: dict,
                     fi: FunctionInfo) -> None:
        if isinstance(t, ast.Name):
            env[t.id] = v
        elif isinstance(t, (ast.Tuple, ast.List)):
            if isinstance(v, VTuple) and len(v.items) == len(t.elts):
                for sub, sv in zip(t.elts, v.items):
                    self._bind_target(sub, sv, env, fi)
            else:
                for sub in t.elts:
                    self._bind_target(sub, UNKNOWN, env, fi)
        elif isinstance(t, ast.Attribute):
            base = self.eval(t.value, env, fi)
            if isinstance(base, VInstance):
                base.attrs[t.attr] = v
        elif isinstance(t, ast.Starred):
            self._bind_target(t.value, UNKNOWN, env, fi)
        # Subscript targets: no binding tracked

    # -- expressions --------------------------------------------------------
    def eval(self, node: ast.AST, env: dict, fi: FunctionInfo) -> _V:
        if isinstance(node, ast.Constant):
            return VConst(node.value)
        if isinstance(node, ast.Name):
            return env.get(node.id, VRef(node.id))
        if isinstance(node, ast.Attribute):
            base = self.eval(node.value, env, fi)
            if isinstance(base, VRef):
                return VRef(f"{base.dotted}.{node.attr}")
            if isinstance(base, VInstance):
                if node.attr in base.attrs:
                    return base.attrs[node.attr]
                ci = self.graph.classes.get(base.cls)
                if ci is not None and node.attr in ci.attr_types:
                    return VInstance(ci.attr_types[node.attr], {})
            return UNKNOWN
        if isinstance(node, ast.Call):
            return self._eval_call(node, env, fi)
        if isinstance(node, (ast.Tuple, ast.List)):
            return VTuple(tuple(self.eval(e, env, fi) for e in node.elts))
        if isinstance(node, ast.IfExp):
            return self._eval_ifexp(node, env, fi)
        if isinstance(node, ast.BoolOp):
            return self._eval_boolop(node, env, fi)
        if isinstance(node, ast.UnaryOp):
            v = self.eval(node.operand, env, fi)
            if isinstance(node.op, ast.Not):
                t = _truth(v)
                return UNKNOWN if t is None else (FALSE if t else TRUE)
            return UNKNOWN
        if isinstance(node, ast.Compare):
            return self._eval_compare(node, env, fi)
        if isinstance(node, ast.BinOp):
            self.eval(node.left, env, fi)
            self.eval(node.right, env, fi)
            return UNKNOWN
        if isinstance(node, ast.Subscript):
            self.eval(node.value, env, fi)
            if isinstance(node.slice, ast.Slice):
                for part in (node.slice.lower, node.slice.upper,
                             node.slice.step):
                    if part is not None:
                        self.eval(part, env, fi)
            else:
                self.eval(node.slice, env, fi)
            return UNKNOWN
        if isinstance(node, ast.Lambda):
            return VFunc(node=node, module=fi.module, cls=fi.cls,
                         env=dict(env))
        if isinstance(node, ast.Starred):
            return self.eval(node.value, env, fi)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            sub = dict(env)
            for gen in node.generators:
                self.eval(gen.iter, sub, fi)
                self._bind_target(gen.target, UNKNOWN, sub, fi)
                for cond in gen.ifs:
                    self.eval(cond, sub, fi)
            if isinstance(node, ast.DictComp):
                self.eval(node.key, sub, fi)
                self.eval(node.value, sub, fi)
            else:
                self.eval(node.elt, sub, fi)  # body once (per-bucket unit)
            return UNKNOWN
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if k is not None:
                    self.eval(k, env, fi)
            for v in node.values:
                self.eval(v, env, fi)
            return UNKNOWN
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    self.eval(v.value, env, fi)
            return UNKNOWN
        if isinstance(node, (ast.Await, ast.YieldFrom)):
            return self.eval(node.value, env, fi)
        return UNKNOWN

    def _eval_ifexp(self, node: ast.IfExp, env: dict, fi: FunctionInfo) -> _V:
        t = _truth(self.eval(node.test, env, fi))
        if t is True:
            return self.eval(node.body, env, fi)
        if t is False:
            return self.eval(node.orelse, env, fi)
        base = self._snap()
        v1 = self.eval(node.body, env, fi)
        s1, c1 = self._score(base), self._snap()
        self._restore(base)
        v2 = self.eval(node.orelse, env, fi)
        s2 = self._score(base)
        if s1 > s2:
            self._restore(c1)
            return v1 if v1 == v2 else UNKNOWN
        return v2 if v1 == v2 else UNKNOWN

    def _eval_boolop(self, node: ast.BoolOp, env: dict,
                     fi: FunctionInfo) -> _V:
        is_and = isinstance(node.op, ast.And)
        last: _V = UNKNOWN
        unknown = False
        for v_expr in node.values:
            v = self.eval(v_expr, env, fi)
            t = _truth(v)
            if t is None:
                unknown = True
            elif is_and and not t:
                return FALSE  # short-circuit (matches runtime evaluation)
            elif not is_and and t:
                return v
            last = v
        return UNKNOWN if unknown else last

    def _eval_compare(self, node: ast.Compare, env: dict,
                      fi: FunctionInfo) -> _V:
        left = self.eval(node.left, env, fi)
        rights = [self.eval(c, env, fi) for c in node.comparators]
        if len(node.ops) == 1 and isinstance(node.ops[0], (ast.Is, ast.IsNot)):
            ln, rn = _is_none(left), _is_none(rights[0])
            # the `x is [not] None` idiom: one side is a known None
            hit = ln if rn is True else (rn if ln is True else None)
            if hit is not None:
                if isinstance(node.ops[0], ast.IsNot):
                    hit = not hit
                return TRUE if hit else FALSE
        return UNKNOWN

    # -- calls --------------------------------------------------------------
    def _eval_call(self, node: ast.Call, env: dict, fi: FunctionInfo) -> _V:
        args = [self.eval(a, env, fi) for a in node.args]
        kwargs = {}
        for kw in node.keywords:
            v = self.eval(kw.value, env, fi)
            if kw.arg is not None:
                kwargs[kw.arg] = v
        func = node.func
        if isinstance(func, ast.Attribute):
            base = self.eval(func.value, env, fi)
            if isinstance(base, VRef):
                return self._resolve_call(f"{base.dotted}.{func.attr}",
                                          args, kwargs, fi)
            if func.attr in _RETURNS_RECEIVER:
                return base
            if isinstance(base, VInstance):
                bound = base.attrs.get(func.attr)
                if bound is not None:
                    return self._call_value(bound, args, kwargs, fi)
                meth = self.graph.lookup_method(base.cls, func.attr)
                if meth is not None:
                    return self.call_function(meth, base, args, kwargs)
                # method on a known instance the graph can't see (the
                # pseudo delta view's device_cm/base_tomb_dev/host_ctx):
                # cost-free, but definitely not None
                return OPAQUE
            return UNKNOWN
        if isinstance(func, ast.Name):
            if func.id in env:
                return self._call_value(env[func.id], args, kwargs, fi)
            return self._resolve_call(func.id, args, kwargs, fi)
        return self._call_value(self.eval(func, env, fi), args, kwargs, fi)

    def _call_value(self, v: _V, args: list, kwargs: dict,
                    fi: FunctionInfo) -> _V:
        if isinstance(v, VFunc):
            return self.call_closure(v, args, kwargs)
        if isinstance(v, VFactory):
            return VInstance(v.cls, {})
        if isinstance(v, VRef):
            return self._resolve_call(v.dotted, args, kwargs, fi)
        return UNKNOWN

    def _resolve_call(self, dotted: str, args: list, kwargs: dict,
                      fi: FunctionInfo) -> _V:
        op = self.graph.counted_op(fi.module, dotted)
        if op is not None:
            self.launches[op] += 1
            return OPAQUE  # an in-flight device payload — non-None
        if self.graph.is_device_get(fi.module, dotted):
            self.host_syncs += 1
            return OPAQUE
        short = dotted.rsplit(".", 1)[-1]
        if short in _RETURNS_ARG0:
            return args[0] if args else kwargs.get("spec", UNKNOWN)
        q = self.graph.resolve(fi.module, dotted)
        if q is not None:
            target = self.graph.functions.get(q)
            if target is not None:
                return self.call_function(target, None, args, kwargs)
            if q in self.graph.classes:
                return VInstance(q, {})
        return UNKNOWN


# -- entry-point configuration ------------------------------------------------
# Adapter receiver bindings: ``self.<attr>`` types the call graph cannot
# infer (``self._scan = scan`` with an unannotated parameter). This is
# config, not inference — wrong entries here produce a certificate the
# runtime cross-validation test rejects.

_SCAN = "repro.core.scan.ColumnarScan"
_INDEX = "repro.core.blockindex.BlockedIndex"
_VAFILE = "repro.core.vafile.VAFile"

PATH_ENTRIES: dict[str, tuple[str, dict]] = {
    "scan": ("repro.core.paths.ColumnarScanPath",
             {"_scan": ("inst", _SCAN)}),
    "scan_vertical": ("repro.core.paths.VerticalScanPath",
                      {"_scan_ref": ("factory", _SCAN)}),
    "kdtree": ("repro.core.paths.BlockedIndexPath",
               {"_index": ("inst", _INDEX)}),
    "rstar": ("repro.core.paths.BlockedIndexPath",
              {"_index": ("inst", _INDEX)}),
    "vafile": ("repro.core.paths.VAFilePath",
               {"_vafile": ("inst", _VAFILE)}),
}

ENGINE_CLASS = "repro.core.engine.MDRQEngine"
PENDING_CLASS = "repro.core.engine.PendingBatch"
SERVER_CLASS = "repro.serve.pipeline.PipelinedMDRQServer"


def _receivers(spec: dict) -> dict:
    out = {}
    for attr, (kind, cls) in spec.items():
        out[attr] = VInstance(cls, {}) if kind == "inst" else VFactory(cls)
    return out


def _delta_view() -> VInstance:
    # The live-delta context: a non-empty DeltaView. ``is_empty`` is the one
    # attribute the launch paths branch on; its device-array methods come
    # back OPAQUE (non-None) from the interpreter's instance-method fallback.
    return VInstance("__delta__", {"is_empty": FALSE})


def _walk_method(graph: CallGraph, cls_qual: str, method: str,
                 receivers: dict, kwargs: dict) -> dict:
    fi = graph.lookup_method(cls_qual, method)
    if fi is None:
        raise BudgetError(f"entry point {cls_qual}.{method} not found — "
                          "PATH_ENTRIES config has drifted from the source")
    it = _Interp(graph)
    it.call_function(fi, VInstance(cls_qual, dict(receivers)), [OPAQUE],
                     dict(kwargs))
    return {"launches": dict(sorted(it.launches.items())),
            "host_syncs": it.host_syncs}


def certify(graph: CallGraph) -> dict:
    """Derive the whole budget certificate from the call graph."""
    paths: dict = {}
    for name, (cls_qual, recv_spec) in sorted(PATH_ENTRIES.items()):
        entry: dict = {}
        for ctx_name, delta in (("frozen", NONE), ("delta", _delta_view())):
            recv = _receivers(recv_spec)
            total = _walk_method(graph, cls_qual, "query_batch", recv,
                                 {"spec": OPAQUE, "delta": delta})
            stage = _walk_method(graph, cls_qual, "launch_batch", recv,
                                 {"spec": OPAQUE, "delta": delta})
            entry[ctx_name] = {
                "total": total,
                "device_stage": stage,
                "finalize_host_syncs":
                    total["host_syncs"] - stage["host_syncs"],
            }
        paths[name] = entry

    engine = {
        # The engine is pure routing: certified to add zero launches/syncs
        # of its own — every counted op in a batch is attributable to the
        # bucket's access path (the per-path table above).
        "MDRQEngine.launch_batch": _walk_method(
            graph, ENGINE_CLASS, "launch_batch", {}, {}),
        "MDRQEngine.query_batch": _walk_method(
            graph, ENGINE_CLASS, "query_batch", {}, {}),
        # Host stage of the split protocol: one counted sync per bucket
        # (the interpreter's loop unit IS the bucket).
        "PendingBatch.finalize": {"per_bucket": _walk_method(
            graph, PENDING_CLASS, "finalize", {}, {})},
    }

    serve = {
        # Both pipelined stages certified sync-free in their own frame: the
        # device stage (flush) only launches via engine.launch_batch; the
        # finalizer thread's syncs are PendingBatch.finalize's per-bucket
        # cost, accounted above.
        "PipelinedMDRQServer.flush": _walk_method(
            graph, SERVER_CLASS, "flush",
            {"engine": VInstance(ENGINE_CLASS, {})}, {}),
        "PipelinedMDRQServer._finalize_loop": _walk_method(
            graph, SERVER_CLASS, "_finalize_loop",
            {"engine": VInstance(ENGINE_CLASS, {})}, {}),
    }

    return {
        "_comment": (
            "Statically certified per-batch-window launch/host-sync budgets "
            "(analysis.budget over the project call graph; stdlib-ast only). "
            "Regenerate with `make budget-cert`; CI diffs this file — a "
            "budget change must ship with its regenerated certificate. The "
            "runtime cross-validation test asserts these numbers equal the "
            "mdrq_launches_total counter deltas for every warm path."),
        "unit": "per bucket (one fused launch group) per batch window",
        "paths": paths,
        "engine": engine,
        "serve": serve,
    }


def render(cert: dict) -> str:
    return json.dumps(cert, indent=2, sort_keys=True) + "\n"


def diff_certificate(old: dict, new: dict) -> list[str]:
    """Human-readable leaf-level differences (old -> new)."""
    out: list[str] = []

    def walk(a, b, path):
        if isinstance(a, dict) and isinstance(b, dict):
            for k in sorted(set(a) | set(b)):
                walk(a.get(k), b.get(k), f"{path}.{k}" if path else k)
        elif a != b:
            out.append(f"{path}: {a!r} -> {b!r}")
    walk(old, new, "")
    return out


def check(graph: CallGraph, path: Path) -> list[str]:
    """Diff the checked-in certificate against a fresh derivation."""
    if not path.exists():
        return [f"{path}: missing — run `make budget-cert`"]
    on_disk = json.loads(path.read_text())
    return diff_certificate(on_disk, certify(graph))
