"""The six mdrqlint rules (DESIGN.md §12).

Each rule encodes an invariant this repo's perf/correctness story depends on:

==================  =========================================================
rule id             invariant
==================  =========================================================
host-sync           device->host transfers route through ``ops.device_get``
                    (counted), never raw ``np.asarray``/``float``/``int``/
                    ``bool``/``.item`` coercions of device values or raw
                    ``jax.device_get``/``block_until_ready``
uncounted-launch    ``jax.jit``/``pallas_call`` entry points in ``kernels/``
                    and ``core/`` are registered via ``ops.counted``
raw-shard-map       ``shard_map`` only via ``core.distributed
                    .shard_map_compat`` (ROADMAP standing rule)
sentinel            no hardcoded ``3e38``-family extrema / ``inf``-into-
                    unknown-dtype casts; use ``repro.numerics`` or
                    ``core.types.finite_query_bounds``
lock-discipline     attrs ever written under ``self._lock``/``_ingest_lock``
                    are never written off-lock (outside ``__init__``);
                    ``_state`` swaps are single assignments under the ingest
                    lock; ``_state`` is never mutated in place
registry-hygiene    ``@register_result_spec`` classes are frozen dataclasses
                    (they ride jit static args); registry classes carry no
                    mutable class-level defaults
==================  =========================================================

The host-sync rule is a *taint-lite* dataflow pass: device values enter a
function through counted ``ops.*`` calls, jit-bound callables (including
``self.fn = jax.jit(...)`` attributes), bare ``pallas_call``, or functions
that return tainted values; taint propagates through assignment/unpacking/
subscripts/arithmetic and through calls carrying tainted arguments;
``ops.device_get`` launders taint (it *is* the counted sync).

v2 (whole-program): with a ``ProjectContext`` present (the runner always
builds one), tainted-returning functions are computed as a *project-wide*
fixpoint over the call graph — a device value returned by
``core.scan.ColumnarScan.launch_batch`` stays tainted through a ``serve/``
helper that calls it, aliased imports (``from repro.kernels import ops as
o``) resolve to the counted registry, and ``self.<attr>.method(...)`` calls
resolve through inferred attribute types. Per-file analysis remains the
fallback when no project is attached.

Three kernel-contract rules (``kernel-tile``, ``kernel-dtype``,
``note-trace``) live in ``analysis.contracts`` and are re-exported through
``ALL_RULES`` here.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.engine import (FileContext, Finding, ProjectContext,
                                   Rule)

# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def _dotted(node: Optional[ast.AST]) -> Optional[str]:
    """'jax.jit' for Attribute chains, 'x' for Name, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _is_jit_expr(node: ast.AST) -> bool:
    """True for ``jax.jit(...)`` and ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return False
    f = _dotted(node.func)
    if f in ("jax.jit", "jit"):
        return True
    if f in ("functools.partial", "partial") and node.args:
        return _dotted(node.args[0]) in ("jax.jit", "jit")
    return False


def _has_jit_decorator(fn: ast.AST) -> bool:
    for d in getattr(fn, "decorator_list", []):
        if _dotted(d) in ("jax.jit", "jit") or _is_jit_expr(d):
            return True
    return False


_COUNTED_NAMES = {"counted", "_counted", "ops.counted"}


def _counted_wrapped_names(tree: ast.AST) -> set[str]:
    """Names F registered by ``counted(...)(F)`` / ``@counted(...)`` forms."""
    out: set[str] = set()
    for node in ast.walk(tree):
        # X = counted("name", "doc")(F)  /  bare  counted(...)(F)
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Call)
                and _dotted(node.func.func) in _COUNTED_NAMES):
            for a in node.args:
                n = _dotted(a)
                if n:
                    out.add(n)
        # @counted("name", "doc") decorator on a def
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for d in node.decorator_list:
                if (isinstance(d, ast.Call)
                        and _dotted(d.func) in _COUNTED_NAMES):
                    out.add(node.name)
    return out


def _in_repro(posix: str) -> bool:
    return "/repro/" in posix or posix.startswith("repro/")


def _in_sync_scope(posix: str) -> bool:
    """host-sync scope: the package plus the driver trees that consume it —
    an uncounted coercion in ``benchmarks/`` corrupts the very numbers the
    benchmark reports, so the rule covers them too."""
    if _in_repro(posix):
        return True
    return any(f"/{root}/" in posix or posix.startswith(f"{root}/")
               for root in ("benchmarks", "examples"))


# ---------------------------------------------------------------------------
# rule 1: host-sync — taint-lite device->host coercion check
# ---------------------------------------------------------------------------

# ops.* helpers that return HOST data (or are pure bookkeeping): calls to
# these are not device-value sources, and device_get launders taint.
_OPS_HOST_FNS = {"device_get", "counter", "counters", "reset_counters",
                 "use_xla", "set_backend", "default_interpret", "counted",
                 "note_trace", "trace_log", "reset_trace_log", "aot_capture",
                 "aot_cache_size", "aot_cache_keys", "clear_aot_cache",
                 "aot_counters"}
_RAW_SYNC_FNS = {"jax.device_get", "jax.block_until_ready"}
_CAST_SINKS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "float", "int", "bool"}


class _FnTaint:
    """One function's taint pass: flags sinks fed by device values."""

    def __init__(self, rule: "HostSyncRule", ctx: FileContext,
                 jit_names: set[str], jit_attrs: set[str],
                 tainted_returning: set[str], collect_only: bool,
                 xmod: "Optional[_CrossModule]" = None):
        self.rule = rule
        self.ctx = ctx
        self.jit_names = jit_names
        self.jit_attrs = jit_attrs
        self.tainted_returning = tainted_returning
        self.collect_only = collect_only
        self.xmod = xmod
        self.tainted: set[str] = set()
        self.returns_tainted = False
        self.findings: list[Finding] = []

    # -- statements ---------------------------------------------------------
    def run(self, fn: ast.AST) -> None:
        body = getattr(fn, "body", [])
        # two passes: monotone taint set converges for use-before-def within
        # loops; findings only recorded on the second pass
        self.collecting = True
        self.block(body)
        self.collecting = False
        if not self.collect_only:
            self.block(body)

    def block(self, stmts: list[ast.stmt]) -> None:
        for s in stmts:
            self.stmt(s)

    def stmt(self, s: ast.stmt) -> None:
        if isinstance(s, ast.Assign):
            t = self.expr(s.value)
            for tgt in s.targets:
                self.bind(tgt, t)
        elif isinstance(s, ast.AugAssign):
            t = self.expr(s.value) or self.expr(s.target)
            self.bind(s.target, t)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self.bind(s.target, self.expr(s.value))
        elif isinstance(s, ast.Return):
            if s.value is not None and self.expr(s.value):
                self.returns_tainted = True
        elif isinstance(s, ast.Expr):
            self.expr(s.value)
        elif isinstance(s, (ast.If, ast.While)):
            self.expr(s.test)
            self.block(s.body)
            self.block(s.orelse)
        elif isinstance(s, ast.For):
            if self.expr(s.iter):
                self.bind(s.target, True)
            self.block(s.body)
            self.block(s.orelse)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                t = self.expr(item.context_expr)
                if item.optional_vars is not None:
                    self.bind(item.optional_vars, t)
            self.block(s.body)
        elif isinstance(s, ast.Try):
            self.block(s.body)
            for h in s.handlers:
                self.block(h.body)
            self.block(s.orelse)
            self.block(s.finalbody)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            pass  # nested scopes analyzed separately
        else:
            for child in ast.iter_child_nodes(s):
                if isinstance(child, ast.expr):
                    self.expr(child)

    def bind(self, tgt: ast.AST, tainted: bool) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self.bind(e, tainted)
            return
        if isinstance(tgt, ast.Starred):
            self.bind(tgt.value, tainted)
            return
        name = _dotted(tgt)
        if tainted and name:
            self.tainted.add(name)

    # -- expressions --------------------------------------------------------
    def flag(self, node: ast.AST, message: str) -> None:
        if not self.collecting and not self.collect_only:
            self.findings.append(self.rule.finding(self.ctx, node, message))

    def expr(self, e: Optional[ast.AST]) -> bool:
        if e is None or isinstance(e, ast.Constant):
            return False
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            d = _dotted(e)
            return self.expr(e.value) or (d in self.tainted)
        if isinstance(e, ast.Call):
            return self.call(e)
        if isinstance(e, ast.Lambda):
            return False  # opaque; bodies get no device values in this repo
        # generic: any tainted child taints the expression
        t = False
        for child in ast.iter_child_nodes(e):
            if isinstance(child, (ast.expr, ast.comprehension, ast.keyword,
                                  ast.arguments)):
                t = self.expr_child(child) or t
        return t

    def expr_child(self, child: ast.AST) -> bool:
        if isinstance(child, ast.keyword):
            return self.expr(child.value)
        if isinstance(child, ast.comprehension):
            t = self.expr(child.iter)
            if t:
                self.bind(child.target, True)
            for cond in child.ifs:
                self.expr(cond)
            return t
        if isinstance(child, ast.arguments):
            return False
        return self.expr(child)

    def call(self, e: ast.Call) -> bool:
        fname = _dotted(e.func) or ""
        short = fname.rsplit(".", 1)[-1]

        # blessed: the counted sync returns host data and launders taint —
        # under any alias ("device_get" is unambiguous in this codebase)
        if short == "device_get" and not fname.startswith("jax"):
            for a in list(e.args) + [k.value for k in e.keywords]:
                self.expr(a)
            return False

        # raw sync APIs: always a finding in scoped files
        if fname in _RAW_SYNC_FNS:
            self.flag(e, f"raw {fname} — route device->host reads through "
                         "ops.device_get so the sync is counted")
        if isinstance(e.func, ast.Attribute) \
                and e.func.attr == "block_until_ready":
            self.flag(e, "raw .block_until_ready() — use ops.device_get "
                         "(or obs.tracing spans) so the sync is counted")

        args_tainted = any(self.expr(a) for a in e.args) | \
            any(self.expr(k.value) for k in e.keywords)
        base_tainted = (isinstance(e.func, ast.Attribute)
                        and self.expr(e.func.value))

        # sinks: host coercions of device values
        if fname in _CAST_SINKS and args_tainted:
            self.flag(e, f"uncounted host sync: {short}() coerces a device "
                         "value — use ops.device_get")
        if isinstance(e.func, ast.Attribute) and e.func.attr == "item" \
                and base_tainted:
            self.flag(e, "uncounted host sync: .item() on a device value — "
                         "use ops.device_get")

        # sources: counted kernel entry points and jit-bound callables
        source = False
        if fname.startswith("ops.") and short not in _OPS_HOST_FNS:
            source = True
        elif fname in self.jit_names or fname in self.tainted_returning:
            source = True
        elif isinstance(e.func, ast.Attribute) \
                and e.func.attr in (self.jit_attrs | self.tainted_returning):
            source = True
        elif short == "pallas_call" or (isinstance(e.func, ast.Call)
                                        and self.expr(e.func)):
            source = True
        elif self.xmod is not None and self.xmod.is_source(fname):
            source = True
        return source or args_tainted or base_tainted


def _module_jit_sets(tree: ast.AST) -> tuple[set[str], set[str]]:
    """(jit-bound names, jit-bound self attrs) for one module tree."""
    jit_names: set[str] = set()   # module-level jit-bound callables
    jit_attrs: set[str] = set()   # self.<attr> = jax.jit(...) anywhere
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _has_jit_decorator(node):
                jit_names.add(node.name)
        elif isinstance(node, ast.Assign) and _is_jit_expr(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    jit_names.add(tgt.id)
                elif isinstance(tgt, ast.Attribute):
                    jit_attrs.add(tgt.attr)
    return jit_names, jit_attrs


def _functions_with_class(tree: ast.AST) -> list[tuple[ast.AST,
                                                       Optional[str]]]:
    """Every function def in the tree, with its immediate owning class."""
    out: list[tuple[ast.AST, Optional[str]]] = []
    method_ids = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out.append((item, node.name))
                    method_ids.add(id(item))
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and id(node) not in method_ids:
            out.append((node, None))
    return out


class _CrossModule:
    """Cross-module source oracle for ``_FnTaint`` (project runs only).

    Resolves a call's dotted name through the project call graph: counted-op
    registrations under any import alias, project functions in the tainted-
    returning fixpoint set, and ``self.<attr>.method(...)`` receivers via
    inferred attribute types.
    """

    def __init__(self, graph, module: str, cls: Optional[str],
                 tainted_quals: set[str]):
        self.graph = graph
        self.module = module
        self.cls = cls
        self.tainted_quals = tainted_quals

    def is_source(self, fname: str) -> bool:
        if not fname:
            return False
        if fname.startswith("self."):
            return self._self_call(fname[len("self."):])
        q = self.graph.resolve(self.module, fname)
        if q is None:
            return False
        return q in self.graph.counted_ops or q in self.tainted_quals

    def _self_call(self, rest: str) -> bool:
        if self.cls is None:
            return False
        cq = f"{self.module}.{self.cls}"
        head, _, meth = rest.partition(".")
        if not meth:   # self.method()
            hit = self.graph.lookup_method(cq, head)
            return hit is not None and hit.qual in self.tainted_quals
        if "." in meth:
            return False
        ci = self.graph.classes.get(cq)
        if ci is None or head not in ci.attr_types:
            return False
        hit = self.graph.lookup_method(ci.attr_types[head], meth)
        return hit is not None and hit.qual in self.tainted_quals


def project_tainted_quals(project: ProjectContext) -> set[str]:
    """Project-wide fixpoint: quals of functions returning device values.

    Cached on the ProjectContext — computed once per run, shared by every
    file's host-sync pass. Monotone (the set only grows), so the sweep
    converges; 6 rounds bounds the deepest cross-module return chain in
    this tree with slack.
    """
    cached = project.cache.get("host_sync_tainted")
    if cached is not None:
        return cached
    graph = project.graph
    rule = HostSyncRule()
    mods = []
    for fctx in project.files:
        mod = graph.modules.get(fctx.module)
        if mod is None:
            continue
        jn, ja = _module_jit_sets(fctx.tree)
        mods.append((fctx, mod, jn, ja, _functions_with_class(fctx.tree)))
    tainted: set[str] = set()
    for _ in range(6):
        changed = False
        for fctx, mod, jn, ja, fns in mods:
            local = {q.rsplit(".", 1)[-1] for q in tainted
                     if q.startswith(mod.name + ".")}
            for fn, cls in fns:
                prefix = f"{mod.name}.{cls}." if cls else f"{mod.name}."
                qual = prefix + fn.name
                if qual in tainted:
                    continue
                xmod = _CrossModule(graph, mod.name, cls, tainted)
                t = _FnTaint(rule, fctx, jn, ja, local, collect_only=True,
                             xmod=xmod)
                t.run(fn)
                if t.returns_tainted:
                    tainted.add(qual)
                    changed = True
        if not changed:
            break
    project.cache["host_sync_tainted"] = tainted
    return tainted


class HostSyncRule(Rule):
    rule_id = "host-sync"
    doc = ("Device->host transfers must route through ops.device_get so the "
           "launch/host-sync counters (and span attribution) stay exact. "
           "Whole-program: taint follows returns across module boundaries.")

    _ALLOWLIST = ("kernels/ops.py",   # the accounting home itself
                  "obs/tracing.py")   # span exit's sanctioned sync

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_sync_scope(ctx.posix) or "/analysis/" in ctx.posix:
            return []
        if any(ctx.posix.endswith(a) for a in self._ALLOWLIST):
            return []

        jit_names, jit_attrs = _module_jit_sets(ctx.tree)
        fns = _functions_with_class(ctx.tree)
        functions = [fn for fn, _ in fns]

        if ctx.project is not None:
            graph = ctx.project.graph
            modname = ctx.module
            quals = project_tainted_quals(ctx.project)
            tainted_returning = {q.rsplit(".", 1)[-1] for q in quals
                                 if q.startswith(modname + ".")}
            findings: list[Finding] = []
            for fn, cls in fns:
                xmod = _CrossModule(graph, modname, cls, quals)
                t = _FnTaint(self, ctx, jit_names, jit_attrs,
                             tainted_returning, collect_only=False,
                             xmod=xmod)
                t.run(fn)
                findings.extend(t.findings)
            return findings

        # fallback: same-module-only analysis (no project attached)
        tainted_returning = set()
        for _ in range(2):  # one refinement round catches chained returns
            for fn in functions:
                t = _FnTaint(self, ctx, jit_names, jit_attrs,
                             tainted_returning, collect_only=True)
                t.run(fn)
                if t.returns_tainted:
                    tainted_returning.add(fn.name)
        findings = []
        for fn in functions:
            t = _FnTaint(self, ctx, jit_names, jit_attrs,
                         tainted_returning, collect_only=False)
            t.run(fn)
            findings.extend(t.findings)
        return findings


# ---------------------------------------------------------------------------
# rule 2: uncounted-launch
# ---------------------------------------------------------------------------

class UncountedLaunchRule(Rule):
    rule_id = "uncounted-launch"
    doc = ("jax.jit / pallas_call entry points in kernels/ and core/ must be "
           "registered via ops.counted so launch budgets stay assertable.")

    def check(self, ctx: FileContext) -> list[Finding]:
        if not ("/repro/kernels/" in ctx.posix or "/repro/core/" in ctx.posix
                or ctx.posix.startswith(("repro/kernels/", "repro/core/"))):
            return []
        registered = _counted_wrapped_names(ctx.tree)
        findings: list[Finding] = []
        for node in ctx.tree.body:  # module-level entry points only
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and _has_jit_decorator(node) \
                    and node.name not in registered:
                findings.append(self.finding(
                    ctx, node, f"jit entry point '{node.name}' is not "
                    "registered via ops.counted — its launches are invisible "
                    "to the counter budget"))
            elif isinstance(node, ast.Assign) and _is_jit_expr(node.value):
                for tgt in node.targets:
                    name = _dotted(tgt)
                    if name and name not in registered:
                        findings.append(self.finding(
                            ctx, node, f"jit binding '{name}' is not "
                            "registered via ops.counted — its launches are "
                            "invisible to the counter budget"))
        # bare pallas_call in core/ (kernel *impl* modules in kernels/ are
        # the sanctioned place to build pallas_call wrappers for ops.py)
        if "/core/" in ctx.posix or ctx.posix.startswith("repro/core/"):
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Call) \
                        and (_dotted(node.func) or "").endswith("pallas_call"):
                    findings.append(self.finding(
                        ctx, node, "bare pallas_call in core/ — wrap it in a "
                        "kernels/ module and register via ops.counted"))
        return findings


# ---------------------------------------------------------------------------
# rule 3: raw-shard-map
# ---------------------------------------------------------------------------

class RawShardMapRule(Rule):
    rule_id = "raw-shard-map"
    doc = ("shard_map only via core.distributed.shard_map_compat (ROADMAP "
           "standing rule: it papers over jax.shard_map API drift).")

    _MSG = ("raw shard_map — use core.distributed.shard_map_compat "
            "(handles the jax.shard_map / jax.experimental.shard_map drift)")

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_repro(ctx.posix) \
                or ctx.posix.endswith("core/distributed.py"):
            return []
        findings = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if "shard_map" in mod or any("shard_map" == a.name
                                             for a in node.names):
                    findings.append(self.finding(ctx, node, self._MSG))
            elif isinstance(node, ast.Import):
                if any("shard_map" in a.name for a in node.names):
                    findings.append(self.finding(ctx, node, self._MSG))
            elif isinstance(node, ast.Attribute) and node.attr == "shard_map":
                base = _dotted(node.value) or ""
                if base.startswith("jax"):
                    findings.append(self.finding(ctx, node, self._MSG))
        return findings


# ---------------------------------------------------------------------------
# rule 4: sentinel
# ---------------------------------------------------------------------------

_CAST_FNS = {"jnp.asarray", "jnp.array", "jnp.full", "jnp.full_like",
             "np.full", "np.full_like"}
_WIDE_DTYPES = {"np.float32", "jnp.float32", "np.float64", "jnp.float64",
                "float", "F32", "F64", "FLOAT32", "FLOAT64"}
_INF_NAMES = {"np.inf", "jnp.inf", "math.inf", "inf"}


def _is_inf_expr(e: ast.AST) -> bool:
    if isinstance(e, ast.UnaryOp) and isinstance(e.op, (ast.USub, ast.UAdd)):
        return _is_inf_expr(e.operand)
    if _dotted(e) in _INF_NAMES:
        return True
    if isinstance(e, ast.Call) and _dotted(e.func) == "float" and e.args:
        a = e.args[0]
        return isinstance(a, ast.Constant) and isinstance(a.value, str) \
            and "inf" in a.value
    return False


class SentinelRule(Rule):
    rule_id = "sentinel"
    doc = ("No hardcoded 3e38-family extrema and no inf into unknown-dtype "
           "casts: f32 extrema round to +-inf under bf16 casts (PR 3 bug). "
           "Use repro.numerics / core.types.finite_query_bounds.")

    _LIMIT = 1e30

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_repro(ctx.posix) or "/analysis/" in ctx.posix \
                or ctx.posix.endswith("repro/numerics.py"):
            return []
        findings = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Constant) \
                    and isinstance(node.value, float) \
                    and abs(node.value) >= self._LIMIT \
                    and node.value == node.value:  # not NaN
                findings.append(self.finding(
                    ctx, node, f"hardcoded extreme literal {node.value!r} — "
                    "derive it from the target dtype via repro.numerics "
                    "(finite_min/finite_max/mask_fill); f32-scale extrema "
                    "round to inf under bf16 casts"))
            elif isinstance(node, ast.Call) \
                    and _dotted(node.func) in _CAST_FNS:
                vals = list(node.args) + [k.value for k in node.keywords]
                if not any(_is_inf_expr(v) for v in vals):
                    continue
                dtypes = [_dotted(v) for v in vals]
                if not any(d in _WIDE_DTYPES for d in dtypes if d):
                    findings.append(self.finding(
                        ctx, node, "inf cast into a non-explicit dtype — "
                        "under bf16 this may stay inf where a finite "
                        "sentinel was intended; use repro.numerics or "
                        "core.types.finite_query_bounds"))
        return findings


# ---------------------------------------------------------------------------
# rule 5: lock-discipline
# ---------------------------------------------------------------------------

def _lockish(ctx: FileContext, w: ast.With, needle: str = "_lock") -> bool:
    return any(needle in (ctx.segment(item.context_expr) or "")
               for item in w.items)


class LockDisciplineRule(Rule):
    rule_id = "lock-discipline"
    doc = ("Attributes ever written under self._lock/_ingest_lock are "
           "lock-guarded: off-lock writes (outside __init__) race the "
           "mutable plane. _state swaps must be one assignment under the "
           "ingest lock; _state is never mutated in place.")

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_repro(ctx.posix) or "/analysis/" in ctx.posix:
            return []
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(self._check_class(ctx, node))
        findings.extend(self._check_state_swaps(ctx))
        return findings

    # -- guarded attribute writes ------------------------------------------
    def _attr_writes(self, fn: ast.AST, ctx: FileContext
                     ) -> list[tuple[str, ast.AST, bool]]:
        """(attr, node, under_lock) for every ``self.X = ...`` write."""
        out: list[tuple[str, ast.AST, bool]] = []

        def walk(stmts, under):
            for s in stmts:
                if isinstance(s, ast.With):
                    walk(s.body, under or _lockish(ctx, s))
                    continue
                if isinstance(s, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    targets = (s.targets if isinstance(s, ast.Assign)
                               else [s.target])
                    for tgt in targets:
                        parts = (tgt.elts
                                 if isinstance(tgt, (ast.Tuple, ast.List))
                                 else [tgt])
                        for t in parts:
                            base = t
                            if isinstance(base, ast.Subscript):
                                base = base.value
                            if isinstance(base, ast.Attribute) \
                                    and isinstance(base.value, ast.Name) \
                                    and base.value.id == "self":
                                out.append((base.attr, s, under))
                for name in ("body", "orelse", "finalbody"):
                    walk(getattr(s, name, []) or [], under)
                for h in getattr(s, "handlers", []) or []:
                    walk(h.body, under)
        walk(getattr(fn, "body", []), False)
        return out

    def _check_class(self, ctx: FileContext, cls: ast.ClassDef
                     ) -> list[Finding]:
        methods = [n for n in cls.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        writes = {m.name: self._attr_writes(m, ctx) for m in methods}
        guarded = {attr for ws in writes.values()
                   for attr, _, under in ws if under}
        findings = []
        for name, ws in writes.items():
            if name == "__init__":
                continue
            for attr, node, under in ws:
                if attr in guarded and not under:
                    findings.append(self.finding(
                        ctx, node, f"'{cls.name}.{attr}' is written under a "
                        "lock elsewhere but mutated here off-lock — this "
                        "races the guarded mutable plane"))
        return findings

    # -- _state swap discipline --------------------------------------------
    def _check_state_swaps(self, ctx: FileContext) -> list[Finding]:
        findings = []

        def walk(stmts, under_ingest, in_init):
            for s in stmts:
                if isinstance(s, ast.With):
                    walk(s.body,
                         under_ingest or _lockish(ctx, s, "_ingest_lock"),
                         in_init)
                    continue
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    walk(s.body, False, s.name == "__init__")
                    continue
                if isinstance(s, ast.ClassDef):
                    walk(s.body, False, False)
                    continue
                if isinstance(s, ast.Assign):
                    for tgt in s.targets:
                        # X._state.attr = v  /  X._state.d[k] = v: in-place
                        base = tgt
                        if isinstance(base, ast.Subscript):
                            base = base.value
                        inner = base.value if isinstance(base, ast.Attribute) \
                            else None
                        if isinstance(inner, ast.Attribute) \
                                and inner.attr == "_state":
                            findings.append(self.finding(
                                ctx, s, "in-place mutation of _state — "
                                "engine state is immutable; build a new "
                                "state and swap it in one assignment"))
                        # X._state = v: must be a lone swap under the lock
                        elif isinstance(base, ast.Attribute) \
                                and base.attr == "_state":
                            if len(s.targets) != 1 \
                                    or isinstance(tgt, (ast.Tuple, ast.List)):
                                findings.append(self.finding(
                                    ctx, s, "_state swap must be a single "
                                    "plain assignment (readers snapshot it "
                                    "lock-free)"))
                            elif not (under_ingest or in_init):
                                findings.append(self.finding(
                                    ctx, s, "_state swap outside the ingest "
                                    "lock — concurrent writers can "
                                    "interleave stale states"))
                for name in ("body", "orelse", "finalbody"):
                    walk(getattr(s, name, []) or [], under_ingest, in_init)
                for h in getattr(s, "handlers", []) or []:
                    walk(h.body, under_ingest, in_init)
        walk(ctx.tree.body, False, False)
        return findings


# ---------------------------------------------------------------------------
# rule 6: registry-hygiene
# ---------------------------------------------------------------------------

_REGISTER_DECOS = {"register_result_spec", "register_path"}


class RegistryHygieneRule(Rule):
    rule_id = "registry-hygiene"
    doc = ("Registered ResultSpec classes must be frozen dataclasses (they "
           "ride jit static args: hashability + immutability) and registry "
           "classes must not carry mutable class-level defaults.")

    _REGISTRY_MODULES = ("core/types.py", "core/paths.py")

    def _register_deco(self, cls: ast.ClassDef) -> Optional[str]:
        for d in cls.decorator_list:
            name = _dotted(d.func if isinstance(d, ast.Call) else d) or ""
            short = name.rsplit(".", 1)[-1]
            if short in _REGISTER_DECOS:
                return short
        return None

    def _frozen_dataclass(self, cls: ast.ClassDef) -> bool:
        for d in cls.decorator_list:
            if isinstance(d, ast.Call):
                name = _dotted(d.func) or ""
                if name.rsplit(".", 1)[-1] == "dataclass":
                    for k in d.keywords:
                        if k.arg == "frozen" \
                                and isinstance(k.value, ast.Constant) \
                                and k.value.value is True:
                            return True
        return False

    def check(self, ctx: FileContext) -> list[Finding]:
        findings = []
        in_registry_module = any(ctx.posix.endswith(m)
                                 for m in self._REGISTRY_MODULES)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            deco = self._register_deco(node)
            if deco == "register_result_spec" \
                    and not self._frozen_dataclass(node):
                findings.append(self.finding(
                    ctx, node, f"'{node.name}' is registered via "
                    "register_result_spec but is not a frozen dataclass — "
                    "specs ride jit static args and must be hashable and "
                    "immutable"))
            if deco or in_registry_module:
                for stmt in node.body:
                    if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                        val = stmt.value
                        if isinstance(val, (ast.List, ast.Dict, ast.Set)):
                            findings.append(self.finding(
                                ctx, stmt, f"mutable class-level default on "
                                f"'{node.name}' — shared across every "
                                "instance (and unhashable under jit static "
                                "args); use dataclasses.field or a tuple"))
        return findings


# ---------------------------------------------------------------------------
# rule 7: thread-boundary
# ---------------------------------------------------------------------------

class ThreadBoundaryRule(Rule):
    rule_id = "thread-boundary"
    doc = ("Pipelined-serving stage discipline (DESIGN.md §13): a "
           "@device_stage function never calls ops.device_get (the counted "
           "sync belongs to the finalizer thread) and never parks a device "
           "value on self — in-flight payloads cross threads only inside a "
           "PendingBatch riding the bounded backlog queue.")

    # calls whose results carry device values in a device-stage function:
    # counted kernel entry points and the split-protocol launch
    _DEVICEY_METHODS = {"launch_batch"}

    @staticmethod
    def _stage(fn: ast.AST) -> Optional[str]:
        for d in getattr(fn, "decorator_list", []):
            name = _dotted(d) or ""
            short = name.rsplit(".", 1)[-1]
            if short == "device_stage":
                return "device"
            if short == "finalizer_stage":
                return "finalize"
        return None

    def check(self, ctx: FileContext) -> list[Finding]:
        if not _in_repro(ctx.posix) or "/analysis/" in ctx.posix:
            return []
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and self._stage(node) == "device":
                findings.extend(self._check_device(ctx, node))
        return findings

    def _check_device(self, ctx: FileContext, fn: ast.AST) -> list[Finding]:
        findings: list[Finding] = []
        tainted: set[str] = set()

        def is_tainted(e: ast.AST) -> bool:
            if isinstance(e, ast.Name):
                return e.id in tainted
            if isinstance(e, ast.Call):
                fname = _dotted(e.func) or ""
                short = fname.rsplit(".", 1)[-1]
                if fname.startswith("ops.") and short not in _OPS_HOST_FNS:
                    return True
                if short in self._DEVICEY_METHODS:
                    return True
                return (any(is_tainted(a) for a in e.args)
                        or any(is_tainted(k.value) for k in e.keywords))
            if isinstance(e, (ast.Tuple, ast.List)):
                return any(is_tainted(x) for x in e.elts)
            if isinstance(e, (ast.Attribute, ast.Subscript, ast.Starred)):
                return is_tainted(e.value)
            return False

        # two monotone passes converge name taint (use-before-def in loops)
        for _ in range(2):
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and is_tainted(node.value):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            tainted.add(tgt.id)
                        elif isinstance(tgt, (ast.Tuple, ast.List)):
                            for e in tgt.elts:
                                if isinstance(e, ast.Name):
                                    tainted.add(e.id)

        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                fname = _dotted(node.func) or ""
                if fname.rsplit(".", 1)[-1] == "device_get":
                    findings.append(self.finding(
                        ctx, node, "ops.device_get in a @device_stage "
                        "function — the counted host sync belongs to the "
                        "finalizer thread; hand the in-flight payload across "
                        "the backlog queue instead"))
            elif isinstance(node, ast.Assign):
                if not is_tainted(node.value):
                    continue
                for tgt in node.targets:
                    base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
                    if isinstance(base, ast.Attribute) \
                            and isinstance(base.value, ast.Name) \
                            and base.value.id == "self":
                        findings.append(self.finding(
                            ctx, node, f"device value parked on "
                            f"'self.{base.attr}' in a @device_stage function "
                            "— device values cross threads only through the "
                            "bounded backlog queue (put a PendingBatch, not "
                            "an attribute)"))
        return findings


# imported at the bottom: contracts.py needs the helpers defined above
from repro.analysis.contracts import CONTRACT_RULES  # noqa: E402

ALL_RULES: tuple[Rule, ...] = (
    HostSyncRule(), UncountedLaunchRule(), RawShardMapRule(), SentinelRule(),
    LockDisciplineRule(), RegistryHygieneRule(), ThreadBoundaryRule(),
) + CONTRACT_RULES
