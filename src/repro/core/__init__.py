"""repro.core — the paper's contribution: MDRQ access paths on modern hardware.

Public API:
  * types: ``RangeQuery``, ``Dataset`` + numpy oracles
  * engines: ``MDRQEngine`` (facade), ``build_columnar_scan``, ``build_kdtree``,
    ``build_rstar``, ``build_vafile``, ``DistributedScan``
  * planning: ``Planner``, ``Histograms``, ``CostModel``
"""
from repro.core.types import (Dataset, QueryBatch, RangeQuery, RESULT_MODES,
                              match_ids_np, match_mask_np)
from repro.core.engine import MDRQEngine, ALL_METHODS, BatchStats
from repro.core.scan import build_columnar_scan, build_row_scan
from repro.core.kdtree import build_kdtree
from repro.core.rstar import build_rstar
from repro.core.vafile import build_vafile
from repro.core.planner import (CalibrationFit, CalibrationReport, CostModel,
                                Histograms, Planner)
from repro.core.distributed import DistributedScan, make_data_mesh

__all__ = [
    "Dataset", "QueryBatch", "RangeQuery", "RESULT_MODES", "match_ids_np",
    "match_mask_np",
    "MDRQEngine", "ALL_METHODS", "BatchStats",
    "build_columnar_scan", "build_row_scan", "build_kdtree", "build_rstar",
    "build_vafile", "CalibrationFit", "CalibrationReport", "CostModel",
    "Histograms", "Planner",
    "DistributedScan", "make_data_mesh",
]
