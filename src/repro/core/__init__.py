"""repro.core — the paper's contribution: MDRQ access paths on modern hardware.

Public API:
  * types: ``RangeQuery``, ``Dataset`` + numpy oracles
  * result specs: ``Ids``, ``Count``, ``Mask``, ``TopK``, ``Agg``
    (``ResultSpec`` protocol + ``register_result_spec`` extension hook)
  * engines: ``MDRQEngine`` (facade/registry), ``build_columnar_scan``,
    ``build_kdtree``, ``build_rstar``, ``build_vafile``, ``DistributedScan``
  * access-path layer: ``AccessPath`` protocol + adapters (``core.paths``)
  * planning: ``Planner``, ``Histograms``, ``CostModel``, ``BatchPlan``
  * mutable plane: ``MutableDelta``, ``DeltaView``, ``Compactor``
    (``MDRQEngine.append`` / ``delete`` / ``compact``)
"""
from repro.core.types import (Agg, Count, Dataset, DeltaHostCtx, Ids, Mask,
                              QueryBatch, RangeQuery, RESULT_MODES,
                              ResultSpec, TopK, match_ids_np, match_mask_np,
                              register_result_spec, resolve_spec,
                              validate_mode)
from repro.core.delta import Compactor, DeltaView, MutableDelta
from repro.core.engine import MDRQEngine, ALL_METHODS, BatchStats
from repro.core.paths import AccessPath, PerQueryPath, PlanInputs
from repro.core.scan import build_columnar_scan, build_row_scan
from repro.core.kdtree import build_kdtree
from repro.core.rstar import build_rstar
from repro.core.vafile import build_vafile
from repro.core.planner import (BatchPlan, CalibrationFit, CalibrationReport,
                                CostModel, Histograms, Planner)
from repro.core.distributed import DistributedScan, make_data_mesh

__all__ = [
    "Dataset", "QueryBatch", "RangeQuery", "RESULT_MODES", "match_ids_np",
    "match_mask_np", "validate_mode", "resolve_spec",
    "ResultSpec", "Ids", "Count", "Mask", "TopK", "Agg", "DeltaHostCtx",
    "register_result_spec",
    "MutableDelta", "DeltaView", "Compactor",
    "MDRQEngine", "ALL_METHODS", "BatchStats",
    "AccessPath", "PerQueryPath", "PlanInputs",
    "build_columnar_scan", "build_row_scan", "build_kdtree", "build_rstar",
    "build_vafile", "BatchPlan", "CalibrationFit", "CalibrationReport",
    "CostModel", "Histograms", "Planner",
    "DistributedScan", "make_data_mesh",
]
