"""Distributed MDRQ execution — horizontal partitioning over devices.

The paper's horizontal partitioning (§3.1) assigns n/t objects to each of t
threads, runs the same search per partition, and concatenates partial results.
The TPU mapping (DESIGN.md §2): the object axis of the columnar array shards
over the ``data`` mesh axis via ``shard_map``; every device runs the identical
Pallas scan on its local (m_pad, n_pad/p) shard. The paper's "concatenate
partial result sets" becomes a no-op — the output mask inherits the input
sharding — and the only collective in the system is an optional ``psum`` for
global match counts. Load balancing is inherited from random object placement,
exactly as in the paper.

Batched execution (cross-device × multi-query): ``distributed_multi_mask`` /
``distributed_multi_counts`` wrap the fused multi-query kernels
(``kernels.multi_scan``) in the same shard_map — data sharded ``P(None,
"data")``, the (m_pad, Q) query bounds replicated — so one collective launch
answers a whole batch on every device at once. In count mode the per-device
(Q,) partial counts reduce through a single ``psum`` and only O(Q) ints ever
cross the collective *and* the host boundary. ``DistributedScan.query_batch``
buckets the query axis to pow2 exactly like ``ColumnarScan`` so both engines
share jit traces per batch-size bucket.

Instrumentation: every entry point here is registered through
``kernels.ops.counted`` and every device->host read goes through
``ops.device_get`` — the distributed path pays the same launch/host-sync
accounting the single-device ops do, so counter-based budget tests see it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import types as T
from repro.kernels import ops
from repro.kernels import multi_scan as _ms
from repro.kernels import range_scan as _rs


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map`` (with ``check_vma``); this tree's
    pinned version only has ``jax.experimental.shard_map.shard_map`` (with
    ``check_rep``). Both flags are disabled for the same reason: pallas_call
    outputs carry no replication/vma metadata.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            pass
        try:
            # intermediate versions export jax.shard_map but still spell the
            # flag check_rep — it must be disabled just the same
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_data_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over all (or the first k) local devices: axis 'data'.

    Builds ``jax.sharding.Mesh`` directly from a device ndarray — the
    ``jax.make_mesh(..., devices=list)`` path is not portable across the JAX
    versions this tree supports.
    """
    devs = jax.devices()
    k = n_devices or len(devs)
    return Mesh(np.asarray(devs[:k]), ("data",))


def shard_columnar(mesh: Mesh, padded_cols: np.ndarray, tile_n: int = 1024) -> jax.Array:
    """Place (m_pad, n_pad) columnar data sharded over objects.

    n_pad must divide by (#devices * tile_n) — callers pad with +inf sentinels
    via ``ops.prepare_columnar`` using tile_n * axis_size.
    """
    n_dev = mesh.shape["data"]
    m_pad, n_pad = padded_cols.shape
    assert n_pad % (n_dev * tile_n) == 0, (n_pad, n_dev, tile_n)
    sharding = NamedSharding(mesh, P(None, "data"))
    return jax.device_put(jnp.asarray(padded_cols), sharding)


def _local_scan(data_local, lo, up, *, tile_n: int, interpret: bool):
    """One device's full scan of its object shard (backend-dispatched)."""
    if ops.use_xla():
        from repro.kernels import ref as _ref
        return _ref.range_scan_ref(data_local, lo, up)
    return _rs.range_scan_tiles(data_local, lo, up, tile_n=tile_n,
                                interpret=interpret)


def _local_multi_scan(data_local, lo, up, *, tile_n: int, interpret: bool):
    """One device's fused multi-query scan of its shard -> (Q, n_local)."""
    if ops.use_xla():
        from repro.kernels import ref as _ref
        return _ref.multi_scan_ref(data_local, lo, up)
    return _ms.multi_scan_tiles(data_local, lo, up, tile_n=tile_n,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("mesh", "tile_n", "interpret"))
def _distributed_mask_jit(
    mesh: Mesh,
    data_sharded: jax.Array,
    qlo: jax.Array,
    qhi: jax.Array,
    *,
    tile_n: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    ops.note_trace("distributed_mask")
    if interpret is None:
        interpret = ops.default_interpret()

    def local_scan(data_local, lo, up):
        return _local_scan(data_local, lo, up, tile_n=tile_n,
                           interpret=interpret)

    fn = shard_map_compat(
        local_scan,
        mesh=mesh,
        in_specs=(P(None, "data"), P(), P()),
        out_specs=P("data"),
    )
    return fn(data_sharded, qlo, qhi)


distributed_mask = ops.counted(
    "distributed_mask",
    "Sharded single-query match mask: each device scans its own object shard "
    "-> (n_pad,) int8, output sharded over 'data'.",
)(_distributed_mask_jit)


@functools.partial(jax.jit, static_argnames=("mesh", "tile_n", "interpret"))
def _distributed_count_jit(
    mesh: Mesh,
    data_sharded: jax.Array,
    qlo: jax.Array,
    qhi: jax.Array,
    *,
    tile_n: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    ops.note_trace("distributed_count")
    if interpret is None:
        interpret = ops.default_interpret()

    def local_count(data_local, lo, up):
        mask = _local_scan(data_local, lo, up, tile_n=tile_n,
                           interpret=interpret)
        return jax.lax.psum(mask.astype(jnp.int32).sum(), "data")

    fn = shard_map_compat(
        local_count,
        mesh=mesh,
        in_specs=(P(None, "data"), P(), P()),
        out_specs=P(),
    )
    return fn(data_sharded, qlo, qhi)


distributed_count = ops.counted(
    "distributed_count",
    "Global single-query match count — one psum over the data axis (the "
    "paper's result concatenation reduced to its cheapest sufficient "
    "collective).",
)(_distributed_count_jit)


@functools.partial(jax.jit, static_argnames=("mesh", "tile_n", "interpret"))
def _distributed_multi_mask_jit(
    mesh: Mesh,
    data_sharded: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    ops.note_trace("distributed_multi_mask")
    if interpret is None:
        interpret = ops.default_interpret()

    def local_multi(data_local, lo, up):
        return _local_multi_scan(data_local, lo, up, tile_n=tile_n,
                                 interpret=interpret)

    fn = shard_map_compat(
        local_multi,
        mesh=mesh,
        in_specs=(P(None, "data"), P(), P()),
        out_specs=P(None, "data"),
    )
    return fn(data_sharded, lower, upper)


distributed_multi_mask = ops.counted(
    "distributed_multi_mask",
    "Cross-device fused batch scan: every device evaluates the whole (m_pad, "
    "Q) replicated query batch against its own object shard in one "
    "collective launch -> (Q, n_pad) int8 masks sharded over objects.",
)(_distributed_multi_mask_jit)


@functools.partial(jax.jit, static_argnames=("mesh", "tile_n", "interpret"))
def _distributed_multi_counts_jit(
    mesh: Mesh,
    data_sharded: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    ops.note_trace("distributed_multi_counts")
    if interpret is None:
        interpret = ops.default_interpret()

    def local_multi_counts(data_local, lo, up):
        mask = _local_multi_scan(data_local, lo, up, tile_n=tile_n,
                                 interpret=interpret)
        # (Q,) partial counts per device; one psum concatenates the paper's
        # partial result sets — only O(Q) ints cross the collective.
        return jax.lax.psum(jnp.sum(mask != 0, axis=-1).astype(jnp.int32),
                            "data")

    fn = shard_map_compat(
        local_multi_counts,
        mesh=mesh,
        in_specs=(P(None, "data"), P(), P()),
        out_specs=P(),
    )
    return fn(data_sharded, lower, upper)


distributed_multi_counts = ops.counted(
    "distributed_multi_counts",
    "Cross-device fused batch count: per-device (Q,) partial counts reduced "
    "via one psum -> (Q,) int32 global match counts, replicated.",
)(_distributed_multi_counts_jit)


@functools.partial(jax.jit, static_argnames=("mesh", "spec", "tile_n",
                                             "interpret"))
def _distributed_multi_reduce_jit(
    mesh: Mesh,
    data_sharded: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    delta_cm: jax.Array | None = None,
    base_tomb: jax.Array | None = None,
    *,
    spec,
    tile_n: int = 1024,
    interpret: bool | None = None,
):
    ops.note_trace("distributed_multi_reduce")
    if interpret is None:
        interpret = ops.default_interpret()

    # Ids/Mask payloads stay sharded over objects (the paper's "partial
    # result sets", never concatenated); reduced payloads replicate.
    out_specs = P(None, "data") if spec.sharded_payload else P()

    if base_tomb is None:
        def local_reduce(data_local, lo, up):
            mask = _local_multi_scan(data_local, lo, up, tile_n=tile_n,
                                     interpret=interpret)
            # Shard-local partials + the spec's collective merge (psum
            # counts, pmin/pmax/psum aggregates, all_gather'd (Q, k) top-k
            # partials) — mirroring the count psum: only the reduced payload
            # crosses the collective. Identity specs return the shard-local
            # mask.
            return spec.distributed_reduce(mask, data_local, "data")

        fn = shard_map_compat(
            local_reduce,
            mesh=mesh,
            in_specs=(P(None, "data"), P(), P()),
            out_specs=out_specs,
        )
        base = fn(data_sharded, lower, upper)
    else:
        def local_reduce_tomb(data_local, lo, up, tomb_local):
            from repro.kernels import reducers as _red
            mask = _local_multi_scan(data_local, lo, up, tile_n=tile_n,
                                     interpret=interpret)
            # The tombstone vector shards with the data axis, so the fold is
            # shard-local — no extra collective.
            mask = _red.fold_tombstones(mask, tomb_local)
            return spec.distributed_reduce(mask, data_local, "data")

        fn = shard_map_compat(
            local_reduce_tomb,
            mesh=mesh,
            in_specs=(P(None, "data"), P(), P(), P("data")),
            out_specs=out_specs,
        )
        base = fn(data_sharded, lower, upper, base_tomb)
    if delta_cm is None:
        return base
    # The delta block is tiny and replicated: scan + reduce it outside the
    # shard_map (every device computes the same payload, no collective).
    return base, ops._delta_payload(delta_cm, lower, upper, spec=spec,
                                    tile_n=tile_n, interpret=interpret)


distributed_multi_reduce = ops.counted(
    "distributed_multi_reduce",
    "Cross-device fused batch scan + the ResultSpec's shard-local reducer "
    "and one small collective merge in a single launch -> the spec payload "
    "(sharded masks for Ids/Mask; replicated counts/top-k/aggregates).",
)(_distributed_multi_reduce_jit)


class DistributedScan:
    """Horizontally partitioned scan over a device mesh (build-once facade).

    Single-query (``mask`` / ``query`` / ``count``) and batched
    (``mask_batch`` / ``query_batch`` / ``count_batch``) entry points mirror
    ``ColumnarScan`` — batched calls are one collective launch and one host
    sync per batch, with the same pow2 query-axis bucketing.
    """

    def __init__(self, dataset: T.Dataset, mesh: Mesh | None = None, tile_n: int = 1024):
        self.mesh = mesh or make_data_mesh()
        self.tile_n = tile_n
        self.n_devices = self.mesh.shape["data"]
        padded, self.m, self.n = ops.prepare_columnar(
            dataset.cols, tile_n=tile_n * self.n_devices
        )
        self.m_pad = padded.shape[0]
        self.data = shard_columnar(self.mesh, padded, tile_n=tile_n)

    @property
    def nbytes_index(self) -> int:
        return 0  # a scan needs no auxiliary structures (paper §8)

    # -- single query ------------------------------------------------------
    def mask(self, q: T.RangeQuery) -> np.ndarray:
        qlo, qhi = ops.query_bounds_device(q, self.m_pad, self.data.dtype)
        out = distributed_mask(self.mesh, self.data, qlo, qhi, tile_n=self.tile_n)
        return ops.device_get(out)[: self.n] > 0

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return np.nonzero(self.mask(q))[0].astype(np.int64)

    def count(self, q: T.RangeQuery) -> int:
        qlo, qhi = ops.query_bounds_device(q, self.m_pad, self.data.dtype)
        total = distributed_count(self.mesh, self.data, qlo, qhi, tile_n=self.tile_n)
        # subtract sentinel padding matches (there are none: +inf never matches)
        return int(ops.device_get(total))

    # -- batched execution (one collective launch per batch) ---------------
    def _as_batch(self, batch) -> T.QueryBatch:
        if not isinstance(batch, T.QueryBatch):
            batch = T.QueryBatch.from_queries(list(batch))
        return batch

    def mask_batch(self, batch) -> np.ndarray:
        """(Q, n) bool match masks from one cross-device fused launch."""
        from repro.core.scan import bucketed_batch_bounds
        batch = self._as_batch(batch)
        _, lo, up = bucketed_batch_bounds(batch, self.m_pad, self.data.dtype)
        out = distributed_multi_mask(self.mesh, self.data, lo, up,
                                     tile_n=self.tile_n)
        return ops.device_get(out)[: len(batch), : self.n] > 0

    def count_batch(self, batch) -> list[int]:
        """Per-query global counts: one collective launch + one psum, so the
        host (and the collective) only ever see (Q,) ints."""
        from repro.core.scan import bucketed_batch_bounds
        batch = self._as_batch(batch)
        _, lo, up = bucketed_batch_bounds(batch, self.m_pad, self.data.dtype)
        counts = distributed_multi_counts(self.mesh, self.data, lo, up,
                                          tile_n=self.tile_n)
        return [int(c) for c in ops.device_get(counts)[: len(batch)]]

    def query_batch(self, batch, spec=T.IDS, delta=None) -> list:
        """Batched execution under any ResultSpec: one collective launch
        (scan + the spec's shard-local reduce + its collective merge, all in
        the same shard_map jit) and one host sync for the payload.

        ``delta`` folds the mutable plane into the same launch: the base
        tombstone vector shards with the data axis and ANDs in shard-locally;
        the small delta block replicates and scans outside the shard_map.
        """
        payload, fin = self.launch_batch(batch, spec=spec, delta=delta)
        return fin(ops.device_get(payload))

    def launch_batch(self, batch, spec=T.IDS, delta=None) -> tuple:
        """Device half of ``query_batch`` -> (payload, finalize): the one
        collective launch without its host sync, for the pipelined server
        (the counted ``device_get`` + host finalizers run via ``finalize``
        on the caller's thread)."""
        spec = T.validate_mode(spec).validate(self.m)
        from repro.core.scan import bucketed_batch_bounds
        batch = self._as_batch(batch)
        _, lo, up = bucketed_batch_bounds(batch, self.m_pad, self.data.dtype)
        dcm = tomb = None
        if delta is not None and not delta.is_empty:
            dcm = delta.device_cm(self.tile_n)
            tomb = delta.base_tomb_dev(
                self.data.shape[1], key=("dist", int(self.data.shape[1])),
                put=lambda h: jax.device_put(
                    jnp.asarray(h), NamedSharding(self.mesh, P("data"))))
        payload = distributed_multi_reduce(self.mesh, self.data, lo, up,
                                           dcm, tomb,
                                           spec=spec, tile_n=self.tile_n)
        n_q, n = len(batch), self.n
        if dcm is None:
            def finalize(host_payload):
                return spec.finalize(host_payload, n_q, n)
            return payload, finalize
        d_n, host_ctx = delta.d, delta.host_ctx()

        def finalize_delta(host_payload):
            base_host, delta_host = host_payload
            base = spec.finalize(base_host, n_q, n)
            dres = spec.finalize(delta_host, n_q, d_n)
            return spec.merge_delta(base, dres, host_ctx)
        return payload, finalize_delta
