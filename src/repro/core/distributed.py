"""Distributed MDRQ execution — horizontal partitioning over devices.

The paper's horizontal partitioning (§3.1) assigns n/t objects to each of t
threads, runs the same search per partition, and concatenates partial results.
The TPU mapping (DESIGN.md §2): the object axis of the columnar array shards
over the ``data`` mesh axis via ``shard_map``; every device runs the identical
Pallas scan on its local (m_pad, n_pad/p) shard. The paper's "concatenate
partial result sets" becomes a no-op — the output mask inherits the input
sharding — and the only collective in the system is an optional ``psum`` for
global match counts. Load balancing is inherited from random object placement,
exactly as in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import types as T
from repro.kernels import ops
from repro.kernels import range_scan as _rs


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map`` (with ``check_vma``); this tree's
    pinned version only has ``jax.experimental.shard_map.shard_map`` (with
    ``check_rep``). Both flags are disabled for the same reason: pallas_call
    outputs carry no replication/vma metadata.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            pass
        try:
            # intermediate versions export jax.shard_map but still spell the
            # flag check_rep — it must be disabled just the same
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_data_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over all (or the first k) local devices: axis 'data'.

    Builds ``jax.sharding.Mesh`` directly from a device ndarray — the
    ``jax.make_mesh(..., devices=list)`` path is not portable across the JAX
    versions this tree supports.
    """
    devs = jax.devices()
    k = n_devices or len(devs)
    return Mesh(np.asarray(devs[:k]), ("data",))


def shard_columnar(mesh: Mesh, padded_cols: np.ndarray, tile_n: int = 1024) -> jax.Array:
    """Place (m_pad, n_pad) columnar data sharded over objects.

    n_pad must divide by (#devices * tile_n) — callers pad with +inf sentinels
    via ``ops.prepare_columnar`` using tile_n * axis_size.
    """
    n_dev = mesh.shape["data"]
    m_pad, n_pad = padded_cols.shape
    assert n_pad % (n_dev * tile_n) == 0, (n_pad, n_dev, tile_n)
    sharding = NamedSharding(mesh, P(None, "data"))
    return jax.device_put(jnp.asarray(padded_cols), sharding)


@functools.partial(jax.jit, static_argnames=("mesh", "tile_n", "interpret"))
def distributed_mask(
    mesh: Mesh,
    data_sharded: jax.Array,
    qlo: jax.Array,
    qhi: jax.Array,
    *,
    tile_n: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """Sharded match mask: each device scans its own object shard."""
    if interpret is None:
        interpret = ops.default_interpret()

    def local_scan(data_local, lo, up):
        if ops.use_xla():
            from repro.kernels import ref as _ref
            return _ref.range_scan_ref(data_local, lo, up)
        return _rs.range_scan_tiles(data_local, lo, up, tile_n=tile_n,
                                    interpret=interpret)

    fn = shard_map_compat(
        local_scan,
        mesh=mesh,
        in_specs=(P(None, "data"), P(), P()),
        out_specs=P("data"),
    )
    return fn(data_sharded, qlo, qhi)


@functools.partial(jax.jit, static_argnames=("mesh", "tile_n", "interpret"))
def distributed_count(
    mesh: Mesh,
    data_sharded: jax.Array,
    qlo: jax.Array,
    qhi: jax.Array,
    *,
    tile_n: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """Global match count — one psum over the data axis (the paper's result
    concatenation reduced to its cheapest sufficient collective)."""
    if interpret is None:
        interpret = ops.default_interpret()

    def local_count(data_local, lo, up):
        if ops.use_xla():
            from repro.kernels import ref as _ref
            mask = _ref.range_scan_ref(data_local, lo, up)
        else:
            mask = _rs.range_scan_tiles(data_local, lo, up, tile_n=tile_n,
                                        interpret=interpret)
        return jax.lax.psum(mask.astype(jnp.int32).sum(), "data")

    fn = shard_map_compat(
        local_count,
        mesh=mesh,
        in_specs=(P(None, "data"), P(), P()),
        out_specs=P(),
    )
    return fn(data_sharded, qlo, qhi)


class DistributedScan:
    """Horizontally partitioned scan over a device mesh (build-once facade)."""

    def __init__(self, dataset: T.Dataset, mesh: Mesh | None = None, tile_n: int = 1024):
        self.mesh = mesh or make_data_mesh()
        self.tile_n = tile_n
        n_dev = self.mesh.shape["data"]
        padded, self.m, self.n = ops.prepare_columnar(
            dataset.cols, tile_n=tile_n * n_dev
        )
        self.m_pad = padded.shape[0]
        self.data = shard_columnar(self.mesh, padded, tile_n=tile_n)

    def mask(self, q: T.RangeQuery) -> np.ndarray:
        qlo, qhi = ops.query_bounds_device(q, self.m_pad, self.data.dtype)
        out = distributed_mask(self.mesh, self.data, qlo, qhi, tile_n=self.tile_n)
        return np.asarray(out)[: self.n] > 0

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return np.nonzero(self.mask(q))[0].astype(np.int64)

    def count(self, q: T.RangeQuery) -> int:
        qlo, qhi = ops.query_bounds_device(q, self.m_pad, self.data.dtype)
        total = distributed_count(self.mesh, self.data, qlo, qhi, tile_n=self.tile_n)
        # subtract sentinel padding matches (there are none: +inf never matches)
        return int(total)
