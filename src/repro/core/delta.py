"""Mutable data plane: delta segment, tombstones, and atomic compaction.

Every structure in this repo is build-once over a frozen ``(m, n)`` array —
the paper's own evaluation assumes a static dataset. This module is the
write path that keeps them honest under live traffic (DESIGN.md §11),
following the skd-tree shape (static bulk structure + in-memory delta):

  * ``MutableDelta`` — the engine-side mutable store: an append-only
    row-major buffer of new rows plus copy-on-write tombstone bitmaps over
    the base dataset and the delta itself. Deletes never touch the built
    structures; they only flip a tombstone bit. Every mutation bumps a
    monotone version counter and the ``mdrq_delta_rows`` /
    ``mdrq_delta_tombstones`` gauges.
  * ``DeltaView`` — an immutable snapshot handed to the read path. Queries
    never see the mutable store: ``query_batch`` snapshots once at entry and
    executes entirely against the view, so a concurrent append/delete cannot
    tear a batch. Repeated batches at an unchanged version receive the *same*
    view object, so its cached device arrays (the columnar delta block, the
    per-layout base-tombstone vectors) are built once per version, not once
    per batch.
  * ``Compactor`` — the background merge: ``build()`` constructs a complete
    new engine state (fresh structures over base-minus-tombstones plus live
    delta rows) WITHOUT holding the ingest lock, then ``commit()`` briefly
    takes the lock, folds in whatever ingest raced with the build (late rows
    re-seed the new delta; late tombstones translate through the id map),
    and swaps the engine's state attribute in one assignment. Queries read
    that attribute exactly once per call, so an in-flight batch finishes on
    the old version and the next batch sees the new one — never a half-merged
    hybrid.

Id space: base rows keep their dataset positions ``[0, n_base)``; appended
rows get ``n_base + j`` in append order. Compaction renumbers — ``compact()``
returns the old->new id map (``-1`` for tombstoned rows) so callers holding
ids can translate.

Tombstoned *delta* rows are poisoned to ``+inf`` when the view materializes
its device block: the batched scan's finite query bounds can never match
them, so the delta scan needs no separate tombstone input. Base tombstones
do need a device-side mask (the base structures were built before the
deletes), folded into the match masks inside the fused reduce jits.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.obs import tracing as obs_tracing
from repro.core import types as T
from repro.kernels import ops

DELTA_ROWS_GAUGE = "mdrq_delta_rows"
DELTA_TOMBS_GAUGE = "mdrq_delta_tombstones"


class DeltaView:
    """One immutable version of the delta: what a query batch executes against.

    Carries host copies of the delta rows and both tombstone bitmaps, plus
    per-layout caches of the device arrays the fused kernels consume. Views
    are shared across batches at the same version (see
    ``MutableDelta.snapshot``), so the caches amortize device transfers the
    same way the base structures amortize their build.
    """

    __slots__ = ("version", "n_base", "m", "d", "rows", "delta_tomb",
                 "base_tomb", "has_base_tombs", "delta_ids", "_base_cols",
                 "_cm_cache", "_tomb_cache", "_combined")

    def __init__(self, version: int, n_base: int, m: int, rows: np.ndarray,
                 delta_tomb: np.ndarray, base_tomb: np.ndarray,
                 base_cols: np.ndarray):
        self.version = version
        self.n_base = n_base
        self.m = m
        self.rows = rows                      # (d, m) float32, row-major
        self.d = rows.shape[0]
        self.delta_tomb = delta_tomb          # (d,) bool
        self.base_tomb = base_tomb            # (n_base,) bool
        self.has_base_tombs = bool(base_tomb.any())
        self.delta_ids = n_base + np.arange(self.d, dtype=np.int64)
        self._base_cols = base_cols
        self._cm_cache: dict = {}
        self._tomb_cache: dict = {}
        self._combined: Optional[np.ndarray] = None

    @property
    def is_empty(self) -> bool:
        """True iff queries can ignore the delta entirely (fast path)."""
        return self.d == 0 and not self.has_base_tombs

    @property
    def nbytes(self) -> int:
        return int(self.rows.nbytes + self.base_tomb.nbytes
                   + self.delta_tomb.nbytes)

    # -- device arrays (cached per layout) ---------------------------------
    def device_cm(self, tile_n: int):
        """(m_pad, d_pad) columnar device block of the delta rows, or None
        when the delta holds no rows.

        Padding matches ``ops.prepare_columnar`` exactly (m -> SUBLANES with
        0.0 match-all, d -> tile_n with +inf never-match), so the block rides
        the same fused kernels — and the same (m_pad, Q) bounds — as the base
        data. Tombstoned rows are poisoned to +inf here: finite query bounds
        cannot match them, so the delta scan carries its deletes for free.
        """
        if self.d == 0:
            return None
        cm = self._cm_cache.get(tile_n)
        if cm is None:
            cols = np.ascontiguousarray(self.rows.T, dtype=np.float32)
            if self.delta_tomb.any():
                cols = cols.copy()
                cols[:, self.delta_tomb] = np.inf
            cm, _, _ = ops.prepare_columnar(cols, tile_n=tile_n)
            self._cm_cache[tile_n] = cm
        return cm

    def base_tomb_dev(self, n_pad: int, perm: Optional[np.ndarray] = None,
                      key=None, put: Optional[Callable] = None):
        """(n_pad,) int8 base-tombstone vector in a structure's storage order,
        or None when no base row is tombstoned.

        ``perm`` maps storage position -> original row id (the tree layouts);
        storage-order layouts (scan, VA-file) omit it and share the default
        cache ``key``. ``put`` overrides the host->device transfer (the
        distributed path shards the vector along its data axis).
        """
        if not self.has_base_tombs:
            return None
        if key is None:
            key = ("_id", int(n_pad))
        arr = self._tomb_cache.get(key)
        if arr is None:
            host = np.zeros(int(n_pad), np.int8)
            if perm is None:
                host[:self.n_base] = self.base_tomb
            else:
                host[:len(perm)] = self.base_tomb[perm]
            arr = (put or jnp.asarray)(host)
            self._tomb_cache[key] = arr
        return arr

    # -- host-side helpers (per-query fallback path, spec merges) ----------
    def match_delta_ids(self, q: "T.RangeQuery") -> np.ndarray:
        """Global ids of live delta rows matching ``q`` (numpy oracle)."""
        if self.d == 0:
            return np.empty((0,), np.int64)
        mask = T.match_mask_np(np.ascontiguousarray(self.rows.T), q)
        return self.delta_ids[mask & ~self.delta_tomb]

    def combined_cols(self) -> np.ndarray:
        """(m, n_base + d) base columns with the delta appended — the value
        source for host-side spec materialization over combined ids."""
        if self._combined is None:
            if self.d:
                self._combined = np.concatenate(
                    [self._base_cols, np.ascontiguousarray(self.rows.T)],
                    axis=1)
            else:
                self._combined = self._base_cols
        return self._combined

    def host_ctx(self) -> "T.DeltaHostCtx":
        """The context ``ResultSpec.merge_delta`` uses to fold base + delta
        results into one answer."""
        return T.DeltaHostCtx(n=self.n_base, delta_ids=self.delta_ids,
                              base_cols=self._base_cols, delta_rows=self.rows)


class MutableDelta:
    """Append-only delta segment + tombstone bitmaps over one base dataset.

    Thread-safe: mutations and snapshots serialize on an internal lock;
    the engine additionally serializes mutations against compaction commits
    with its ingest lock. Readers never touch this object directly — they go
    through ``snapshot()``.
    """

    def __init__(self, dataset: "T.Dataset"):
        self.n_base = int(dataset.n)
        self.m = int(dataset.m)
        self._base_cols = dataset.cols
        self._lock = threading.Lock()
        self._rows = np.empty((0, self.m), np.float32)
        self._d = 0
        self._base_tomb = np.zeros(self.n_base, dtype=bool)
        self._delta_tomb = np.zeros(0, dtype=bool)
        self._version = 0
        self._view: Optional[DeltaView] = None
        reg = obs.registry()
        self._rows_gauge = reg.gauge(
            DELTA_ROWS_GAUGE, help="rows in the delta segment (incl. "
            "tombstoned, pending compaction)")
        self._tombs_gauge = reg.gauge(
            DELTA_TOMBS_GAUGE, help="tombstones pending compaction "
            "(base + delta)")
        self._publish_gauges()

    @property
    def d(self) -> int:
        return self._d

    @property
    def version(self) -> int:
        return self._version

    @property
    def n_total(self) -> int:
        """One past the largest currently-valid id."""
        return self.n_base + self._d

    @property
    def nbytes(self) -> int:
        """Delta rows + both tombstone bitmaps (the memory_report entry)."""
        with self._lock:
            return int(self._rows[: self._d].nbytes + self._base_tomb.nbytes
                       + self._delta_tomb[: self._d].nbytes)

    def _publish_gauges(self) -> None:
        self._rows_gauge.set(self._d)
        self._tombs_gauge.set(int(self._base_tomb.sum())
                              + int(self._delta_tomb[: self._d].sum()))

    def append(self, rows) -> np.ndarray:
        """Append row(s); returns their new global ids (``n_base + j``)."""
        rows = np.asarray(rows, dtype=np.float32)
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.ndim != 2 or rows.shape[1] != self.m:
            raise ValueError(
                f"appended rows must be (k, {self.m}), got {rows.shape}")
        k = rows.shape[0]
        with self._lock:
            need = self._d + k
            if need > self._rows.shape[0]:
                cap = max(64, 2 * self._rows.shape[0], need)
                grown = np.empty((cap, self.m), np.float32)
                grown[: self._d] = self._rows[: self._d]
                self._rows = grown
                tomb = np.zeros(cap, dtype=bool)
                tomb[: self._d] = self._delta_tomb[: self._d]
                self._delta_tomb = tomb
            self._rows[self._d:need] = rows
            ids = self.n_base + np.arange(self._d, need, dtype=np.int64)
            self._d = need
            self._version += 1
            self._publish_gauges()
            return ids

    def delete(self, ids) -> int:
        """Tombstone ids (base or delta). Idempotent per id; returns how many
        rows were newly tombstoned. Ids must be valid in the current version
        (compaction renumbers — translate through its id map first)."""
        ids = np.unique(np.asarray(ids, dtype=np.int64).ravel())
        if ids.size == 0:
            return 0
        with self._lock:
            if ids[0] < 0 or ids[-1] >= self.n_base + self._d:
                raise ValueError(
                    f"delete ids out of range [0, {self.n_base + self._d})")
            base = ids[ids < self.n_base]
            dloc = ids[ids >= self.n_base] - self.n_base
            newly = (int((~self._base_tomb[base]).sum())
                     + int((~self._delta_tomb[dloc]).sum()))
            self._base_tomb[base] = True
            self._delta_tomb[dloc] = True
            self._version += 1
            self._publish_gauges()
            return newly

    def snapshot(self) -> DeltaView:
        """The current version as an immutable view. Returns the *same*
        object while the version is unchanged, so per-version device-array
        caches are shared across batches."""
        with self._lock:
            v = self._view
            if v is not None and v.version == self._version:
                return v
            view = DeltaView(
                version=self._version, n_base=self.n_base, m=self.m,
                rows=self._rows[: self._d].copy(),
                delta_tomb=self._delta_tomb[: self._d].copy(),
                base_tomb=self._base_tomb.copy(),
                base_cols=self._base_cols)
            self._view = view
            return view


class Compactor:
    """Two-phase merge of base + delta into a fresh engine state.

    ``build()`` runs lock-free against a delta snapshot — the expensive part
    (rebuilding every structure) happens while ingest and serving continue.
    ``commit()`` takes the engine's ingest lock only long enough to fold in
    ingest that raced with the build and swap the state attribute. Queries
    capture the state once per call, so the swap is atomic from their side.

    ``commit()`` returns the full old->new id map (length ``n_base + d`` at
    commit time; ``-1`` marks tombstoned rows). Use ``MDRQEngine.compact()``
    for the one-shot form.
    """

    def __init__(self, engine):
        self.engine = engine
        self._old_state = None
        self._view: Optional[DeltaView] = None
        self._new_state = None
        self._id_map: Optional[np.ndarray] = None

    def build(self) -> "Compactor":
        """Merge the snapshot into a brand-new state (no locks held)."""
        with obs_tracing.span("build"):
            eng = self.engine
            state = eng._state
            view = state.delta.snapshot()
            keep_base = ~view.base_tomb
            keep_delta = ~view.delta_tomb
            parts = [state.dataset.cols[:, keep_base]]
            if view.d:
                parts.append(np.ascontiguousarray(view.rows[keep_delta].T))
            new_cols = np.ascontiguousarray(
                np.concatenate(parts, axis=1).astype(np.float32))
            if new_cols.shape[1] == 0:
                raise ValueError("compaction would produce an empty dataset; "
                                 "keep at least one live row")
            n_keep_base = int(keep_base.sum())
            id_map = np.full(view.n_base + view.d, -1, dtype=np.int64)
            id_map[: view.n_base][keep_base] = np.arange(n_keep_base)
            if view.d:
                id_map[view.n_base:][keep_delta] = (
                    n_keep_base + np.arange(int(keep_delta.sum())))
            # Compactor is single-owner: commit() touches _new_state under
            # the engine's ingest lock only incidentally (that lock guards
            # the *engine*), so this lock-free write does not race anything.
            self._new_state = eng._build_state(  # mdrqlint: disable=lock-discipline
                T.Dataset(new_cols), version=state.version + 1)
            self._old_state = state
            self._view = view
            self._id_map = id_map
        return self

    def commit(self) -> np.ndarray:
        """Fold in post-snapshot ingest, swap the engine state atomically."""
        if self._new_state is None:
            raise RuntimeError("Compactor.commit() before build()")
        eng = self.engine
        view = self._view
        with obs_tracing.span("commit"), eng._ingest_lock:
            if eng._state is not self._old_state:
                raise RuntimeError("engine state changed during compaction "
                                   "build; re-run build()")
            delta = self._old_state.delta
            with delta._lock:
                d_now = delta._d
                late_rows = delta._rows[view.d:d_now].copy()
                base_tomb_now = delta._base_tomb.copy()
                delta_tomb_now = delta._delta_tomb[:d_now].copy()
            id_map = self._id_map
            new_state = self._new_state
            # Tombstones that landed after the snapshot on rows the merge
            # kept: translate them into the new id space and re-apply as
            # base tombstones of the new state.
            late_dead = np.concatenate([
                np.nonzero(base_tomb_now & ~view.base_tomb)[0],
                view.n_base + np.nonzero(
                    delta_tomb_now[: view.d] & ~view.delta_tomb)[0],
            ])
            if late_dead.size:
                mapped = id_map[late_dead]
                new_state.delta.delete(mapped[mapped >= 0])
                id_map[late_dead] = -1
            full_map = np.concatenate(
                [id_map, np.full(d_now - view.d, -1, np.int64)])
            if d_now > view.d:
                # Rows appended during the build re-seed the new delta.
                new_ids = new_state.delta.append(late_rows)
                full_map[view.n_base + view.d:] = new_ids
                dead_late = delta_tomb_now[view.d:]
                if dead_late.any():
                    new_state.delta.delete(new_ids[dead_late])
                    full_map[view.n_base + view.d:][dead_late] = -1
            eng._state = new_state
            obs.registry().counter(
                "mdrq_compactions_total",
                help="completed delta compactions (atomic state swaps)").inc()
            new_state.delta._publish_gauges()
            self._new_state = None
            return full_map
