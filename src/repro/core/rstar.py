"""Packed STR R*-tree (TPU adaptation of the paper's §2.2.1 / §5.1).

The paper uses libspatialindex's R*-tree with insert-time forced re-insertion
splits. For the analytical workloads the paper targets (bulk loads, rare
updates — §1, and the paper itself reports insert order does not change its
results, §7.1.2 fn. 14), the TPU-native equivalent is a *bulk-loaded packed*
R-tree: Sort-Tile-Recursive (STR, Leutenegger et al. 1997) tiles the space so
leaf MBRs are near-minimal-overlap — the same objective the R*-tree's
re-insertion heuristic optimizes incrementally — while the resulting structure
is a dense, pointer-free array of MBRs that the VPU can prune breadth-first.
Cache-line node alignment (paper §5.1 adapts node capacity to 64B lines)
becomes VMEM tile alignment: leaf capacity = ``tile_n`` objects, inner fanout
sized so one level fits a handful of VREGs.

Query: shared two-phase plan (see ``blockindex``).
"""
from __future__ import annotations

import numpy as np

from repro.core import types as T
from repro.core.blockindex import BlockedIndex, finish_build


def _str_order(cols: np.ndarray, idx: np.ndarray, dims: list[int], tile_n: int) -> list[np.ndarray]:
    """Sort-Tile-Recursive: sort by dims[0], slice, recurse within slices."""
    if idx.size <= tile_n or not dims:
        return [idx]
    d = dims[0]
    srt = idx[np.argsort(cols[d, idx], kind="stable")]
    # Number of slabs: objects-per-slab such that remaining dims can tile into
    # tile_n leaves — the standard STR S = ceil((n/tile_n)^(1/k)) slab count.
    n_leaves = -(-idx.size // tile_n)
    slabs = int(np.ceil(n_leaves ** (1.0 / len(dims))))
    slab_size = -(-idx.size // slabs)
    out: list[np.ndarray] = []
    for s in range(slabs):
        part = srt[s * slab_size : (s + 1) * slab_size]
        if part.size:
            out.extend(_str_order(cols, part, dims[1:], tile_n))
    return out


def build_rstar(
    dataset: T.Dataset, tile_n: int = 1024, fanout: int = 64, sort_dims: int | None = None
) -> BlockedIndex:
    """Bulk-load a packed STR R-tree.

    Args:
      dataset: columnar dataset.
      tile_n: leaf capacity (objects per MBR leaf).
      fanout: inner-level fanout.
      sort_dims: how many leading dimensions STR sorts by (default: all, capped
        at 6 — beyond that the per-dim slab count degenerates to 1).
    """
    cols = dataset.cols
    k = min(dataset.m, 6 if sort_dims is None else sort_dims)
    order = _str_order(cols, np.arange(dataset.n), list(range(k)), tile_n)
    perm = np.concatenate(order)
    cols_perm = cols[:, perm]
    return finish_build("rstar", cols_perm, perm, tile_n, fanout)
