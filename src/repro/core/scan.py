"""Parallel scans (TPU adaptation of the paper's §3 / §5.4 / §5.5).

Three scan flavors, mirroring the paper's contestants:

  * ``ColumnarScan.query``          — complete-match scan over the columnar
    layout via the ``range_scan`` Pallas kernel (vectorized, all dims fused).
  * ``ColumnarScan.query_partial``  — partial-match scan via the
    ``range_scan_vertical`` kernel: touches only queried dimensions' columns
    (the paper's vertical-partitioning advantage, §5.5).
  * ``RowScan.query``               — row-major layout scan (the paper's
    horizontal partitioning, §5.4) — kept for the layout ablation.

The paper's multi-threading dimension (horizontal partitioning over t threads)
maps to sharding over devices and lives in ``core.distributed``.

Batched execution: ``mask_batch`` / ``mask_batch_partial`` evaluate a whole
``QueryBatch`` through the fused multi-query kernels (``kernels.multi_scan``)
— one launch per batch instead of one per query, with the query axis padded
to a pow2 bucket so arbitrary batch sizes hit a bounded set of jit traces.

Result shapes: ``query_batch(batch, spec=...)`` takes any ``types.ResultSpec``
— the fused kernel and the spec's on-device reducer run as one launch
(``ops.multi_scan_reduce`` / ``multi_scan_vertical_reduce``), so counts,
top-k, and aggregates ship only their payload across the device->host
boundary and the per-query host-side ``nonzero`` — the dominant cost for
large result sets — never runs. The single-query ``count`` /
``count_partial`` / ``count_batch`` fast paths reduce via ``ops.mask_counts``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import types as T
from repro.kernels import ops


def bucketed_batch_bounds(batch: T.QueryBatch, m_pad: int, dtype
                          ) -> tuple[int, jax.Array, jax.Array]:
    """(q_pad, lo, up): pow2-bucketed device bounds for one fused batch launch.

    The query axis rounds up to the next power of two so arbitrary batch sizes
    hit a bounded set of jit traces; padding columns are match-all and their
    output rows are dropped by the caller. Shared by ``ColumnarScan`` and
    ``DistributedScan`` so both batch paths bucket identically.
    """
    q_pad = T.next_pow2(len(batch))
    lo, up = ops.batch_bounds_device(batch, m_pad, dtype, q_pad=q_pad)
    return q_pad, lo, up


@dataclasses.dataclass
class ColumnarScan:
    """Full-scan engine over dimension-major data."""

    data_dev: jax.Array  # (m_pad, n_pad)
    m: int
    n: int
    tile_n: int = 1024

    @property
    def nbytes_index(self) -> int:
        return 0  # a scan needs no auxiliary structures (paper §8)

    def mask(self, q: T.RangeQuery) -> np.ndarray:
        """(n,) bool match mask (complete or partial match)."""
        qlo, qhi = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        out = ops.range_scan(self.data_dev, qlo, qhi, tile_n=self.tile_n)
        return ops.device_get(out)[: self.n] > 0

    def mask_partial(self, q: T.RangeQuery) -> np.ndarray:
        """(n,) bool mask touching only the queried dimensions."""
        dims = np.nonzero(q.dims_mask)[0].astype(np.int32)
        if dims.size == 0:
            return np.ones((self.n,), bool)
        qlo, qhi = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        out = ops.range_scan_vertical(
            self.data_dev, jnp.asarray(dims), qlo, qhi, tile_n=self.tile_n
        )
        return ops.device_get(out)[: self.n] > 0

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return np.nonzero(self.mask(q))[0].astype(np.int64)

    def query_partial(self, q: T.RangeQuery) -> np.ndarray:
        return np.nonzero(self.mask_partial(q))[0].astype(np.int64)

    # -- count-only results (device-side reduction, no id materialization) --
    def count(self, q: T.RangeQuery) -> int:
        """Match count from one scan launch + one scalar transfer."""
        qlo, qhi = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        out = ops.range_scan(self.data_dev, qlo, qhi, tile_n=self.tile_n)
        return int(ops.device_get(ops.mask_counts(out)))

    def count_partial(self, q: T.RangeQuery) -> int:
        """Match count touching only the queried dimensions' columns."""
        dims = np.nonzero(q.dims_mask)[0].astype(np.int32)
        if dims.size == 0:
            return self.n
        qlo, qhi = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        out = ops.range_scan_vertical(
            self.data_dev, jnp.asarray(dims), qlo, qhi, tile_n=self.tile_n
        )
        return int(ops.device_get(ops.mask_counts(out)))

    # -- batched execution (fused multi-query kernels) ---------------------
    # The query axis pads to a pow2 bucket (match-all padding columns, rows
    # dropped here) so arbitrary batch sizes hit a bounded set of jit traces.
    def mask_batch(self, batch: T.QueryBatch) -> np.ndarray:
        """(Q, n) bool match masks from one fused full-scan launch."""
        out = self._mask_batch_device(batch, partial=False)
        return ops.device_get(out)[: len(batch), : self.n] > 0

    def mask_batch_partial(self, batch: T.QueryBatch) -> np.ndarray:
        """(Q, n) bool masks touching only each query's constrained dims."""
        out = self._mask_batch_device(batch, partial=True)
        return ops.device_get(out)[: len(batch), : self.n] > 0

    def _mask_batch_device(self, batch: T.QueryBatch, partial: bool) -> jax.Array:
        """(q_pad, n_pad) device masks from one fused launch (rows >= Q and
        columns >= n are padding; object padding never matches)."""
        q_pad, lo, up = bucketed_batch_bounds(batch, self.data_dev.shape[0],
                                              self.data_dev.dtype)
        if partial:
            dim_ids = batch.padded_dim_ids(q_pad)
            return ops.multi_range_scan_vertical(
                self.data_dev, jnp.asarray(dim_ids), lo, up,
                tile_n=self.tile_n,
            )
        return ops.multi_range_scan(self.data_dev, lo, up, tile_n=self.tile_n)

    def count_batch(self, batch: T.QueryBatch, partial: bool = False
                    ) -> list[int]:
        """Per-query match counts: one fused launch, one O(Q) host transfer."""
        out = self._mask_batch_device(batch, partial)
        counts = ops.device_get(ops.mask_counts(out))[: len(batch)]
        return [int(c) for c in counts]

    def query_batch(self, batch: T.QueryBatch, partial: bool = False,
                    spec: T.ResultSpec = T.IDS, delta=None) -> list:
        """Batched execution under any ResultSpec: the fused multi-query
        kernel and the spec's on-device reducer run as one launch, the
        payload crosses in one host sync, and the spec's host finalizer
        types the per-query results (ids / counts / masks / top-k ids /
        aggregates).

        ``delta`` (a ``core.delta.DeltaView``) folds the mutable data plane
        into the same launch: base tombstones AND into the masks on device,
        the delta block scans with the same bounds, and the spec merges the
        two finalized halves — still one launch + one host sync.
        """
        payload, fin = self.launch_batch(batch, partial=partial, spec=spec,
                                         delta=delta)
        return fin(ops.device_get(payload))

    def launch_batch(self, batch: T.QueryBatch, partial: bool = False,
                     spec: T.ResultSpec = T.IDS, delta=None):
        """Device half of ``query_batch``: issue the one fused launch and
        return ``(payload, finalize)`` without synchronizing.

        ``payload`` is the in-flight device value; ``finalize(host_payload)``
        — where ``host_payload`` is the caller's single counted
        ``ops.device_get(payload)`` — runs the spec's host finalizer (and the
        delta merge) and types the per-query results. The split is what the
        pipelined server overlaps: batch k+1 launches while batch k's
        finalize runs on another thread; composing the halves back-to-back is
        exactly the synchronous path with an unchanged launch/sync budget.
        """
        spec = T.validate_mode(spec).validate(self.m)
        q_pad, lo, up = bucketed_batch_bounds(batch, self.data_dev.shape[0],
                                              self.data_dev.dtype)
        dcm = tomb = None
        if delta is not None and not delta.is_empty:
            dcm = delta.device_cm(self.tile_n)
            tomb = delta.base_tomb_dev(self.data_dev.shape[1])
        if partial:
            dim_ids = batch.padded_dim_ids(q_pad)
            payload = ops.multi_scan_vertical_reduce(
                self.data_dev, jnp.asarray(dim_ids), lo, up, dcm, tomb,
                spec=spec, tile_n=self.tile_n)
        else:
            payload = ops.multi_scan_reduce(self.data_dev, lo, up, dcm, tomb,
                                            spec=spec, tile_n=self.tile_n)
        n_q, n, d_n = len(batch), self.n, delta.d if dcm is not None else 0
        if dcm is None:
            def finalize(host_payload):
                return spec.finalize(host_payload, n_q, n)
        else:
            host_ctx = delta.host_ctx()

            def finalize(host_payload):
                base_host, delta_host = host_payload
                base = spec.finalize(base_host, n_q, n)
                dres = spec.finalize(delta_host, n_q, d_n)
                return spec.merge_delta(base, dres, host_ctx)
        return payload, finalize


def build_columnar_scan(dataset: T.Dataset, tile_n: int = 1024) -> ColumnarScan:
    padded, m, n = ops.prepare_columnar(dataset.cols, tile_n=tile_n)
    return ColumnarScan(data_dev=jnp.asarray(padded), m=m, n=n, tile_n=tile_n)


@dataclasses.dataclass
class RowScan:
    """Row-major layout scan (horizontal partitioning analogue)."""

    data_dev: jax.Array  # (n_pad, m_pad)
    m: int
    n: int
    tile_rows: int = 512

    @property
    def nbytes_index(self) -> int:
        return 0

    def _mask_device(self, q: T.RangeQuery) -> jax.Array:
        qlo, qhi = ops.query_bounds_device(q, self.data_dev.shape[1], self.data_dev.dtype)
        return ops.range_scan_rows(
            self.data_dev, qlo.T, qhi.T, tile_rows=self.tile_rows
        )

    def mask(self, q: T.RangeQuery) -> np.ndarray:
        return ops.device_get(self._mask_device(q))[: self.n] > 0

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return np.nonzero(self.mask(q))[0].astype(np.int64)

    def count(self, q: T.RangeQuery) -> int:
        """Match count summed on device (+inf padding rows never match)."""
        return int(ops.device_get(ops.mask_counts(self._mask_device(q))))


def build_row_scan(dataset: T.Dataset, tile_rows: int = 512) -> RowScan:
    rows = dataset.rows()  # (n, m)
    rows = T.pad_axis(rows, 1, 8, 0.0)       # dim padding: match-all bounds
    rows = T.pad_axis(rows, 0, tile_rows, np.inf)  # object padding: never match
    return RowScan(data_dev=jnp.asarray(rows), m=dataset.m, n=dataset.n,
                   tile_rows=tile_rows)


@jax.jit
def _xla_scan_mask_jit(data_cm: jax.Array, qlo: jax.Array,
                       qhi: jax.Array) -> jax.Array:
    ops.note_trace("xla_scan_mask")
    ok = jnp.logical_and(data_cm >= qlo, data_cm <= qhi)
    return jnp.all(ok, axis=0)


xla_scan_mask = ops.counted(
    "xla_scan_mask",
    "Plain-XLA (non-Pallas) columnar scan — the 'unoptimized baseline' the "
    "Pallas kernel is benchmarked against (paper's scalar-vs-SIMD axis).",
)(_xla_scan_mask_jit)


def numpy_scan_ids(cols: np.ndarray, q: T.RangeQuery) -> np.ndarray:
    """Single-core numpy scan — the host-side baseline."""
    return T.match_ids_np(cols, q)
