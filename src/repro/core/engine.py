"""MDRQEngine — the unified facade over all access paths.

Ingests a columnar dataset, builds the requested structures (scan is always
available; kd-tree / R*-tree / VA-file optional), and answers range queries
either with an explicitly chosen method or through the planner ("auto").
This is the paper's experimental matrix (§7.1.3) as a composable component —
and the interface the framework's data pipeline uses for sample selection.

Batched execution: ``query_batch`` takes a whole stream of queries at once —
the inter-query-parallelism counterpart of the paper's intra-query parallel
scans (§5). Queries bucket by planner-chosen access path (amortized costs),
each bucket executes through one fused multi-query launch
(``kernels.multi_scan``), and results come back per query, identical to the
single-query path. ``serve.mdrq_server`` wraps this into a throughput-
oriented front end.

Result modes: ``mode="ids"`` (default) returns sorted matching id arrays;
``mode="count"`` returns per-query match counts reduced *on device* — the
per-query host-side ``nonzero`` that dominates large result sets never runs
(the COUNT(*) fast path of analytical workloads).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core import types as T
from repro.core import scan as scan_mod
from repro.core.distributed import DistributedScan
from repro.core.kdtree import build_kdtree
from repro.core.rstar import build_rstar
from repro.core.vafile import build_vafile
from repro.core.planner import CostModel, Histograms, Planner

ALL_METHODS = ("scan", "scan_vertical", "rowscan", "kdtree", "rstar", "vafile")
RESULT_MODES = T.RESULT_MODES


@dataclasses.dataclass
class QueryStats:
    method: str
    seconds: float
    n_results: int
    est_selectivity: float


@dataclasses.dataclass
class BatchStats:
    """Aggregate statistics of one ``query_batch`` execution."""

    n_queries: int
    seconds: float
    method_counts: dict[str, int]
    n_results: int

    @property
    def qps(self) -> float:
        # 0.0 on an empty/zero-time batch (mirrors ServerStats.qps — a rate
        # with nothing measured is reported as zero, not infinity).
        return self.n_queries / self.seconds if self.seconds > 0 else 0.0


def _n_results(results: Sequence) -> int:
    """Total matches across per-query results (id arrays or int counts)."""
    return int(sum(int(r) if np.isscalar(r) else int(r.size) for r in results))


class MDRQEngine:
    """Build-once, query-many MDRQ engine (analytical workloads, §1)."""

    def __init__(
        self,
        dataset: T.Dataset,
        structures: tuple[str, ...] = ("scan", "kdtree", "rstar", "vafile"),
        tile_n: int = 1024,
        rowscan: bool = False,
        mesh=None,
    ):
        self.dataset = dataset
        self.tile_n = tile_n
        # With a mesh, "scan" executes as the cross-device batched scan: data
        # sharded over the 'data' axis, one collective launch per batch
        # (horizontal partitioning, §3.1). Other paths stay single-device —
        # and the single-device columnar copy is then built lazily, so a
        # meshed engine that never routes through them doesn't hold the
        # dataset on device twice.
        self.dist = (DistributedScan(dataset, mesh=mesh, tile_n=tile_n)
                     if mesh is not None else None)
        self._columnar = (None if mesh is not None
                          else scan_mod.build_columnar_scan(dataset, tile_n=tile_n))
        self.rowscan = scan_mod.build_row_scan(dataset) if rowscan else None
        self.kdtree = build_kdtree(dataset, tile_n=tile_n) if "kdtree" in structures else None
        self.rstar = build_rstar(dataset, tile_n=tile_n) if "rstar" in structures else None
        self.vafile = build_vafile(dataset, tile_n=tile_n) if "vafile" in structures else None
        self.hist = Histograms.build(dataset)
        # Every built structure must be plannable, or "auto" silently never
        # chooses it (the seed omitted rstar here — a structure that was paid
        # for at build time but could not win a single query). On a meshed
        # engine the vertical scan is *not* plannable: it executes on the
        # single-device columnar copy, so an "auto" choice of it would
        # lazily re-place the full dataset on one device — the exact
        # duplication sharding exists to avoid. Explicit
        # ``method="scan_vertical"`` remains an opt-in.
        available = ["scan"] if self.dist is not None else ["scan", "scan_vertical"]
        for name in ("kdtree", "rstar", "vafile"):
            if getattr(self, name) is not None:
                available.append(name)
        self.planner = Planner(
            self.hist, CostModel(n=dataset.n, m=dataset.m, tile_n=tile_n,
                                 n_devices=(self.dist.n_devices
                                            if self.dist is not None else 1)),
            available=tuple(available),
        )
        self.last_stats: Optional[QueryStats] = None
        self.last_batch_stats: Optional[BatchStats] = None

    @property
    def columnar(self) -> scan_mod.ColumnarScan:
        if self._columnar is None:
            self._columnar = scan_mod.build_columnar_scan(self.dataset,
                                                          tile_n=self.tile_n)
        return self._columnar

    def memory_report(self) -> dict[str, int]:
        """Bytes of auxiliary structures per method (paper §7.2 comparison)."""
        rep = {"data": self.dataset.nbytes, "scan": 0}
        if self.kdtree is not None:
            rep["kdtree"] = self.kdtree.nbytes_index
        if self.rstar is not None:
            rep["rstar"] = self.rstar.nbytes_index
        if self.vafile is not None:
            rep["vafile"] = self.vafile.nbytes_index
        return rep

    def query(self, q: T.RangeQuery, method: str = "auto",
              mode: str = "ids") -> Union[np.ndarray, int]:
        """Execute q -> sorted matching ids (or an int count with
        ``mode="count"``); records QueryStats."""
        if q.m != self.dataset.m:
            raise ValueError(f"query dims {q.m} != dataset dims {self.dataset.m}")
        if mode not in RESULT_MODES:
            raise ValueError(f"unknown mode {mode!r}; options: {RESULT_MODES}")
        if method == "auto":
            plan = self.planner.explain(q)
            method, est = plan.method, plan.est_selectivity
        else:
            est = self.planner.hist.selectivity(q)
        t0 = time.perf_counter()
        if mode == "count":
            res: Union[np.ndarray, int] = self._dispatch_count(q, method)
            n_res = int(res)
        else:
            res = self._dispatch(q, method)
            n_res = int(res.size)
        dt = time.perf_counter() - t0
        self.last_stats = QueryStats(method=method, seconds=dt,
                                     n_results=n_res, est_selectivity=est)
        return res

    def query_batch(
        self,
        queries: Union[T.QueryBatch, Sequence[T.RangeQuery]],
        method: str = "auto",
        mode: str = "ids",
    ) -> Union[list[np.ndarray], list[int]]:
        """Execute a batch of queries -> per-query sorted id arrays (or int
        counts with ``mode="count"``).

        Queries are bucketed by access path (the planner's choice under
        whole-batch cost amortization when ``method="auto"``, or the explicit
        method for all) and each bucket runs through a single fused
        multi-query launch. Results are positionally aligned with the input
        and identical to per-query ``query`` calls; aggregate ``BatchStats``
        land in ``last_batch_stats``.
        """
        if mode not in RESULT_MODES:
            raise ValueError(f"unknown mode {mode!r}; options: {RESULT_MODES}")
        if isinstance(queries, T.QueryBatch):
            batch = queries
        else:
            queries = list(queries)
            batch = T.QueryBatch.from_queries(queries) if queries else None
        if batch is None or len(batch) == 0:
            self.last_batch_stats = BatchStats(0, 0.0, {}, 0)
            return []
        if batch.m != self.dataset.m:
            raise ValueError(f"batch dims {batch.m} != dataset dims {self.dataset.m}")
        t0 = time.perf_counter()
        if method == "auto":
            plans = self.planner.explain_batch(batch.queries)
            methods = [p.method for p in plans]
        elif method in ALL_METHODS:
            methods = [method] * len(batch)
        else:
            raise ValueError(f"unknown method {method!r}; options: {ALL_METHODS} or 'auto'")

        buckets: dict[str, list[int]] = {}
        for k, meth in enumerate(methods):
            buckets.setdefault(meth, []).append(k)

        results: list = [None] * len(batch)
        for meth, idxs in buckets.items():
            sub = T.QueryBatch(batch.lower[idxs], batch.upper[idxs])
            for k, res in zip(idxs, self._dispatch_batch(sub, meth, mode)):
                results[k] = res
        dt = time.perf_counter() - t0
        self.last_batch_stats = BatchStats(
            n_queries=len(batch),
            seconds=dt,
            method_counts={m: len(ix) for m, ix in buckets.items()},
            n_results=_n_results(results),
        )
        return results

    def _dispatch_batch(self, batch: T.QueryBatch, method: str,
                        mode: str = "ids") -> list:
        if method == "scan":
            if self.dist is not None:
                return self.dist.query_batch(batch, mode=mode)
            return self.columnar.query_batch(batch, mode=mode)
        if method == "scan_vertical":
            return self.columnar.query_batch(batch, partial=True, mode=mode)
        if method == "kdtree" and self.kdtree is not None:
            return self.kdtree.query_batch(batch, mode=mode)
        if method == "rstar" and self.rstar is not None:
            return self.rstar.query_batch(batch, mode=mode)
        if method == "vafile" and self.vafile is not None:
            return self.vafile.query_batch(batch, mode=mode)
        # rowscan (and unbuilt structures) fall back to the per-query path,
        # which raises the same errors the single-query API does.
        if mode == "count":
            return [self._dispatch_count(batch[k], method) for k in range(len(batch))]
        return [self._dispatch(batch[k], method) for k in range(len(batch))]

    def _dispatch(self, q: T.RangeQuery, method: str) -> np.ndarray:
        if method == "scan":
            if self.dist is not None:
                return self.dist.query(q)
            return self.columnar.query(q)
        if method == "scan_vertical":
            return self.columnar.query_partial(q)
        if method == "rowscan":
            if self.rowscan is None:
                raise ValueError("rowscan not built (pass rowscan=True)")
            return self.rowscan.query(q)
        if method == "kdtree":
            if self.kdtree is None:
                raise ValueError("kdtree not built")
            return self.kdtree.query(q)
        if method == "rstar":
            if self.rstar is None:
                raise ValueError("rstar not built")
            return self.rstar.query(q)
        if method == "vafile":
            if self.vafile is None:
                raise ValueError("vafile not built")
            return self.vafile.query(q)
        raise ValueError(f"unknown method {method!r}; options: {ALL_METHODS} or 'auto'")

    def _dispatch_count(self, q: T.RangeQuery, method: str) -> int:
        """Count-only dispatch: every access path sums its match masks on
        device instead of materializing an id array."""
        if method == "scan":
            if self.dist is not None:
                return self.dist.count(q)
            return self.columnar.count(q)
        if method == "scan_vertical":
            return self.columnar.count_partial(q)
        if method == "rowscan":
            if self.rowscan is None:
                raise ValueError("rowscan not built (pass rowscan=True)")
            return self.rowscan.count(q)
        if method == "kdtree":
            if self.kdtree is None:
                raise ValueError("kdtree not built")
            return self.kdtree.count(q)
        if method == "rstar":
            if self.rstar is None:
                raise ValueError("rstar not built")
            return self.rstar.count(q)
        if method == "vafile":
            if self.vafile is None:
                raise ValueError("vafile not built")
            return self.vafile.count(q)
        raise ValueError(f"unknown method {method!r}; options: {ALL_METHODS} or 'auto'")
