"""MDRQEngine — the unified facade over all access paths.

Ingests a columnar dataset, builds the requested structures (scan is always
available; kd-tree / R*-tree / VA-file optional), and answers range queries
either with an explicitly chosen method or through the planner ("auto").
This is the paper's experimental matrix (§7.1.3) as a composable component —
and the interface the framework's data pipeline uses for sample selection.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import types as T
from repro.core import scan as scan_mod
from repro.core.kdtree import build_kdtree
from repro.core.rstar import build_rstar
from repro.core.vafile import build_vafile
from repro.core.planner import CostModel, Histograms, Planner

ALL_METHODS = ("scan", "scan_vertical", "rowscan", "kdtree", "rstar", "vafile")


@dataclasses.dataclass
class QueryStats:
    method: str
    seconds: float
    n_results: int
    est_selectivity: float


class MDRQEngine:
    """Build-once, query-many MDRQ engine (analytical workloads, §1)."""

    def __init__(
        self,
        dataset: T.Dataset,
        structures: tuple[str, ...] = ("scan", "kdtree", "rstar", "vafile"),
        tile_n: int = 1024,
        rowscan: bool = False,
    ):
        self.dataset = dataset
        self.tile_n = tile_n
        self.columnar = scan_mod.build_columnar_scan(dataset, tile_n=tile_n)
        self.rowscan = scan_mod.build_row_scan(dataset) if rowscan else None
        self.kdtree = build_kdtree(dataset, tile_n=tile_n) if "kdtree" in structures else None
        self.rstar = build_rstar(dataset, tile_n=tile_n) if "rstar" in structures else None
        self.vafile = build_vafile(dataset, tile_n=tile_n) if "vafile" in structures else None
        self.hist = Histograms.build(dataset)
        available = ["scan", "scan_vertical"]
        if self.kdtree is not None:
            available.append("kdtree")
        if self.vafile is not None:
            available.append("vafile")
        self.planner = Planner(
            self.hist, CostModel(n=dataset.n, m=dataset.m, tile_n=tile_n),
            available=tuple(available),
        )
        self.last_stats: Optional[QueryStats] = None

    def memory_report(self) -> dict[str, int]:
        """Bytes of auxiliary structures per method (paper §7.2 comparison)."""
        rep = {"data": self.dataset.nbytes, "scan": 0}
        if self.kdtree is not None:
            rep["kdtree"] = self.kdtree.nbytes_index
        if self.rstar is not None:
            rep["rstar"] = self.rstar.nbytes_index
        if self.vafile is not None:
            rep["vafile"] = self.vafile.nbytes_index
        return rep

    def query(self, q: T.RangeQuery, method: str = "auto") -> np.ndarray:
        """Execute q -> sorted matching ids; records QueryStats."""
        if q.m != self.dataset.m:
            raise ValueError(f"query dims {q.m} != dataset dims {self.dataset.m}")
        if method == "auto":
            plan = self.planner.explain(q)
            method, est = plan.method, plan.est_selectivity
        else:
            est = self.planner.hist.selectivity(q)
        t0 = time.perf_counter()
        ids = self._dispatch(q, method)
        dt = time.perf_counter() - t0
        self.last_stats = QueryStats(method=method, seconds=dt,
                                     n_results=int(ids.size), est_selectivity=est)
        return ids

    def _dispatch(self, q: T.RangeQuery, method: str) -> np.ndarray:
        if method == "scan":
            return self.columnar.query(q)
        if method == "scan_vertical":
            return self.columnar.query_partial(q)
        if method == "rowscan":
            if self.rowscan is None:
                raise ValueError("rowscan not built (pass rowscan=True)")
            return self.rowscan.query(q)
        if method == "kdtree":
            if self.kdtree is None:
                raise ValueError("kdtree not built")
            return self.kdtree.query(q)
        if method == "rstar":
            if self.rstar is None:
                raise ValueError("rstar not built")
            return self.rstar.query(q)
        if method == "vafile":
            if self.vafile is None:
                raise ValueError("vafile not built")
            return self.vafile.query(q)
        raise ValueError(f"unknown method {method!r}; options: {ALL_METHODS} or 'auto'")
