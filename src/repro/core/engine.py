"""MDRQEngine — a registry of access paths behind one query interface.

Ingests a columnar dataset, builds the requested structures (scan is always
available; kd-tree / R*-tree / VA-file optional), wraps each in its
``core.paths.AccessPath`` adapter, and answers range queries either with an
explicitly named path or through the planner ("auto"). This is the paper's
experimental matrix (§7.1.3) as a composable component — and the extension
seam (DESIGN.md §6): all routing (single/batch, ids/count) is one lookup in
the ``paths`` registry, so a new access path is ``register_path`` away from
planning and execution, with no engine changes.

Batched execution: ``query_batch`` takes a whole stream of queries at once —
the inter-query-parallelism counterpart of the paper's intra-query parallel
scans (§5). The planner's vectorized fixpoint (``Planner.plan_batch``)
assigns every query an access path under *realized-bucket* cost
amortization, each bucket executes through one fused multi-query launch
(``kernels.multi_scan``), and results come back per query, identical to the
single-query path. ``BatchStats`` splits ``plan_seconds`` from execution so
the planning cost is visible to ``benchmarks.bench_throughput``;
``serve.mdrq_server`` wraps the whole thing into a throughput front end.

Result shapes: every entry point takes a ``types.ResultSpec`` — ``Ids()``
(default, the paper's §2.1 id sets), ``Count()``, ``Mask()``,
``TopK(k, dim)``, ``Agg(op, dim)`` — pairing an on-device reducer with a
host finalizer, so reduced shapes ship only their payload across the
device->host boundary (the filter-then-aggregate fast path of analytical
workloads). The legacy ``mode="ids"|"count"`` strings keep working through
``types.validate_mode`` with a DeprecationWarning. A new result shape is a
``register_result_spec`` subclass away — specs extend like access paths, not
via another if/elif sweep.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.kernels import ops
from repro.core import types as T
from repro.core import delta as delta_mod
from repro.core import scan as scan_mod
from repro.core import paths as paths_mod
from repro.core.distributed import DistributedScan
from repro.core.kdtree import build_kdtree
from repro.core.rstar import build_rstar
from repro.core.vafile import build_vafile
from repro.core.planner import CostModel, Histograms, Planner

# The built-in access paths (every name ``structures``/``rowscan``/``mesh``
# can put in the registry). The registry itself — ``MDRQEngine.paths`` — is
# the authoritative routing table; this tuple is the build vocabulary.
ALL_METHODS = ("scan", "scan_vertical", "rowscan", "kdtree", "rstar", "vafile")
RESULT_MODES = T.RESULT_MODES


@dataclasses.dataclass
class QueryStats:
    method: str
    seconds: float
    n_results: int
    est_selectivity: float


@dataclasses.dataclass
class BatchStats:
    """Aggregate statistics of one ``query_batch`` execution.

    ``seconds`` is the whole wall time (planning + execution);
    ``plan_seconds`` is the planning share of it, so the vectorized batch
    planner's cost is measurable separately from kernel time.
    """

    n_queries: int
    seconds: float
    method_counts: dict[str, int]
    n_results: int
    plan_seconds: float = 0.0
    # per-query chosen path, positionally aligned with the input batch — the
    # server's query log records how each query was served without paying for
    # full tracing
    methods: Optional[list[str]] = None

    @property
    def qps(self) -> float:
        # 0.0 on an empty/zero-time batch (mirrors ServerStats.qps — a rate
        # with nothing measured is reported as zero, not infinity).
        return self.n_queries / self.seconds if self.seconds > 0 else 0.0


def _n_results(spec: T.ResultSpec, results: Sequence) -> int:
    """Total result magnitude across per-query results, typed by the spec."""
    return int(sum(spec.result_size(r) for r in results))


@dataclasses.dataclass
class PendingBatch:
    """An in-flight batch: device work launched, host finalization deferred.

    Produced by ``MDRQEngine.launch_batch`` (the device stage of a split
    ``query_batch``); ``finalize()`` — run later, possibly on another thread
    — performs each bucket's single counted ``ops.device_get`` and the spec's
    host finalizers, returning the per-query results positionally aligned
    with the input. Everything the finalize needs was captured at launch time
    (the state version, the delta snapshot inside each finalize closure), so
    a concurrent ingest or compaction swap cannot mix versions mid-batch.

    ``stats`` is filled by ``finalize()`` but deliberately NOT written to
    ``engine.last_batch_stats``: with several batches in flight the engine-
    level "last" slot would interleave nondeterministically; the pipelined
    server aggregates per-window stats itself.
    """

    n_queries: int
    spec: T.ResultSpec
    methods: list[str]
    method_counts: dict[str, int]
    plan_seconds: float
    launch_seconds: float
    version: int
    # per-bucket (input positions, in-flight device payload | None, finalize)
    _parts: list = dataclasses.field(default_factory=list)
    stats: Optional[BatchStats] = None

    def finalize(self) -> list:
        """Host stage: sync each bucket's payload, run the host finalizers,
        scatter per-query results back to input order. Idempotent only in
        the sense that ``stats`` records the *last* call; call once."""
        t0 = time.perf_counter()
        results: list = [None] * self.n_queries
        for idxs, payload, fin in self._parts:
            host = ops.device_get(payload) if payload is not None else None
            out = fin(host)
            for k, res in zip(idxs, out):
                results[k] = res
        dt = time.perf_counter() - t0
        self.stats = BatchStats(
            n_queries=self.n_queries,
            seconds=self.plan_seconds + self.launch_seconds + dt,
            method_counts=dict(self.method_counts),
            n_results=_n_results(self.spec, results),
            plan_seconds=self.plan_seconds,
            methods=list(self.methods),
        )
        return results


def _lookup_path(paths: dict, method: str) -> paths_mod.AccessPath:
    path = paths.get(method)
    if path is None:
        raise ValueError(f"unknown method {method!r}; "
                         f"options: {tuple(paths)} or 'auto'")
    return path


class _EngineState:
    """One immutable *version* of the engine: frozen structures built from a
    dataset snapshot, their access-path registry + planner, and the mutable
    delta segment layered on top (DESIGN.md §11).

    Queries read ``MDRQEngine._state`` exactly once and work off the captured
    object, so the compactor's atomic swap — a single attribute assignment —
    can never mix structures from two versions inside one batch; in-flight
    batches simply finish on the version they captured.
    """

    def __init__(self, dataset: T.Dataset, structures: tuple[str, ...],
                 tile_n: int, rowscan: bool, mesh, version: int = 0):
        self.dataset = dataset
        self.tile_n = tile_n
        self.version = version
        # With a mesh, "scan" executes as the cross-device batched scan: data
        # sharded over the 'data' axis, one collective launch per batch
        # (horizontal partitioning, §3.1). Other paths stay single-device —
        # and the single-device columnar copy is then built lazily, so a
        # meshed engine that never routes through them doesn't hold the
        # dataset on device twice.
        self.dist = (DistributedScan(dataset, mesh=mesh, tile_n=tile_n)
                     if mesh is not None else None)
        self._columnar = (None if mesh is not None
                          else scan_mod.build_columnar_scan(dataset, tile_n=tile_n))
        self.rowscan = scan_mod.build_row_scan(dataset) if rowscan else None
        self.kdtree = build_kdtree(dataset, tile_n=tile_n) if "kdtree" in structures else None
        self.rstar = build_rstar(dataset, tile_n=tile_n) if "rstar" in structures else None
        self.vafile = build_vafile(dataset, tile_n=tile_n) if "vafile" in structures else None
        self.hist = Histograms.build(dataset)
        # The mutable plane over this frozen version: appended rows +
        # tombstones, scanned by every batch launch alongside the structures.
        self.delta = delta_mod.MutableDelta(dataset)

        # -- the access-path registry (build-from-spec) --------------------
        # Every built structure registers as a plannable path, or "auto"
        # silently never chooses it (the seed omitted rstar — a structure
        # paid for at build time that could not win a single query). On a
        # meshed engine the vertical scan is *not* plannable: it executes on
        # the single-device columnar copy, so an "auto" choice of it would
        # lazily re-place the full dataset on one device — the exact
        # duplication sharding exists to avoid. Explicit
        # ``method="scan_vertical"`` remains an opt-in.
        self.paths: dict[str, paths_mod.AccessPath] = {}
        if self.dist is not None:
            self.add_path(paths_mod.DistributedScanPath(self.dist))
            self.add_path(
                paths_mod.VerticalScanPath(lambda: self.columnar,
                                           plannable=False))
        else:
            self.add_path(paths_mod.ColumnarScanPath(self._columnar))
            self.add_path(paths_mod.VerticalScanPath(lambda: self.columnar))
        if self.rowscan is not None:
            # no fused batch kernel for the row layout — per-query fallback;
            # host columns enable the reduced specs' from_ids finalization
            self.add_path(paths_mod.PerQueryPath("rowscan", self.rowscan,
                                                 cols=dataset.cols))
        for index in (self.kdtree, self.rstar):
            if index is not None:
                self.add_path(paths_mod.BlockedIndexPath(index))
        if self.vafile is not None:
            self.add_path(paths_mod.VAFilePath(self.vafile, self.hist))

        # The planner shares the registry dict: paths registered later are
        # planned without rebuilding anything.
        self.planner = Planner(
            self.hist, CostModel(n=dataset.n, m=dataset.m, tile_n=tile_n,
                                 n_devices=(self.dist.n_devices
                                            if self.dist is not None else 1)),
            paths=self.paths,
        )

    @property
    def columnar(self) -> scan_mod.ColumnarScan:
        if self._columnar is None:
            self._columnar = scan_mod.build_columnar_scan(self.dataset,
                                                          tile_n=self.tile_n)
        return self._columnar

    def add_path(self, path: paths_mod.AccessPath) -> None:
        for attr in ("name", "plannable", "owns_storage", "nbytes_index",
                     "query", "count", "query_batch", "cost", "cost_batch"):
            if not hasattr(path, attr):
                raise TypeError(f"access path lacks {attr!r} "
                                f"(see core.paths.AccessPath)")
        self.paths[path.name] = path


class MDRQEngine:
    """Build-once, query-many MDRQ engine (analytical workloads, §1) — now
    with a mutable plane: ``append``/``delete`` land in a versioned delta
    segment and ``compact`` folds it back into freshly built structures."""

    def __init__(
        self,
        dataset: T.Dataset,
        structures: tuple[str, ...] = ("scan", "kdtree", "rstar", "vafile"),
        tile_n: int = 1024,
        rowscan: bool = False,
        mesh=None,
    ):
        # Build parameters persist so ``compact`` can rebuild the same
        # structure set over the merged dataset.
        self._structures = tuple(structures)
        self.tile_n = tile_n
        self._rowscan_enabled = bool(rowscan)
        self._mesh = mesh
        # Serializes the write side (append/delete/compact-commit); the read
        # side is lock-free — queries capture ``self._state`` once.
        self._ingest_lock = threading.Lock()
        self._state = self._build_state(dataset, version=0)
        self.last_stats: Optional[QueryStats] = None
        self.last_batch_stats: Optional[BatchStats] = None
        self.last_trace: Optional[obs_tracing.BatchTrace] = None

    def _build_state(self, dataset: T.Dataset, version: int = 0) -> _EngineState:
        return _EngineState(dataset, self._structures, self.tile_n,
                            self._rowscan_enabled, self._mesh, version=version)

    # -- versioned-state views ---------------------------------------------
    # Pre-versioning callers read these as plain attributes; each delegates
    # to the *current* version. Code that must be swap-consistent (query,
    # query_batch, the Compactor) captures ``self._state`` once instead.
    @property
    def dataset(self) -> T.Dataset:
        return self._state.dataset

    @property
    def dist(self):
        return self._state.dist

    @property
    def rowscan(self):
        return self._state.rowscan

    @property
    def kdtree(self):
        return self._state.kdtree

    @property
    def rstar(self):
        return self._state.rstar

    @property
    def vafile(self):
        return self._state.vafile

    @property
    def hist(self) -> Histograms:
        return self._state.hist

    @property
    def paths(self) -> dict[str, paths_mod.AccessPath]:
        return self._state.paths

    @property
    def planner(self) -> Planner:
        return self._state.planner

    @property
    def columnar(self) -> scan_mod.ColumnarScan:
        return self._state.columnar

    @property
    def _columnar(self):
        # introspection compat: None until the lazy columnar copy is built
        return self._state._columnar

    @property
    def delta(self) -> delta_mod.MutableDelta:
        return self._state.delta

    @property
    def version(self) -> int:
        """Monotone dataset version: bumps on every compaction swap."""
        return self._state.version

    # -- the mutable plane (append / delete / compact) ----------------------
    def append(self, rows) -> np.ndarray:
        """Append rows ((k, m) array-like) -> their assigned int64 ids.

        Rows land in the current version's delta segment and are visible to
        every subsequent query: the fused batch launches scan the delta
        block alongside the frozen structures (same launch, same host sync).
        """
        with self._ingest_lock:
            return self._state.delta.append(rows)

    def delete(self, ids) -> int:
        """Tombstone ids (base or delta rows) -> count of newly deleted."""
        with self._ingest_lock:
            return self._state.delta.delete(ids)

    def compact(self) -> np.ndarray:
        """Merge delta rows + tombstones into freshly built main structures
        and atomically swap the engine to the new version.

        Returns the id map (old id -> new id, -1 for deleted rows). The
        build runs outside the ingest lock — serving and ingest continue on
        the old version — and the commit re-folds anything ingested during
        the build into the new version's delta before swapping ``_state`` in
        a single assignment.
        """
        with obs_tracing.span("compact", version=self._state.version):
            comp = delta_mod.Compactor(self)
            comp.build()
            return comp.commit()

    # -- the registry ------------------------------------------------------
    def register_path(self, path: paths_mod.AccessPath) -> None:
        """Register (or replace) an access path under ``path.name``.

        The planner sees it immediately (shared registry dict): a plannable
        path is costed by ``explain``/``plan_batch`` and can win "auto"
        queries; any registered path is addressable as ``method=name``.
        Registration binds to the *current* version — a later ``compact``
        rebuilds the registry from the engine's build spec, so external
        paths must re-register after a swap.
        """
        self._state.add_path(path)

    def _path(self, method: str) -> paths_mod.AccessPath:
        return _lookup_path(self._state.paths, method)

    def memory_report(self) -> dict[str, int]:
        """Bytes of auxiliary structures per path (paper §7.2 comparison),
        plus the mutable plane ("delta": segment rows + both tombstone sets).

        Storage-owning paths only: views over another path's arrays (the
        vertical scan) would double-count.
        """
        state = self._state
        rep = {"data": state.dataset.nbytes, "delta": state.delta.nbytes}
        for name, path in state.paths.items():
            if path.owns_storage:
                rep[name] = path.nbytes_index
        return rep

    @staticmethod
    def _path_query_batch(path, sub: T.QueryBatch, spec: T.ResultSpec,
                          delta=None) -> list:
        """Run one bucket through a path under ``spec`` (and ``delta``).

        Paths registered against the pre-ResultSpec protocol (a
        ``query_batch(batch, mode)`` taking mode strings) still serve the
        two legacy shapes; reduced shapes on such a path get the canonical
        error instead of silently wrong results. A non-empty delta likewise
        only goes to paths that declare the parameter — anything else would
        silently drop appended rows.
        """
        if delta is not None:
            if not paths_mod.takes_delta(path.query_batch):
                raise ValueError(
                    f"access path {path.name!r} is not delta-aware; "
                    f"call compact() first")
            return path.query_batch(sub, spec=spec, delta=delta)
        if paths_mod.takes_spec(path.query_batch):
            return path.query_batch(sub, spec=spec)
        if spec.kind in T.RESULT_MODES:
            import warnings
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                return path.query_batch(sub, spec.kind)
        raise ValueError(f"path {path.name!r} predates the ResultSpec "
                         f"protocol and cannot serve spec {spec.kind!r}")

    @staticmethod
    def _path_supports_launch(path, delta) -> bool:
        """Whether this bucket can use the split launch/finalize protocol.

        Registered paths without ``launch_batch`` (or whose ``launch_batch``
        predates the spec/delta parameters) fall back to synchronous
        execution inside the device stage — correct, just not overlapped.
        """
        if not paths_mod.supports_launch(path):
            return False
        lb = path.launch_batch
        if not paths_mod.takes_spec(lb):
            return False
        if delta is not None and not paths_mod.takes_delta(lb):
            return False
        return True

    def launch_batch(
        self,
        queries: Union[T.QueryBatch, Sequence[T.RangeQuery]],
        method: str = "auto",
        spec: Optional[T.ResultSpec] = None,
        mode: Optional[str] = None,
    ) -> PendingBatch:
        """Device stage of a split ``query_batch`` -> a ``PendingBatch``.

        Plans the batch and issues every bucket's fused launch without
        synchronizing; the returned ``PendingBatch.finalize()`` performs the
        deferred host syncs + spec finalizers (one counted ``device_get`` per
        bucket — the same budget as the synchronous path) and may run on
        another thread. State and delta snapshot are captured here, once:
        in-flight batches finalize on the version they launched against, so
        ingest/compaction stays atomic while a batch is in flight
        (DESIGN.md §13). Buckets whose path lacks the split protocol execute
        synchronously inside this call (their results ride a pre-finalized
        part). ``finalize()`` fills ``PendingBatch.stats`` but never touches
        ``engine.last_batch_stats``.
        """
        state = self._state
        spec = T.resolve_spec(spec, mode)
        if isinstance(queries, T.QueryBatch):
            batch = queries
        else:
            queries = list(queries)
            batch = T.QueryBatch.from_queries(queries) if queries else None
        if batch is None or len(batch) == 0:
            return PendingBatch(0, spec, [], {}, 0.0, 0.0, state.version)
        if batch.m != state.dataset.m:
            raise ValueError(f"batch dims {batch.m} != dataset dims "
                             f"{state.dataset.m}")
        spec.validate(state.dataset.m)
        dview = state.delta.snapshot()
        delta_arg = None if dview.is_empty else dview

        t0 = time.perf_counter()
        with obs_tracing.span("plan", n_queries=len(batch)):
            state.planner.model.delta_n = dview.d
            if method == "auto":
                bp = state.planner.plan_batch(batch, spec=spec)
                methods = bp.methods
            else:
                _lookup_path(state.paths, method)  # raise before work
                methods = [method] * len(batch)
        t1 = time.perf_counter()

        buckets: dict[str, list[int]] = {}
        for k, meth in enumerate(methods):
            buckets.setdefault(meth, []).append(k)

        pending = PendingBatch(
            n_queries=len(batch), spec=spec, methods=list(methods),
            method_counts={m: len(ix) for m, ix in buckets.items()},
            plan_seconds=t1 - t0, launch_seconds=0.0, version=state.version)
        for meth, idxs in buckets.items():
            sub = T.QueryBatch(batch.lower[idxs], batch.upper[idxs])
            path = _lookup_path(state.paths, meth)
            with obs_tracing.span("execute", path=meth, bucket=len(idxs),
                                  stage="launch"):
                if self._path_supports_launch(path, delta_arg):
                    payload, fin = path.launch_batch(sub, spec=spec,
                                                     delta=delta_arg)
                else:
                    out = self._path_query_batch(path, sub, spec,
                                                 delta=delta_arg)
                    payload, fin = None, (lambda _h, _out=out: _out)
            pending._parts.append((idxs, payload, fin))
        pending.launch_seconds = time.perf_counter() - t1

        reg = obs_metrics.registry()
        reg.counter("mdrq_query_batches_total",
                    help="query_batch executions").inc()
        for meth, idxs in buckets.items():
            reg.counter("mdrq_queries_total",
                        help="queries served, by access path",
                        path=meth).inc(len(idxs))
        return pending

    def query(self, q: T.RangeQuery, method: str = "auto",
              spec: Optional[T.ResultSpec] = None,
              mode: Optional[str] = None):
        """Execute q under a ResultSpec -> sorted ids (default ``Ids()``),
        an int count, a bool mask, top-k ids, or an aggregate; records
        QueryStats. ``mode="ids"|"count"`` is the deprecated string alias.
        """
        state = self._state
        if q.m != state.dataset.m:
            raise ValueError(f"query dims {q.m} != dataset dims {state.dataset.m}")
        spec = T.resolve_spec(spec, mode).validate(state.dataset.m)
        dview = state.delta.snapshot()
        state.planner.model.delta_n = dview.d
        if method == "auto":
            plan = state.planner.explain(q, spec=spec)
            method, est = plan.method, plan.est_selectivity
        else:
            est = state.planner.hist.selectivity(q)
        path = _lookup_path(state.paths, method)
        t0 = time.perf_counter()
        if not dview.is_empty:
            # Singles see only the frozen base — with a live delta every
            # spec (ids and count included) rides the delta-aware batch
            # rung at Q=1.
            res = self._path_query_batch(
                path, T.QueryBatch.from_queries([q]), spec, delta=dview)[0]
        elif spec.kind == "ids":    # dedicated single-query fast paths for
            res = path.query(q)     # the two historical shapes; every other
        elif spec.kind == "count":  # spec rides the batch rung at Q=1
            res = path.count(q)
        else:
            res = self._path_query_batch(
                path, T.QueryBatch.from_queries([q]), spec)[0]
        dt = time.perf_counter() - t0
        self.last_stats = QueryStats(method=method, seconds=dt,
                                     n_results=spec.result_size(res),
                                     est_selectivity=est)
        return res

    def query_batch(
        self,
        queries: Union[T.QueryBatch, Sequence[T.RangeQuery]],
        method: str = "auto",
        spec: Optional[T.ResultSpec] = None,
        mode: Optional[str] = None,
        trace: bool = False,
    ) -> list:
        """Execute a batch of queries under a ResultSpec -> per-query typed
        results (sorted id arrays by default).

        Queries are bucketed by access path (the planner's vectorized
        fixpoint under realized-bucket, spec-aware cost amortization when
        ``method="auto"``, or the explicit method for all) and each bucket
        runs through a single fused multi-query launch carrying the spec's
        on-device reducer. Results are positionally aligned with the input
        and identical to per-query ``query`` calls; aggregate ``BatchStats``
        land in ``last_batch_stats`` with the planning share in
        ``plan_seconds``.

        ``trace=True`` installs an ``obs.Tracer`` for the duration and leaves
        a ``BatchTrace`` in ``last_trace``: one ``QueryTrace`` per query
        (chosen path, bucket, estimated vs realized selectivity and cost,
        amortized launches/host-syncs) plus the span tree. With
        ``trace=False`` the span calls short-circuit to ``obs.NULL_SPAN`` —
        nothing is allocated on the hot path.
        """
        state = self._state
        spec = T.resolve_spec(spec, mode)
        if isinstance(queries, T.QueryBatch):
            batch = queries
        else:
            queries = list(queries)
            batch = T.QueryBatch.from_queries(queries) if queries else None
        if batch is None or len(batch) == 0:
            self.last_batch_stats = BatchStats(0, 0.0, {}, 0, methods=[])
            return []
        if batch.m != state.dataset.m:
            raise ValueError(f"batch dims {batch.m} != dataset dims {state.dataset.m}")
        spec.validate(state.dataset.m)
        # One snapshot serves the whole batch: concurrent appends/deletes
        # become visible at the next batch, never mid-batch.
        dview = state.delta.snapshot()
        delta_arg = None if dview.is_empty else dview

        tracer = obs_tracing.Tracer() if trace else None
        if tracer is not None:
            tracer.__enter__()
        bp = None
        try:
            t0 = time.perf_counter()
            with obs_tracing.span("plan", n_queries=len(batch)):
                # The delta's size is a per-version cost axis: every path
                # pays an extra delta scan per batch, amortized over its
                # realized bucket — which can flip index picks to the scan
                # as the delta grows.
                state.planner.model.delta_n = dview.d
                if method == "auto":
                    bp = state.planner.plan_batch(batch, spec=spec)
                    methods = bp.methods
                else:
                    _lookup_path(state.paths, method)  # raise before work
                    methods = [method] * len(batch)
            plan_dt = time.perf_counter() - t0

            buckets: dict[str, list[int]] = {}
            for k, meth in enumerate(methods):
                buckets.setdefault(meth, []).append(k)

            results: list = [None] * len(batch)
            for meth, idxs in buckets.items():
                sub = T.QueryBatch(batch.lower[idxs], batch.upper[idxs])
                with obs_tracing.span("execute", path=meth,
                                      bucket=len(idxs)) as sp:
                    out = self._path_query_batch(
                        _lookup_path(state.paths, meth), sub, spec,
                        delta=delta_arg)
                    sp.block_on(out)
                for k, res in zip(idxs, out):
                    results[k] = res
            dt = time.perf_counter() - t0
        finally:
            if tracer is not None:
                tracer.__exit__(None, None, None)

        reg = obs_metrics.registry()
        reg.counter("mdrq_query_batches_total",
                    help="query_batch executions").inc()
        for meth, idxs in buckets.items():
            reg.counter("mdrq_queries_total",
                        help="queries served, by access path",
                        path=meth).inc(len(idxs))

        self.last_batch_stats = BatchStats(
            n_queries=len(batch),
            seconds=dt,
            method_counts={m: len(ix) for m, ix in buckets.items()},
            n_results=_n_results(spec, results),
            plan_seconds=plan_dt,
            methods=list(methods),
        )
        if tracer is not None:
            self.last_trace = self._build_trace(
                state, tracer, batch, spec, bp, methods, buckets, results,
                plan_dt, dt)
        return results

    @staticmethod
    def _build_trace(state, tracer, batch, spec, bp, methods, buckets,
                     results, plan_dt, dt) -> obs_tracing.BatchTrace:
        """Assemble per-query ``QueryTrace`` records from the span tree and
        the batch plan (estimates come from ``bp`` when the planner chose;
        explicit-method runs get histogram selectivities and NaN cost)."""
        n = state.dataset.n
        mq = batch.dims_mask.sum(axis=1)
        if bp is not None:
            sels = bp.est_selectivity
            path_row = {name: j for j, name in enumerate(bp.path_names)}
        else:
            sels = state.planner.plan_inputs(batch).sels
            path_row = {}
        # one execute span per bucket, keyed by its path attr
        bucket_spans = {s.attrs.get("path"): s for s in tracer.find("execute")}
        records = []
        for k, meth in enumerate(methods):
            bsize = len(buckets[meth])
            sp = bucket_spans.get(meth)
            res_size = spec.result_size(results[k])
            obs_sel = (res_size / n if spec.kind in ("ids", "count", "mask")
                       else None)
            est_cost = (float(bp.costs[path_row[meth], k]) if bp is not None
                        else float("nan"))
            records.append(obs_tracing.QueryTrace(
                index=k,
                method=meth,
                bucket_size=bsize,
                est_selectivity=float(sels[k]),
                est_cost=est_cost,
                spec_kind=spec.kind,
                mq=int(mq[k]),
                result_size=res_size,
                obs_selectivity=obs_sel,
                seconds=(sp.seconds / bsize if sp is not None else 0.0),
                launches=(sp.launches / bsize if sp is not None else 0.0),
                host_syncs=(sp.host_syncs / bsize if sp is not None else 0.0),
            ))
        return obs_tracing.BatchTrace(
            n=n, n_queries=len(batch), spec_kind=spec.kind,
            plan_seconds=plan_dt, seconds=dt, queries=records,
            spans=tracer.spans)
