"""Core datatypes for multidimensional range queries (MDRQ).

Mirrors the paper's problem definition (§2.1):

  * a dataset ``D`` of ``n`` objects with ``m`` float attributes,
  * a (partial- or complete-match) range query ``q`` with per-dimension
    predicates ``[lb_j, ub_j]``; un-queried dimensions use ``[-inf, +inf]``,
  * a result = the set of identifiers of matching objects.

The canonical device layout is **dimension-major (columnar)**, shape ``(m, n)``
— the TPU-native realization of the paper's vertical partitioning (§3.2): the
last (lane) dimension runs over objects so one VREG holds 128 objects of one
attribute, and the AND-merge across dimensions happens in-register.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax.numpy as jnp

NEG_INF = np.float32(-np.inf)
POS_INF = np.float32(np.inf)

# Result modes shared by every engine entry point: "ids" materializes sorted
# matching identifiers (the paper's result definition); "count" returns only
# per-query match counts, reduced on device (COUNT(*) analytics fast path —
# skips the host-side ``nonzero`` entirely).
RESULT_MODES = ("ids", "count")


def validate_mode(mode: str) -> str:
    """Reject unknown result modes with the one canonical error.

    Every entry point that accepts a ``mode`` (engine singles and batches,
    the access paths, the serving front end) validates through here, so the
    check — and its error text — cannot drift between layers.
    """
    if mode not in RESULT_MODES:
        raise ValueError(f"unknown mode {mode!r}; options: {RESULT_MODES}")
    return mode


@dataclasses.dataclass(frozen=True)
class RangeQuery:
    """A multidimensional range query (complete- or partial-match).

    ``lower``/``upper`` always have length ``m``; dimensions not mentioned in
    the query carry ``[-inf, +inf]`` (paper §2.1). ``dims_mask`` records which
    dimensions are actually constrained — engines use it to skip un-queried
    columns (the vertical-partitioning partial-match advantage, §3.2/§5.5).
    """

    lower: np.ndarray  # (m,) float32
    upper: np.ndarray  # (m,) float32

    def __post_init__(self):
        lo = np.asarray(self.lower, dtype=np.float32)
        up = np.asarray(self.upper, dtype=np.float32)
        if lo.shape != up.shape or lo.ndim != 1:
            raise ValueError(f"bad query bounds: {lo.shape} vs {up.shape}")
        object.__setattr__(self, "lower", lo)
        object.__setattr__(self, "upper", up)

    @property
    def m(self) -> int:
        return self.lower.shape[0]

    @property
    def dims_mask(self) -> np.ndarray:
        """(m,) bool — True where the dimension is actually constrained."""
        return ~(np.isneginf(self.lower) & np.isposinf(self.upper))

    @property
    def n_queried_dims(self) -> int:
        return int(self.dims_mask.sum())

    @property
    def is_complete_match(self) -> bool:
        return bool(self.dims_mask.all())

    @staticmethod
    def complete(lower: Sequence[float], upper: Sequence[float]) -> "RangeQuery":
        return RangeQuery(np.asarray(lower, np.float32), np.asarray(upper, np.float32))

    @staticmethod
    def partial(m: int, predicates: dict[int, tuple[float, float]]) -> "RangeQuery":
        """Partial-match query: ``{dim: (lb, ub)}`` over an m-dim space."""
        lo = np.full((m,), NEG_INF, np.float32)
        up = np.full((m,), POS_INF, np.float32)
        for j, (a, b) in predicates.items():
            lo[j], up[j] = np.float32(a), np.float32(b)
        return RangeQuery(lo, up)

    def reorder(self, order: np.ndarray) -> "RangeQuery":
        """Query with dimensions permuted by ``order`` (selectivity ordering)."""
        return RangeQuery(self.lower[order], self.upper[order])


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """An ordered batch of range queries over the same m-dim space.

    Batched execution: analytical workloads are streams of queries, and the
    fused multi-query kernels (``kernels.multi_scan``) evaluate a whole batch
    per launch. ``QueryBatch`` is the host-side carrier: bounds are stacked
    (Q, m) so the kernels' query-minor (m_pad, Q) layout and the per-query
    constrained-dim lists derive without touching each query again.
    """

    lower: np.ndarray  # (Q, m) float32
    upper: np.ndarray  # (Q, m) float32

    def __post_init__(self):
        lo = np.asarray(self.lower, dtype=np.float32)
        up = np.asarray(self.upper, dtype=np.float32)
        if lo.shape != up.shape or lo.ndim != 2:
            raise ValueError(f"bad batch bounds: {lo.shape} vs {up.shape}")
        object.__setattr__(self, "lower", lo)
        object.__setattr__(self, "upper", up)

    @staticmethod
    def from_queries(queries: Sequence["RangeQuery"]) -> "QueryBatch":
        if not queries:
            raise ValueError("empty query batch")
        m = queries[0].m
        for q in queries:
            if q.m != m:
                raise ValueError(f"mixed dims in batch: {q.m} != {m}")
        return QueryBatch(np.stack([q.lower for q in queries]),
                          np.stack([q.upper for q in queries]))

    def __len__(self) -> int:
        return self.lower.shape[0]

    def __getitem__(self, k: int) -> "RangeQuery":
        return RangeQuery(self.lower[k], self.upper[k])

    @property
    def m(self) -> int:
        return self.lower.shape[1]

    @property
    def queries(self) -> list["RangeQuery"]:
        return [self[k] for k in range(len(self))]

    @property
    def dims_mask(self) -> np.ndarray:
        """(Q, m) bool — True where a dimension is actually constrained."""
        return ~(np.isneginf(self.lower) & np.isposinf(self.upper))

    def bounds_columnar(self, m_pad: int, q_pad: int | None = None,
                        dtype=np.float32) -> tuple[np.ndarray, np.ndarray]:
        """Query-minor (m_pad, q_pad or Q) finite bounds for the fused kernels.

        Padding dims (and unconstrained dims) carry the extrema of ``dtype``
        (the dtype the device comparison runs in), i.e. match-all against any
        finite value; padding *queries* (columns beyond Q, used to round the
        batch to a pow2 jit bucket) are match-all too — callers drop their
        output rows.
        """
        q_n = q_pad or len(self)
        lo = np.full((m_pad, q_n), NEG_INF, np.float32)
        up = np.full((m_pad, q_n), POS_INF, np.float32)
        lo[: self.m, : len(self)] = self.lower.T
        up[: self.m, : len(self)] = self.upper.T
        return finite_query_bounds(lo, up, dtype=dtype)

    def padded_dim_ids(self, q_pad: int | None = None) -> np.ndarray:
        """(q_pad or Q, D_max) int32 constrained-dim ids for the batched
        vertical scan.

        Shorter rows pad by repeating the query's own last constrained dim
        (AND is idempotent); a fully unconstrained query — and any padding
        query row — uses dim 0, whose bounds column is match-all. D_max
        rounds to a pow2 to bound jit retraces.
        """
        mask = self.dims_mask
        d_max = next_pow2(max(1, int(mask.sum(axis=1).max(initial=0))))
        ids = np.zeros((q_pad or len(self), d_max), np.int32)
        for k in range(len(self)):
            d = np.nonzero(mask[k])[0].astype(np.int32)
            if d.size == 0:
                d = np.zeros((1,), np.int32)
            ids[k] = np.pad(d, (0, d_max - d.size), mode="edge")
        return ids


@dataclasses.dataclass
class Dataset:
    """A columnar in-memory dataset: ``cols[j, i]`` = attribute j of object i.

    ``row(i)`` and ``rows()`` give the row-major view (the paper's horizontal
    layout) when needed.
    """

    cols: np.ndarray  # (m, n) float32

    def __post_init__(self):
        c = np.asarray(self.cols)
        if c.ndim != 2:
            raise ValueError(f"cols must be (m, n), got {c.shape}")
        self.cols = np.ascontiguousarray(c, dtype=np.float32)

    @property
    def m(self) -> int:
        return self.cols.shape[0]

    @property
    def n(self) -> int:
        return self.cols.shape[1]

    @property
    def nbytes(self) -> int:
        return self.cols.nbytes

    def rows(self) -> np.ndarray:
        return np.ascontiguousarray(self.cols.T)

    @staticmethod
    def from_rows(rows: np.ndarray) -> "Dataset":
        rows = np.asarray(rows, np.float32)
        return Dataset(np.ascontiguousarray(rows.T))

    def selectivity(self, q: RangeQuery) -> float:
        """Exact selectivity of ``q`` on this dataset (fraction in [0, 1])."""
        return float(match_mask_np(self.cols, q).mean())


def match_mask_np(cols: np.ndarray, q: RangeQuery) -> np.ndarray:
    """Numpy oracle: (n,) bool mask of objects matching q. O(n·m)."""
    lo = q.lower[:, None]
    up = q.upper[:, None]
    return np.logical_and(cols >= lo, cols <= up).all(axis=0)


def match_ids_np(cols: np.ndarray, q: RangeQuery) -> np.ndarray:
    """Numpy oracle: sorted identifiers of matching objects."""
    return np.nonzero(match_mask_np(cols, q))[0].astype(np.int64)


def mask_to_ids(mask) -> np.ndarray:
    """Device/host mask -> sorted id array (host-side, dynamic shape)."""
    return np.nonzero(np.asarray(mask))[0].astype(np.int64)


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (pow2 buckets bound jit retraces)."""
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def pad_axis(x: np.ndarray, axis: int, multiple: int, value) -> np.ndarray:
    """Pad ``axis`` of x up to the next multiple of ``multiple`` with value."""
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return np.pad(x, widths, constant_values=value)


def padded_query_bounds(
    q: RangeQuery, m_padded: int
) -> tuple[np.ndarray, np.ndarray]:
    """Query bounds padded to ``m_padded`` dims with [-inf, +inf] (match-all)."""
    lo = np.full((m_padded,), NEG_INF, np.float32)
    up = np.full((m_padded,), POS_INF, np.float32)
    lo[: q.m] = q.lower
    up[: q.m] = q.upper
    return lo, up


def finite_query_bounds(lo: np.ndarray, up: np.ndarray, dtype=np.float32):
    """Replace +-inf with the *target device dtype's* finite extrema.

    ``dtype`` must be the dtype the comparison actually runs in: substituting
    float32 extrema under a bfloat16 cast rounds ``finfo(f32).max`` back to
    ``+inf``, so the +inf object-padding sentinels *match* and every
    padded-axis reduction (``mask_counts``, ``visit_counts``, psum counts)
    overcounts. ``jnp.finfo`` understands bfloat16 (ml_dtypes); extrema are
    additionally clamped into float32's finite range because these carrier
    arrays are float32 — for a wider dtype (f64 under jax x64) the f32
    extrema are what survive the round trip finite, and all dataset values
    are f32-representable (``Dataset`` stores float32).
    """
    fin = jnp.finfo(dtype)
    f32 = np.finfo(np.float32)
    neg = max(float(fin.min), float(f32.min))
    pos = min(float(fin.max), float(f32.max))
    lo = np.where(np.isneginf(lo), neg, lo).astype(np.float32)
    up = np.where(np.isposinf(up), pos, up).astype(np.float32)
    return lo, up
