"""Core datatypes for multidimensional range queries (MDRQ).

Mirrors the paper's problem definition (§2.1):

  * a dataset ``D`` of ``n`` objects with ``m`` float attributes,
  * a (partial- or complete-match) range query ``q`` with per-dimension
    predicates ``[lb_j, ub_j]``; un-queried dimensions use ``[-inf, +inf]``,
  * a result = the set of identifiers of matching objects.

The canonical device layout is **dimension-major (columnar)**, shape ``(m, n)``
— the TPU-native realization of the paper's vertical partitioning (§3.2): the
last (lane) dimension runs over objects so one VREG holds 128 objects of one
attribute, and the AND-merge across dimensions happens in-register.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, ClassVar, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro import numerics

NEG_INF = np.float32(-np.inf)
POS_INF = np.float32(np.inf)

# Legacy result-mode strings (pre-ResultSpec protocol). Kept only for the
# ``mode="ids"|"count"`` back-compat shim in ``validate_mode``.
RESULT_MODES = ("ids", "count")


# =============================================================================
# ResultSpec — the first-class result protocol (DESIGN.md §9)
# =============================================================================
# The paper defines an MDRQ result as the materialized id set (§2.1), but the
# analytics workloads that motivate its scan-vs-index question mostly consume
# that set through a *reduction* — counts, extremes, top-k by an attribute.
# A ``ResultSpec`` names the shape a caller wants back and pairs
#
#   * an **on-device reducer** — applied to the (Q, n) match masks (or the
#     (V, tile_n) two-phase visit masks) inside the same jit as the kernel
#     that produced them, so only the reduced payload ever crosses the
#     device->host boundary, and
#   * a **host finalizer** — turning the fetched payload into one typed
#     result per query,
#
# plus the planner's output-bytes estimate and the per-query host fallback
# (``from_ids``) the generic ``PerQueryPath`` rung uses. Each access-path
# shape calls a fixed protocol method — there is no per-kind if/elif sweep
# anywhere in the engine — so a new result shape is one subclass plus
# ``register_result_spec``, exactly like registering a new access path.
#
# Specs are frozen (hashable) dataclasses: they ride jax.jit static args, so
# the reduction specializes at trace time per spec instance.

RESULT_SPEC_KINDS: dict[str, type] = {}


def register_result_spec(cls):
    """Register a ResultSpec subclass under ``cls.kind`` (decorator).

    Registration makes the kind addressable by name (``ServerStats``
    bucketing, benchmark ``--spec`` flags) — the result-shape analogue of
    ``MDRQEngine.register_path``.
    """
    RESULT_SPEC_KINDS[cls.kind] = cls
    return cls


@dataclasses.dataclass(frozen=True)
class ResultSpec:
    """Base of the result protocol: what a query should return, and how.

    Subclasses override the device reducers for the three execution shapes
    (full masks, two-phase visit masks, sharded masks) and the matching host
    finalizers. The base class implements the identity reduction (payload =
    the masks themselves) so mask-shaped specs (``Ids``, ``Mask``) need no
    device code at all.
    """

    kind: ClassVar[str] = "abstract"
    # True when the device payload stays sharded over the object axis under
    # shard_map (Ids/Mask); False when the reducer merges to a replicated
    # payload through collectives (Count/TopK/Agg).
    sharded_payload: ClassVar[bool] = False
    # True when ``reduce_visits`` consumes the host-built (Q, M) visit-index
    # table (TopK's gather); everyone else gets a (1, 1) placeholder so the
    # two-phase paths skip the build + transfer.
    needs_visit_index: ClassVar[bool] = False

    @property
    def value_dim(self) -> Optional[int]:
        """Attribute dimension whose values the reducer reads (None = none)."""
        return None

    def validate(self, m: int) -> "ResultSpec":
        """Check the spec against an m-dim dataset (canonical error site)."""
        d = self.value_dim
        if d is not None and not (0 <= d < m):
            raise ValueError(f"{self.kind} dim {d} out of range for m={m}")
        return self

    # -- on-device reducers (called inside the fused-kernel jits) ----------
    def device_reduce(self, masks, data_cm, *, tile_n: int, interpret: bool):
        """(q_pad, n_pad) match masks -> device payload (identity here)."""
        return masks

    def reduce_visits(self, masks, data_cm, qids, bids, valid, visit_index,
                      *, tile_n: int, n_queries: int, interpret: bool):
        """(V_pad, tile_n) two-phase visit masks -> device payload."""
        return masks

    def distributed_reduce(self, mask_local, data_local, axis: str):
        """Per-shard masks -> payload, inside shard_map (collectives OK)."""
        return mask_local

    # -- host finalizers ----------------------------------------------------
    def finalize(self, payload, q_n: int, n: int) -> list:
        """Host payload from the mask-shaped routes -> one result/query."""
        raise NotImplementedError

    def finalize_visits(self, payload, vctx: "VisitHostCtx") -> list:
        """Host payload from the visit-shaped route -> one result/query.

        Defaults to ``finalize`` — correct whenever the visit reducer already
        produced the same payload shape as the mask reducer (Count/TopK/Agg).
        """
        return self.finalize(payload, vctx.n_queries, vctx.n)

    def from_ids(self, ids: np.ndarray, cols: np.ndarray):
        """Host fallback from a materialized id set (``PerQueryPath`` rung)."""
        raise NotImplementedError

    # -- planner surface ----------------------------------------------------
    def host_bytes(self, touched, n: int):
        """Estimated device->host payload + host-materialization bytes per
        query. ``touched`` is the mask bytes the path would read back in the
        identity reduction (n for full scans, visited-fraction * n for the
        two-phase paths); scalar or (Q,) — the return broadcasts with it.
        """
        raise NotImplementedError

    # -- delta merge (mutable data plane, DESIGN.md §11) --------------------
    def merge_delta(self, base_results: list, delta_results: list,
                    dctx: "DeltaHostCtx") -> list:
        """Fold per-query delta results into the base results.

        Under a non-empty delta segment the fused jits evaluate base and
        delta in one launch and return two payloads; both finalize with the
        spec's ordinary host finalizer (the delta side in *local* delta
        coordinates, objects ``[0, d)``), and this hook combines them into
        one answer per query. Specs that don't implement it can't serve a
        mutated engine — ``compact()`` first.
        """
        raise NotImplementedError(
            f"result spec {self.kind!r} does not implement merge_delta; "
            f"compact() the engine before querying with it")

    # -- misc ---------------------------------------------------------------
    def empty_result(self, n: int):
        """The result of a query with an empty candidate set."""
        raise NotImplementedError

    def result_size(self, res) -> int:
        """Result magnitude for QueryStats/BatchStats ``n_results``."""
        raise NotImplementedError


@register_result_spec
@dataclasses.dataclass(frozen=True)
class Ids(ResultSpec):
    """Sorted matching identifiers — the paper's §2.1 result definition."""

    kind: ClassVar[str] = "ids"
    sharded_payload: ClassVar[bool] = True

    def finalize(self, payload, q_n, n):
        return [np.nonzero(payload[k, :n])[0].astype(np.int64)
                for k in range(q_n)]

    def finalize_visits(self, payload, vctx):
        from repro.core import blockindex  # runtime: no import cycle
        return blockindex.scatter_visit_results(
            payload[: vctx.qids.size], vctx.qids, vctx.bids, vctx.n_queries,
            vctx.tile_n, vctx.n, vctx.perm)

    def from_ids(self, ids, cols):
        return ids

    def merge_delta(self, base_results, delta_results, dctx):
        # Delta ids are all >= n (append order), so concatenation keeps the
        # per-query id arrays sorted.
        return [np.concatenate(
            [b, dctx.delta_ids[np.asarray(d, np.int64)]])
            for b, d in zip(base_results, delta_results)]

    def host_bytes(self, touched, n):
        # the mask readback plus the host-side nonzero sweep over it; the
        # materialized id arrays themselves are selectivity-proportional and
        # path-independent, so they never move a ranking
        return 2.0 * touched

    def empty_result(self, n):
        return np.empty((0,), np.int64)

    def result_size(self, res):
        return int(res.size)


@register_result_spec
@dataclasses.dataclass(frozen=True)
class Mask(ResultSpec):
    """The raw (n,) bool match mask per query (no id materialization)."""

    kind: ClassVar[str] = "mask"
    sharded_payload: ClassVar[bool] = True

    def finalize(self, payload, q_n, n):
        return [np.asarray(payload[k, :n]) > 0 for k in range(q_n)]

    def finalize_visits(self, payload, vctx):
        from repro.core import blockindex
        out = []
        for ids in blockindex.scatter_visit_results(
                payload[: vctx.qids.size], vctx.qids, vctx.bids,
                vctx.n_queries, vctx.tile_n, vctx.n, vctx.perm):
            m = np.zeros((vctx.n,), bool)
            m[ids] = True
            out.append(m)
        return out

    def from_ids(self, ids, cols):
        m = np.zeros((cols.shape[1],), bool)
        m[ids] = True
        return m

    def merge_delta(self, base_results, delta_results, dctx):
        # The merged mask covers the combined id space [0, n + d).
        out = []
        for b, d in zip(base_results, delta_results):
            m = np.zeros((dctx.n + dctx.delta_ids.size,), bool)
            m[: dctx.n] = b
            m[dctx.n:] = d
            out.append(m)
        return out

    def host_bytes(self, touched, n):
        return touched + float(n)

    def empty_result(self, n):
        return np.zeros((n,), bool)

    def result_size(self, res):
        return int(res.sum())


@register_result_spec
@dataclasses.dataclass(frozen=True)
class Count(ResultSpec):
    """Per-query match counts reduced on device (COUNT(*) fast path)."""

    kind: ClassVar[str] = "count"

    def device_reduce(self, masks, data_cm, *, tile_n, interpret):
        return jnp.sum(masks != 0, axis=-1).astype(jnp.int32)

    def reduce_visits(self, masks, data_cm, qids, bids, valid, visit_index,
                      *, tile_n, n_queries, interpret):
        from repro.kernels import reducers
        return reducers.visit_mask_counts(masks, qids, valid, n_queries)

    def distributed_reduce(self, mask_local, data_local, axis):
        import jax
        return jax.lax.psum(
            jnp.sum(mask_local != 0, axis=-1).astype(jnp.int32), axis)

    def finalize(self, payload, q_n, n):
        return [int(c) for c in np.asarray(payload)[:q_n]]

    def from_ids(self, ids, cols):
        return int(ids.size)

    def merge_delta(self, base_results, delta_results, dctx):
        return [int(b) + int(d)
                for b, d in zip(base_results, delta_results)]

    def host_bytes(self, touched, n):
        return 4.0 * np.ones_like(np.asarray(touched, np.float64))

    def empty_result(self, n):
        return 0

    def result_size(self, res):
        return int(res)


@register_result_spec
@dataclasses.dataclass(frozen=True)
class TopK(ResultSpec):
    """Top-k matching ids ordered by attribute ``dim`` (k-largest/smallest).

    The reducer fills non-matching lanes with the identity, runs a device
    ``top_k`` over the filled values, and ships only (k values, k positions,
    1 count) per query; the finalizer maps positions to original ids
    (through the structure's permutation where one exists) and truncates to
    the true match count. Ties order by ascending id (XLA top_k and the
    numpy fallback agree).
    """

    kind: ClassVar[str] = "topk"
    needs_visit_index: ClassVar[bool] = True
    k: int = 1
    dim: int = 0
    largest: bool = True

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"TopK k must be >= 1, got {self.k}")

    @property
    def value_dim(self):
        return self.dim

    @property
    def _fill(self) -> float:
        return -np.inf if self.largest else np.inf

    def device_reduce(self, masks, data_cm, *, tile_n, interpret):
        from repro.kernels import reducers
        return reducers.masked_topk(masks, data_cm[self.dim], self.k,
                                    self.largest, tile_n=tile_n,
                                    interpret=interpret)

    def reduce_visits(self, masks, data_cm, qids, bids, valid, visit_index,
                      *, tile_n, n_queries, interpret):
        from repro.kernels import reducers
        vblocks = reducers.gather_visit_values(data_cm, self.dim, bids, tile_n)
        vals, pos = reducers.visit_topk(masks, vblocks, bids, valid,
                                        visit_index, self.k, self.largest,
                                        tile_n)
        counts = reducers.visit_mask_counts(masks, qids, valid, n_queries)
        return vals, pos, counts

    def distributed_reduce(self, mask_local, data_local, axis):
        import jax
        lax = jax.lax
        vals = data_local[self.dim].astype(jnp.float32)
        filled = jnp.where(mask_local != 0, vals, self._fill)
        key = filled if self.largest else -filled
        kk = min(self.k, key.shape[-1])
        v, i = lax.top_k(key, kk)  # shard-local partials, key space
        gidx = i.astype(jnp.int32) \
            + lax.axis_index(axis).astype(jnp.int32) * data_local.shape[-1]
        counts = lax.psum(jnp.sum(mask_local != 0, axis=-1).astype(jnp.int32),
                          axis)
        vg = lax.all_gather(v, axis)      # (D, Q, kk) — the small collective
        ig = lax.all_gather(gidx, axis)
        d = vg.shape[0]
        q_n = v.shape[0]
        key_all = jnp.transpose(vg, (1, 0, 2)).reshape(q_n, d * kk)
        idx_all = jnp.transpose(ig, (1, 0, 2)).reshape(q_n, d * kk)
        v2, j = lax.top_k(key_all, min(self.k, d * kk))
        idx = jnp.take_along_axis(idx_all, j, axis=1)
        return (v2 if self.largest else -v2), idx, counts

    def finalize(self, payload, q_n, n):
        _, idx, counts = payload
        out = []
        for k in range(q_n):
            c = min(int(counts[k]), idx.shape[1], self.k)
            out.append(np.asarray(idx[k, :c]).astype(np.int64))
        return out

    def finalize_visits(self, payload, vctx):
        vals, pos, counts = payload
        out = []
        for k in range(vctx.n_queries):
            c = min(int(counts[k]), pos.shape[1], self.k)
            p = np.asarray(pos[k, :c]).astype(np.int64)
            out.append(vctx.perm[p] if vctx.perm is not None else p)
        return out

    def from_ids(self, ids, cols):
        vals = cols[self.dim, ids]
        order = np.argsort(-vals if self.largest else vals, kind="stable")
        return ids[order[: self.k]].astype(np.int64)

    def merge_delta(self, base_results, delta_results, dctx):
        # Exact: top-k of (base ∪ delta) ⊆ (top-k of base) ∪ (top-k of
        # delta), so re-ranking the ≤2k candidates by a host value gather
        # reproduces the frozen-dataset answer. Ties keep the ascending-id
        # order the device top_k produces.
        out = []
        for b, d in zip(base_results, delta_results):
            cand = np.concatenate(
                [np.asarray(b, np.int64),
                 dctx.delta_ids[np.asarray(d, np.int64)]])
            if cand.size == 0:
                out.append(cand)
                continue
            vals = np.where(
                cand < dctx.n,
                dctx.base_cols[self.dim, np.minimum(cand, dctx.n - 1)],
                dctx.delta_rows[np.maximum(cand - dctx.n, 0), self.dim])
            order = np.lexsort((cand, -vals if self.largest else vals))
            out.append(cand[order[: self.k]].astype(np.int64))
        return out

    def host_bytes(self, touched, n):
        return (12.0 * self.k + 4.0) \
            * np.ones_like(np.asarray(touched, np.float64))

    def empty_result(self, n):
        return np.empty((0,), np.int64)

    def result_size(self, res):
        return int(res.size)


@register_result_spec
@dataclasses.dataclass(frozen=True)
class Agg(ResultSpec):
    """A per-query aggregate (min | max | sum) of attribute ``dim`` over the
    matching set. Empty matches finalize to 0.0 (sum) or NaN (min/max)."""

    kind: ClassVar[str] = "agg"
    op: str = "sum"
    dim: int = 0

    OPS: ClassVar[tuple[str, ...]] = ("min", "max", "sum")

    def __post_init__(self):
        if self.op not in self.OPS:
            raise ValueError(f"unknown agg op {self.op!r}; options: {self.OPS}")

    @property
    def value_dim(self):
        return self.dim

    @property
    def _fill(self) -> float:
        return {"sum": 0.0, "min": np.inf, "max": -np.inf}[self.op]

    def device_reduce(self, masks, data_cm, *, tile_n, interpret):
        from repro.kernels import reducers
        return reducers.masked_agg(masks, data_cm[self.dim], self.op,
                                   tile_n=tile_n, interpret=interpret)

    def reduce_visits(self, masks, data_cm, qids, bids, valid, visit_index,
                      *, tile_n, n_queries, interpret):
        from repro.kernels import reducers
        vblocks = reducers.gather_visit_values(data_cm, self.dim, bids, tile_n)
        agg = reducers.visit_agg(masks, vblocks, qids, valid, self.op,
                                 n_queries)
        counts = reducers.visit_mask_counts(masks, qids, valid, n_queries)
        return agg, counts

    def distributed_reduce(self, mask_local, data_local, axis):
        import jax
        lax = jax.lax
        vals = data_local[self.dim].astype(jnp.float32)
        filled = jnp.where(mask_local != 0, vals, self._fill)
        local = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[self.op](
            filled, axis=-1)
        merge = {"sum": lax.psum, "min": lax.pmin, "max": lax.pmax}[self.op]
        counts = lax.psum(jnp.sum(mask_local != 0, axis=-1).astype(jnp.int32),
                          axis)
        return merge(local, axis), counts

    def finalize(self, payload, q_n, n):
        agg, counts = payload
        out = []
        for k in range(q_n):
            if int(counts[k]) == 0:
                out.append(self.empty_result(n))
            else:
                out.append(float(agg[k]))
        return out

    def from_ids(self, ids, cols):
        if ids.size == 0:
            return self.empty_result(cols.shape[1])
        vals = cols[self.dim, ids]
        if self.op == "sum":
            # float32 accumulation, matching the device reducer's dtype
            return float(np.sum(vals, dtype=np.float32))
        return float({"min": np.min, "max": np.max}[self.op](vals))

    def merge_delta(self, base_results, delta_results, dctx):
        # NaN marks an empty match set on min/max (the finalizer's empty
        # sentinel), so the combine is NaN-aware; sums add directly (empty
        # sides contribute the 0.0 identity).
        out = []
        for b, d in zip(base_results, delta_results):
            if self.op == "sum":
                out.append(float(b) + float(d))
            elif np.isnan(b):
                out.append(float(d))
            elif np.isnan(d):
                out.append(float(b))
            else:
                out.append(float({"min": min, "max": max}[self.op](b, d)))
        return out

    def host_bytes(self, touched, n):
        return 12.0 * np.ones_like(np.asarray(touched, np.float64))

    def empty_result(self, n):
        return 0.0 if self.op == "sum" else float("nan")

    def result_size(self, res):
        return 1


# Shared default instances (hash-stable jit static args; use these instead of
# constructing fresh specs in hot paths).
IDS = Ids()
COUNT = Count()

# Legacy mode-string vocabulary of the pre-spec protocol.
_MODE_SPECS: dict[str, ResultSpec] = {"ids": IDS, "count": COUNT}


@dataclasses.dataclass(frozen=True)
class VisitHostCtx:
    """Host-side context ``finalize_visits`` needs to map a visit-shaped
    payload back to per-query results (two-phase paths only)."""

    qids: np.ndarray            # (V,) int32 query id per real visit
    bids: np.ndarray            # (V,) int32 block id per real visit
    tile_n: int
    n: int                      # logical object count
    n_queries: int
    perm: Optional[np.ndarray]  # position -> original id (None = identity)


@dataclasses.dataclass(frozen=True)
class DeltaHostCtx:
    """Host-side context ``ResultSpec.merge_delta`` needs to fold per-query
    delta results (local delta coordinates) into base results (original ids).

    Built by ``core.delta.DeltaView.host_ctx``; the value arrays back the
    TopK re-rank's host gather.
    """

    n: int                      # base object count — delta ids start here
    delta_ids: np.ndarray       # (d,) int64 global ids of the delta rows
    base_cols: np.ndarray       # (m, n) base columns
    delta_rows: np.ndarray      # (d, m) delta rows


def validate_mode(mode) -> ResultSpec:
    """Canonicalize a result spec; the one place unknown specs are rejected.

    ``ResultSpec`` instances pass through untouched. The legacy string
    spellings ``"ids"`` / ``"count"`` map to ``Ids()`` / ``Count()`` with a
    single ``DeprecationWarning`` (every layer hands the resolved spec
    object down, so the warning fires once per user call, at the boundary).
    Anything else gets the canonical error.
    """
    if isinstance(mode, ResultSpec):
        return mode
    if isinstance(mode, str) and mode in _MODE_SPECS:
        warnings.warn(
            f"mode={mode!r} strings are deprecated; pass a ResultSpec "
            f"(types.{_MODE_SPECS[mode].kind.capitalize()}()) instead",
            DeprecationWarning, stacklevel=3)
        return _MODE_SPECS[mode]
    raise ValueError(f"unknown mode {mode!r}; options: {RESULT_MODES} "
                     f"or a types.ResultSpec")


def resolve_spec(spec=None, mode=None) -> ResultSpec:
    """Resolve the (spec=..., mode=...) kwarg pair of the public entry points.

    ``spec`` is the typed protocol; ``mode`` is the deprecated string alias.
    Both default to ``Ids()``; passing both is an error (ambiguous intent).
    """
    if spec is not None and mode is not None:
        raise ValueError("pass spec= or the deprecated mode=, not both")
    if spec is None and mode is None:
        return IDS
    return validate_mode(spec if spec is not None else mode)


@dataclasses.dataclass(frozen=True)
class RangeQuery:
    """A multidimensional range query (complete- or partial-match).

    ``lower``/``upper`` always have length ``m``; dimensions not mentioned in
    the query carry ``[-inf, +inf]`` (paper §2.1). ``dims_mask`` records which
    dimensions are actually constrained — engines use it to skip un-queried
    columns (the vertical-partitioning partial-match advantage, §3.2/§5.5).
    """

    lower: np.ndarray  # (m,) float32
    upper: np.ndarray  # (m,) float32

    def __post_init__(self):
        lo = np.asarray(self.lower, dtype=np.float32)
        up = np.asarray(self.upper, dtype=np.float32)
        if lo.shape != up.shape or lo.ndim != 1:
            raise ValueError(f"bad query bounds: {lo.shape} vs {up.shape}")
        object.__setattr__(self, "lower", lo)
        object.__setattr__(self, "upper", up)

    @property
    def m(self) -> int:
        return self.lower.shape[0]

    @property
    def dims_mask(self) -> np.ndarray:
        """(m,) bool — True where the dimension is actually constrained."""
        return ~(np.isneginf(self.lower) & np.isposinf(self.upper))

    @property
    def n_queried_dims(self) -> int:
        return int(self.dims_mask.sum())

    @property
    def is_complete_match(self) -> bool:
        return bool(self.dims_mask.all())

    @staticmethod
    def complete(lower: Sequence[float], upper: Sequence[float]) -> "RangeQuery":
        return RangeQuery(np.asarray(lower, np.float32), np.asarray(upper, np.float32))

    @staticmethod
    def partial(m: int, predicates: dict[int, tuple[float, float]]) -> "RangeQuery":
        """Partial-match query: ``{dim: (lb, ub)}`` over an m-dim space."""
        lo = np.full((m,), NEG_INF, np.float32)
        up = np.full((m,), POS_INF, np.float32)
        for j, (a, b) in predicates.items():
            lo[j], up[j] = np.float32(a), np.float32(b)
        return RangeQuery(lo, up)

    def reorder(self, order: np.ndarray) -> "RangeQuery":
        """Query with dimensions permuted by ``order`` (selectivity ordering)."""
        return RangeQuery(self.lower[order], self.upper[order])


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """An ordered batch of range queries over the same m-dim space.

    Batched execution: analytical workloads are streams of queries, and the
    fused multi-query kernels (``kernels.multi_scan``) evaluate a whole batch
    per launch. ``QueryBatch`` is the host-side carrier: bounds are stacked
    (Q, m) so the kernels' query-minor (m_pad, Q) layout and the per-query
    constrained-dim lists derive without touching each query again.
    """

    lower: np.ndarray  # (Q, m) float32
    upper: np.ndarray  # (Q, m) float32

    def __post_init__(self):
        lo = np.asarray(self.lower, dtype=np.float32)
        up = np.asarray(self.upper, dtype=np.float32)
        if lo.shape != up.shape or lo.ndim != 2:
            raise ValueError(f"bad batch bounds: {lo.shape} vs {up.shape}")
        object.__setattr__(self, "lower", lo)
        object.__setattr__(self, "upper", up)

    @staticmethod
    def from_queries(queries: Sequence["RangeQuery"]) -> "QueryBatch":
        if not queries:
            raise ValueError("empty query batch")
        m = queries[0].m
        for q in queries:
            if q.m != m:
                raise ValueError(f"mixed dims in batch: {q.m} != {m}")
        return QueryBatch(np.stack([q.lower for q in queries]),
                          np.stack([q.upper for q in queries]))

    def __len__(self) -> int:
        return self.lower.shape[0]

    def __getitem__(self, k: int) -> "RangeQuery":
        return RangeQuery(self.lower[k], self.upper[k])

    @property
    def m(self) -> int:
        return self.lower.shape[1]

    @property
    def queries(self) -> list["RangeQuery"]:
        return [self[k] for k in range(len(self))]

    @property
    def dims_mask(self) -> np.ndarray:
        """(Q, m) bool — True where a dimension is actually constrained."""
        return ~(np.isneginf(self.lower) & np.isposinf(self.upper))

    def bounds_columnar(self, m_pad: int, q_pad: int | None = None,
                        dtype=np.float32) -> tuple[np.ndarray, np.ndarray]:
        """Query-minor (m_pad, q_pad or Q) finite bounds for the fused kernels.

        Padding dims (and unconstrained dims) carry the extrema of ``dtype``
        (the dtype the device comparison runs in), i.e. match-all against any
        finite value; padding *queries* (columns beyond Q, used to round the
        batch to a pow2 jit bucket) are match-all too — callers drop their
        output rows.
        """
        q_n = q_pad or len(self)
        lo = np.full((m_pad, q_n), NEG_INF, np.float32)
        up = np.full((m_pad, q_n), POS_INF, np.float32)
        lo[: self.m, : len(self)] = self.lower.T
        up[: self.m, : len(self)] = self.upper.T
        return finite_query_bounds(lo, up, dtype=dtype)

    def padded_dim_ids(self, q_pad: int | None = None) -> np.ndarray:
        """(q_pad or Q, D_max) int32 constrained-dim ids for the batched
        vertical scan.

        Shorter rows pad by repeating the query's own last constrained dim
        (AND is idempotent); a fully unconstrained query — and any padding
        query row — uses dim 0, whose bounds column is match-all. D_max
        rounds to a pow2 to bound jit retraces.
        """
        mask = self.dims_mask
        d_max = next_pow2(max(1, int(mask.sum(axis=1).max(initial=0))))
        ids = np.zeros((q_pad or len(self), d_max), np.int32)
        for k in range(len(self)):
            d = np.nonzero(mask[k])[0].astype(np.int32)
            if d.size == 0:
                d = np.zeros((1,), np.int32)
            ids[k] = np.pad(d, (0, d_max - d.size), mode="edge")
        return ids


@dataclasses.dataclass
class Dataset:
    """A columnar in-memory dataset: ``cols[j, i]`` = attribute j of object i.

    ``row(i)`` and ``rows()`` give the row-major view (the paper's horizontal
    layout) when needed.
    """

    cols: np.ndarray  # (m, n) float32

    def __post_init__(self):
        c = np.asarray(self.cols)
        if c.ndim != 2:
            raise ValueError(f"cols must be (m, n), got {c.shape}")
        self.cols = np.ascontiguousarray(c, dtype=np.float32)

    @property
    def m(self) -> int:
        return self.cols.shape[0]

    @property
    def n(self) -> int:
        return self.cols.shape[1]

    @property
    def nbytes(self) -> int:
        return self.cols.nbytes

    def rows(self) -> np.ndarray:
        return np.ascontiguousarray(self.cols.T)

    @staticmethod
    def from_rows(rows: np.ndarray) -> "Dataset":
        rows = np.asarray(rows, np.float32)
        return Dataset(np.ascontiguousarray(rows.T))

    def selectivity(self, q: RangeQuery) -> float:
        """Exact selectivity of ``q`` on this dataset (fraction in [0, 1])."""
        return float(match_mask_np(self.cols, q).mean())


def match_mask_np(cols: np.ndarray, q: RangeQuery) -> np.ndarray:
    """Numpy oracle: (n,) bool mask of objects matching q. O(n·m)."""
    lo = q.lower[:, None]
    up = q.upper[:, None]
    return np.logical_and(cols >= lo, cols <= up).all(axis=0)


def match_ids_np(cols: np.ndarray, q: RangeQuery) -> np.ndarray:
    """Numpy oracle: sorted identifiers of matching objects."""
    return np.nonzero(match_mask_np(cols, q))[0].astype(np.int64)


def mask_to_ids(mask) -> np.ndarray:
    """Device/host mask -> sorted id array (host-side, dynamic shape)."""
    return np.nonzero(np.asarray(mask))[0].astype(np.int64)


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (pow2 buckets bound jit retraces)."""
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def pad_axis(x: np.ndarray, axis: int, multiple: int, value) -> np.ndarray:
    """Pad ``axis`` of x up to the next multiple of ``multiple`` with value."""
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return np.pad(x, widths, constant_values=value)


def padded_query_bounds(
    q: RangeQuery, m_padded: int
) -> tuple[np.ndarray, np.ndarray]:
    """Query bounds padded to ``m_padded`` dims with [-inf, +inf] (match-all)."""
    lo = np.full((m_padded,), NEG_INF, np.float32)
    up = np.full((m_padded,), POS_INF, np.float32)
    lo[: q.m] = q.lower
    up[: q.m] = q.upper
    return lo, up


def finite_query_bounds(lo: np.ndarray, up: np.ndarray, dtype=np.float32):
    """Replace +-inf with the *target device dtype's* finite extrema.

    ``dtype`` must be the dtype the comparison actually runs in: substituting
    float32 extrema under a bfloat16 cast rounds ``finfo(f32).max`` back to
    ``+inf``, so the +inf object-padding sentinels *match* and every
    padded-axis reduction (``mask_counts``, visit segment counts, psum counts)
    overcounts. ``jnp.finfo`` understands bfloat16 (ml_dtypes); extrema are
    additionally clamped into float32's finite range because these carrier
    arrays are float32 — for a wider dtype (f64 under jax x64) the f32
    extrema are what survive the round trip finite, and all dataset values
    are f32-representable (``Dataset`` stores float32).
    """
    neg = max(numerics.finite_min(dtype), numerics.finite_min(np.float32))
    pos = min(numerics.finite_max(dtype), numerics.finite_max(np.float32))
    lo = np.where(np.isneginf(lo), neg, lo).astype(np.float32)
    up = np.where(np.isposinf(up), pos, up).astype(np.float32)
    return lo, up
