"""Blocked kd-tree (TPU adaptation of the paper's §2.2.2 / §5.2).

Build: recursive median splits with round-robin delimiter dimensions — the
original Bentley policy the paper also uses ("promises a robust behavior over
a wide range of data distributions") — but splitting stops at *leaf blocks* of
``tile_n`` objects instead of single objects. Single-object nodes would force
~log2(n) dependent random accesses per root-to-leaf path, which on TPU costs
more than scanning a whole VMEM tile; the block leaf restores the arithmetic
intensity the VPU needs (DESIGN.md §2).

Query: shared two-phase plan from ``blockindex`` (vectorized hierarchy prune
-> Pallas visit kernel over surviving leaves). The hierarchy prune over
axis-aligned block boxes is exactly the kd-tree interval-overlap descent,
evaluated breadth-first over all nodes of a level at once.
"""
from __future__ import annotations

import numpy as np

from repro.core import types as T
from repro.core.blockindex import BlockedIndex, finish_build


def _median_split(
    cols: np.ndarray, idx: np.ndarray, depth: int, tile_n: int, order: list[np.ndarray]
) -> None:
    """Recursively split ``idx`` (ids into cols) until <= tile_n, in-order."""
    if idx.size <= tile_n:
        order.append(idx)
        return
    dim = depth % cols.shape[0]  # round-robin delimiter dimension (paper §2.2.2)
    vals = cols[dim, idx]
    half = idx.size // 2
    part = np.argpartition(vals, half)
    _median_split(cols, idx[part[:half]], depth + 1, tile_n, order)
    _median_split(cols, idx[part[half:]], depth + 1, tile_n, order)


def build_kdtree(
    dataset: T.Dataset, tile_n: int = 1024, fanout: int = 64
) -> BlockedIndex:
    """Build a blocked kd-tree over the dataset.

    Args:
      dataset: columnar dataset.
      tile_n: leaf block size (objects); 1024 = 8 VREG lanes rows of f32.
      fanout: MBR hierarchy fanout for the prune phase.
    """
    cols = dataset.cols
    order: list[np.ndarray] = []
    _median_split(cols, np.arange(dataset.n), 0, tile_n, order)
    perm = np.concatenate(order)
    cols_perm = cols[:, perm]
    return finish_build("kdtree", cols_perm, perm, tile_n, fanout)
