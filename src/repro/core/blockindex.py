"""Shared machinery for blocked (two-phase) MDIS on TPU.

Both tree MDIS in this framework — the blocked kd-tree and the packed STR
R*-tree — reduce at query time to the same TPU-native two-phase plan
(DESIGN.md §2):

  phase 1 (prune):  vectorized MBR-overlap tests over a small hierarchy of
                    per-block bounding boxes (device, one jit call);
  phase 2 (refine): the ``range_scan_visit`` Pallas kernel scans *only* the
                    surviving leaf blocks (grid size = #survivors, so pruned
                    blocks cost nothing — the TPU analogue of subtree pruning).

What distinguishes the structures is the *build*: how objects are permuted
into leaf blocks (median splits vs sort-tile-recursive vs storage order).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import types as T
from repro.kernels import ops


def build_hierarchy(
    leaf_lo: np.ndarray, leaf_hi: np.ndarray, fanout: int = 64
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Build MBR levels bottom-up from leaf MBRs.

    Args:
      leaf_lo, leaf_hi: (m, n_leaves) per-leaf bounding boxes (columnar).
      fanout: children per inner node.

    Returns:
      Levels from root to leaves: [(lo, hi), ...] each (m, n_nodes_level).
    """
    levels = [(leaf_lo, leaf_hi)]
    lo, hi = leaf_lo, leaf_hi
    while lo.shape[1] > 1:
        n_nodes = lo.shape[1]
        n_up = -(-n_nodes // fanout)
        pad = n_up * fanout - n_nodes
        lo_p = np.pad(lo, ((0, 0), (0, pad)), constant_values=np.inf)
        hi_p = np.pad(hi, ((0, 0), (0, pad)), constant_values=-np.inf)
        lo = lo_p.reshape(lo.shape[0], n_up, fanout).min(axis=2)
        hi = hi_p.reshape(hi.shape[0], n_up, fanout).max(axis=2)
        levels.append((lo, hi))
        if n_up == 1:
            break
    return levels[::-1]  # root first


@functools.partial(jax.jit, static_argnames=("fanout",))
def _prune_hierarchy_jit(
    levels_lo: tuple[jax.Array, ...],
    levels_hi: tuple[jax.Array, ...],
    qlo: jax.Array,
    qhi: jax.Array,
    fanout: int,
) -> jax.Array:
    """Top-down vectorized MBR pruning.

    Args:
      levels_lo/hi: root-first tuples of (m, n_nodes) MBR bounds.
      qlo, qhi: (m, 1) query bounds.

    Returns:
      (n_leaves,) bool — leaves whose MBR intersects the query box.
    """
    ops.note_trace("prune_hierarchy")
    active = None
    for lo, hi in zip(levels_lo, levels_hi):
        overlap = jnp.all(jnp.logical_and(hi >= qlo, lo <= qhi), axis=0)
        if active is None:
            active = overlap
        else:
            parents = jnp.repeat(active, fanout)[: overlap.shape[0]]
            active = jnp.logical_and(parents, overlap)
    return active


prune_hierarchy = ops.counted(
    "prune_hierarchy",
    "Phase-1 MBR hierarchy prune for one query (the tree MDIS's extra "
    "launch on top of the fused visit kernel).",
)(_prune_hierarchy_jit)


@functools.partial(jax.jit, static_argnames=("fanout",))
def _prune_hierarchy_batch_jit(
    levels_lo: tuple[jax.Array, ...],
    levels_hi: tuple[jax.Array, ...],
    qlo: jax.Array,
    qhi: jax.Array,
    fanout: int,
) -> jax.Array:
    """Batched top-down MBR pruning: all queries of a batch in one jit call.

    Args:
      levels_lo/hi: root-first tuples of (m, n_nodes) MBR bounds.
      qlo, qhi: (m, Q) query bounds, one column per query.

    Returns:
      (Q, n_leaves) bool — per-query leaf survivors.
    """
    ops.note_trace("prune_hierarchy_batch")
    active = None
    for lo, hi in zip(levels_lo, levels_hi):
        overlap = jnp.all(
            jnp.logical_and(hi[:, None, :] >= qlo[:, :, None],
                            lo[:, None, :] <= qhi[:, :, None]),
            axis=0,
        )  # (Q, n_nodes)
        if active is None:
            active = overlap
        else:
            parents = jnp.repeat(active, fanout, axis=1)[:, : overlap.shape[1]]
            active = jnp.logical_and(parents, overlap)
    return active


prune_hierarchy_batch = ops.counted(
    "prune_hierarchy_batch",
    "Batched phase-1 MBR hierarchy prune: every query of a batch in one "
    "vectorized launch (the tree paths' real budget is this launch + its "
    "survivor-mask sync on top of the fused visit launch).",
)(_prune_hierarchy_batch_jit)


_next_pow2 = T.next_pow2


def _pad_visit_list(
    query_ids: np.ndarray, block_ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Pad a flattened (query, block) visit list to a pow2 jit bucket.

    Padding rows carry query 0 / block -1 — the visit kernel clamps negative
    block ids to 0, so callers must drop (ids mode) or zero out (count mode)
    the padding rows' output.
    """
    n_visit = _next_pow2(query_ids.size)
    qids_p = np.zeros((n_visit,), np.int32)
    bids_p = np.full((n_visit,), -1, np.int32)
    qids_p[: query_ids.size] = query_ids
    bids_p[: block_ids.size] = block_ids
    return qids_p, bids_p


def _build_visit_index(query_ids: np.ndarray, n_queries: int,
                       n_visit_pad: int) -> np.ndarray:
    """(n_queries, M) table of padded-visit row indices per query.

    M is the pow2-padded maximum visit count of any query (bounds jit
    retraces); empty slots point at row ``n_visit_pad`` — the sentinel fill
    row the top-k visit reducer appends. One argsort pass, no Python loop
    over queries.
    """
    counts = np.bincount(query_ids, minlength=n_queries)
    m_vis = _next_pow2(max(int(counts.max(initial=0)), 1))
    index = np.full((n_queries, m_vis), n_visit_pad, np.int32)
    order = np.argsort(query_ids, kind="stable")
    starts = np.zeros(n_queries + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    slots = np.arange(query_ids.size) - starts[query_ids[order]]
    index[query_ids[order], slots] = order.astype(np.int32)
    return index


def reduce_visits_batch(
    data_dev: jax.Array,
    query_ids: np.ndarray,
    block_ids: np.ndarray,
    batch: T.QueryBatch,
    tile_n: int,
    n_queries: int,
    spec: T.ResultSpec,
    n: int,
    perm: np.ndarray | None = None,
    delta=None,
) -> list:
    """Phase 2 of every batched two-phase path, under any ResultSpec.

    Pads the flattened (query, block) visit list to a pow2 bucket, runs ONE
    ``ops.multi_visit_reduce`` launch (the visit kernel + the spec's
    on-device visit reducer in the same jit), fetches the payload in one
    host sync, and finalizes per query. Shared by the tree MDIS and the
    VA-file so a new result shape lands on both at once.

    ``delta`` (a ``core.delta.DeltaView``) rides the same launch: base
    tombstones gather per visited block and AND into the visit masks, the
    delta block scans with the batch bounds, and the spec merges the halves.
    """
    payload, fin = launch_visits_batch(data_dev, query_ids, block_ids, batch,
                                       tile_n, n_queries, spec, n, perm=perm,
                                       delta=delta)
    return fin(ops.device_get(payload) if payload is not None else None)


def launch_visits_batch(
    data_dev: jax.Array,
    query_ids: np.ndarray,
    block_ids: np.ndarray,
    batch: T.QueryBatch,
    tile_n: int,
    n_queries: int,
    spec: T.ResultSpec,
    n: int,
    perm: np.ndarray | None = None,
    delta=None,
) -> tuple:
    """Device half of ``reduce_visits_batch``: one launch, no host sync.

    Returns ``(payload, finalize)``; the caller owns the single counted
    ``ops.device_get(payload)`` and hands its host value to ``finalize`` —
    which is what lets the pipelined server run the sync + host finalizers on
    a different thread from the launch. ``payload`` is ``None`` (and the
    host value ignored) when nothing pruned through on a frozen dataset —
    that corner has no device work at all.
    """
    dview = delta if delta is not None and not delta.is_empty else None
    dcm = dview.device_cm(tile_n) if dview is not None else None
    if query_ids.size == 0:
        # Nothing pruned through — but a non-empty delta still has to scan.
        # This corner pays one delta-only launch (vs zero on a frozen
        # dataset); the normal non-empty-visit case stays at one launch.
        base = [spec.empty_result(n) for _ in range(n_queries)]
        if dcm is None:
            return None, lambda _host: base
        lo_d, up_d = ops.batch_bounds_device(batch, dcm.shape[0], dcm.dtype,
                                             q_pad=_next_pow2(len(batch)))
        payload = ops.multi_scan_reduce(dcm, lo_d, up_d, spec=spec,
                                        tile_n=tile_n)
        d_n, host_ctx = dview.d, dview.host_ctx()

        def finalize_empty(host_payload):
            dres = spec.finalize(host_payload, n_queries, d_n)
            return spec.merge_delta(base, dres, host_ctx)
        return payload, finalize_empty
    tomb = None
    if dview is not None:
        key = None if perm is None else ("perm", id(perm),
                                         int(data_dev.shape[1]))
        tomb = dview.base_tomb_dev(data_dev.shape[1], perm=perm, key=key)
    qids_p, bids_p = _pad_visit_list(query_ids, block_ids)
    q_bucket = _next_pow2(max(n_queries, 1))  # pow2 bounds jit retraces
    # The per-query visit-index table only feeds TopK's gather; every other
    # spec ignores it, so it is built (and shipped) on demand — a (1, 1)
    # placeholder keeps the jit signature stable for the rest.
    if spec.needs_visit_index:
        visit_index = _build_visit_index(query_ids.astype(np.int64), q_bucket,
                                         qids_p.size)
    else:
        visit_index = np.zeros((1, 1), np.int32)
    lo_d, up_d = ops.batch_bounds_device(batch, data_dev.shape[0],
                                         data_dev.dtype,
                                         q_pad=_next_pow2(len(batch)))
    payload = ops.multi_visit_reduce(
        data_dev, jnp.asarray(qids_p), jnp.asarray(bids_p),
        jnp.asarray((bids_p >= 0).astype(np.int32)),
        jnp.asarray(visit_index), lo_d, up_d, dcm, tomb,
        spec=spec, tile_n=tile_n, n_queries=q_bucket,
    )
    vctx = T.VisitHostCtx(
        qids=query_ids.astype(np.int32), bids=block_ids.astype(np.int32),
        tile_n=tile_n, n=n, n_queries=n_queries, perm=perm)
    if dcm is None:
        def finalize(host_payload):
            return spec.finalize_visits(host_payload, vctx)
        return payload, finalize
    d_n, host_ctx = dview.d, dview.host_ctx()

    def finalize_delta(host_payload):
        base_host, delta_host = host_payload
        base = spec.finalize_visits(base_host, vctx)
        dres = spec.finalize(delta_host, n_queries, d_n)
        return spec.merge_delta(base, dres, host_ctx)
    return payload, finalize_delta


def scatter_visit_results(
    masks: np.ndarray,
    query_ids: np.ndarray,
    block_ids: np.ndarray,
    n_queries: int,
    tile_n: int,
    n: int,
    perm: np.ndarray | None = None,
) -> list[np.ndarray]:
    """Turn fused (V, tile_n) visit masks back into per-query sorted id arrays.

    Shared tail of every batched two-phase path (tree and VA-file): each visit
    row holds the match mask of one (query, block) pair; positions map through
    ``perm`` (when the structure permuted objects) and object padding drops.
    Visit rows are grouped by query with one argsort + searchsorted pass
    (O(V log V)) instead of rescanning the whole visit list per query (O(Q·V)).
    """
    results: list[np.ndarray] = [np.empty((0,), np.int64) for _ in range(n_queries)]
    offsets = np.arange(tile_n)
    order = np.argsort(query_ids, kind="stable")
    qids_sorted = query_ids[order]
    bounds = np.searchsorted(qids_sorted, np.arange(n_queries + 1))
    for k in range(n_queries):
        rows = order[bounds[k]: bounds[k + 1]]
        if rows.size == 0:
            continue
        pos = block_ids[rows][:, None] * tile_n + offsets[None, :]
        pos = pos[masks[rows] > 0]
        pos = pos[pos < n]
        if perm is not None:
            pos = perm[pos]
        results[k] = np.sort(pos).astype(np.int64)
    return results


@dataclasses.dataclass
class BlockedIndex:
    """A built blocked MDIS instance (query-side shared by kd-tree / R-tree).

    Attributes:
      name: structure name ("kdtree" | "rstar").
      data_dev: (m_pad, n_pad) permuted columnar data on device.
      perm: (n,) original object id of each permuted position.
      levels: root-first MBR hierarchy, device arrays.
      tile_n: leaf block size (objects per leaf).
      m, n: logical sizes.
    """

    name: str
    data_dev: jax.Array
    perm: np.ndarray
    levels_lo: tuple[jax.Array, ...]
    levels_hi: tuple[jax.Array, ...]
    fanout: int
    tile_n: int
    m: int
    n: int

    # -- stats of the last query (for benchmarks / planner calibration) --
    last_visited_blocks: int = 0

    @property
    def n_leaves(self) -> int:
        return self.data_dev.shape[1] // self.tile_n

    @property
    def nbytes_index(self) -> int:
        """Extra memory vs a plain scan (MBR hierarchy; paper §7.2 metric)."""
        return sum(int(np.prod(l.shape)) * 4 * 2 for l in self.levels_lo)

    def query_leaf_mask(self, q: T.RangeQuery) -> np.ndarray:
        """Phase 1: (n_leaves,) bool survivors of the hierarchy prune."""
        qlo, qhi = ops.query_bounds_device(q, self.m, jnp.float32)
        mask = prune_hierarchy(self.levels_lo, self.levels_hi, qlo, qhi,
                               fanout=self.fanout)
        return ops.device_get(mask)

    def query(self, q: T.RangeQuery) -> np.ndarray:
        """Full query -> sorted original ids of matching objects."""
        leaf_mask = self.query_leaf_mask(q)
        survivors = np.nonzero(leaf_mask)[0].astype(np.int32)
        self.last_visited_blocks = int(survivors.size)
        if survivors.size == 0:
            return np.empty((0,), np.int64)
        # Pad the visit list to a pow2 bucket to bound jit retraces.
        n_visit = _next_pow2(survivors.size)
        ids = np.full((n_visit,), -1, np.int32)
        ids[: survivors.size] = survivors
        qlo, qhi = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        masks = ops.range_scan_visit(self.data_dev, jnp.asarray(ids), qlo, qhi,
                                     tile_n=self.tile_n)
        masks = ops.device_get(masks)[: survivors.size]  # (v, tile_n)
        # Map (block, offset) -> permuted position -> original id.
        pos = (survivors[:, None] * self.tile_n + np.arange(self.tile_n)[None, :])
        pos = pos[masks > 0]
        pos = pos[pos < self.n]  # drop object padding
        return np.sort(self.perm[pos]).astype(np.int64)

    def count(self, q: T.RangeQuery) -> int:
        """Count-only query: visit masks are summed on device (no id arrays —
        counts are permutation-invariant, so ``perm`` never enters)."""
        leaf_mask = self.query_leaf_mask(q)
        survivors = np.nonzero(leaf_mask)[0].astype(np.int32)
        self.last_visited_blocks = int(survivors.size)
        if survivors.size == 0:
            return 0
        n_visit = _next_pow2(survivors.size)
        ids = np.full((n_visit,), -1, np.int32)
        ids[: survivors.size] = survivors
        qlo, qhi = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        masks = ops.range_scan_visit(self.data_dev, jnp.asarray(ids), qlo, qhi,
                                     tile_n=self.tile_n)
        # padding visits (id -1, clamped to block 0) are sliced off on device
        return int(ops.device_get(jnp.sum(masks[: survivors.size] != 0)))

    def launch_batch(self, batch: T.QueryBatch, spec: T.ResultSpec = T.IDS,
                     delta=None) -> tuple:
        """Device half of the batched two-phase query -> (payload, finalize).

        The prune phase is inherently a mid-stage sync (the surviving
        (query, block) pairs decide the visit launch's shapes), so it runs
        here — in the device stage — along with the fused visit *launch*;
        what the returned ``finalize`` defers to the caller's thread is the
        payload sync + the spec's host finalizers, the host-heavy tail.
        ``payload`` is None (host value ignored) when nothing pruned through
        on a frozen dataset.
        """
        spec = T.validate_mode(spec).validate(self.m)
        q_n = len(batch)
        q_pad = _next_pow2(q_n)  # pow2 query bucket bounds jit retraces
        qlo, qhi = batch.bounds_columnar(self.m, q_pad)
        leaf_mask = ops.device_get(prune_hierarchy_batch(
            self.levels_lo, self.levels_hi,
            jnp.asarray(qlo), jnp.asarray(qhi), fanout=self.fanout,
        ))[:q_n]  # (Q, n_leaves); padding queries are match-all -> dropped
        qids, bids = np.nonzero(leaf_mask)
        self.last_visited_blocks = int(qids.size)
        return launch_visits_batch(
            self.data_dev, qids.astype(np.int32), bids.astype(np.int32),
            batch, self.tile_n, q_n, spec, self.n, perm=self.perm,
            delta=delta,
        )

    def query_batch(self, batch: T.QueryBatch, spec: T.ResultSpec = T.IDS,
                    delta=None) -> list:
        """Batched two-phase query: one counted prune launch (+ its
        survivor-mask sync) + one fused visit launch (+ its payload sync).

        Phase 1 prunes all Q queries' hierarchies in a single vectorized
        call; phase 2 flattens the surviving (query, block) pairs into one
        ``multi_visit_reduce`` launch that carries the ResultSpec's
        on-device reducer, so per-query dispatch and host-sync taxes are
        paid once per batch and reduced shapes (count, top-k, aggregate)
        ship only their payload. Both phases are visible to the launch /
        host-sync counters (mdrqlint's host-sync rule keeps it that way). Positions map through ``perm`` in the
        spec's finalizer (counts and aggregates are permutation-invariant).
        """
        payload, fin = self.launch_batch(batch, spec=spec, delta=delta)
        return fin(ops.device_get(payload) if payload is not None else None)


def finish_build(
    name: str,
    cols_perm: np.ndarray,
    perm: np.ndarray,
    tile_n: int,
    fanout: int,
    dtype=jnp.float32,
) -> BlockedIndex:
    """Common tail of every build: pad, compute leaf MBRs, build hierarchy.

    Args:
      cols_perm: (m, n) columnar data already permuted into leaf order.
      perm: (n,) original id per permuted position.
    """
    m, n = cols_perm.shape
    padded, _, _ = ops.prepare_columnar(cols_perm, tile_n=tile_n)
    n_leaves = padded.shape[1] // tile_n
    blocks = padded[:m].reshape(m, n_leaves, tile_n)
    # +inf object padding poisons MBR lows/highs of the last block; mask it.
    leaf_lo = np.where(np.isposinf(blocks), np.inf, blocks).min(axis=2)
    leaf_hi = np.where(np.isposinf(blocks), -np.inf, blocks).max(axis=2)
    levels = build_hierarchy(leaf_lo, leaf_hi, fanout=fanout)
    return BlockedIndex(
        name=name,
        data_dev=jnp.asarray(padded, dtype=dtype),
        perm=np.asarray(perm),
        levels_lo=tuple(jnp.asarray(lo) for lo, _ in levels),
        levels_hi=tuple(jnp.asarray(hi) for _, hi in levels),
        fanout=fanout,
        tile_n=tile_n,
        m=m,
        n=n,
    )
