"""Access-path planner: the paper's break-even rule as an operational cost model.

The paper's headline result is that the scan-vs-index break-even selectivity
drops from the classical 15-20% to ~1% on modern hardware (§8). Here that
conclusion becomes machinery: per-dimension equi-width histograms estimate
query selectivity (independence assumption, §2.1 — the paper notes it fails
for correlated dims, so estimates are clamped and calibration is exposed), and
an analytic byte-cost model ranks the available access paths.

Cost model (napkin terms, all in bytes moved + per-dispatch overhead):

  scan_full      : n * m * B
  scan_vertical  : n * m_q * B                      (partial match, §5.5)
  kdtree / rstar : nodes * m * 2B  +  f_leaf * n * m * B / visit_discount  + sync
  vafile         : n * ceil(m/16) * 4  +  f_blk * n * m * B / visit_discount + sync

with ``f_leaf ~= prod_over_queried (s^(1/m_q) + l)``, ``l = (tile/n)^(1/m)``
(query box side + leaf box side per dim) and the VA candidate fraction
``prod (s_j + 2/CELLS)``.

The two index-specific taxes are the TPU translation of the paper's
random-access penalty: two-phase execution needs a device->host->device round
trip (``host_sync_overhead``) to turn the prune mask into a visit list, and
the visit kernel's scattered tile DMAs run below streaming HBM bandwidth
(``visit_bw_discount``). These terms are what move the break-even point — with
them the model reproduces the paper's structure: scans always win at small n
(sync floor dominates, Fig. 7), indexes only win at high selectivity
(Fig. 6), and the break-even lands near 1% at the paper's 1M-object scale.
``calibrate()`` fits the machine constants from measured runs.

Batched execution: every cost accepts a ``batch`` size — the number of
queries fused into one launch (``MDRQEngine.query_batch``). Fixed taxes
(dispatch, host sync) divide by the batch, and the fused scans' streamed
bytes amortize down to a VPU compute floor (``sec_per_cmp``). The two effects
pull the scan-vs-index break-even in *opposite* directions, and
``break_even_selectivity(batch_size=...)`` reports the net — a result the
paper's single-query analysis cannot express.

Batch planning is vectorized and runs to a fixpoint (DESIGN.md §7): one numpy
pass over the (Q, 2, m) bounds estimates every query's selectivity
(``Histograms.selectivity_batch``), each registered access path prices all Q
queries at once (``AccessPath.cost_batch`` -> a (paths x Q) cost matrix), and
``plan_batch`` iterates plan -> bucket -> replan so the amortization uses the
*realized* per-bucket sizes — not the whole-batch approximation — converging
in 2-3 rounds because every amortized term is monotone in bucket size.
Planning cost no longer grows Python-linearly with Q.

The planner itself is access-path-agnostic: it ranks whatever path objects it
holds (the engine's registry, or structure-free stubs when built from names
for cost-model studies). Path-specific formulas live in the ``CostModel``
methods the ``core.paths`` cost mixins delegate to.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core import types as T
from repro.core import paths as paths_mod
# The VA-file's cell resolution and packing density: the planning slack
# (2/CELLS per dim) and the approximation bytes (ceil(m / DIMS_PER_WORD)
# words) derive from the same constants the build and the kernel use, so a
# cell-resolution change can never silently skew the plan vs the execution.
from repro.core.vafile import CELLS as VA_CELLS
from repro.kernels.va_filter import DIMS_PER_WORD as VA_DIMS_PER_WORD

BINS = 64


@dataclasses.dataclass
class Histograms:
    """Per-dimension equi-width histograms for selectivity estimation."""

    edges: np.ndarray   # (m, BINS + 1)
    counts: np.ndarray  # (m, BINS)
    n: int

    @staticmethod
    def build(dataset: T.Dataset, bins: int = BINS) -> "Histograms":
        m, n = dataset.m, dataset.n
        edges = np.empty((m, bins + 1), np.float64)
        counts = np.empty((m, bins), np.float64)
        for d in range(m):
            c, e = np.histogram(dataset.cols[d], bins=bins)
            edges[d], counts[d] = e, c
        return Histograms(edges=edges, counts=counts, n=n)

    def dim_selectivity(self, d: int, lb: float, ub: float) -> float:
        """Estimated fraction of objects with attribute d in [lb, ub].

        Any predicate overlapping the observed domain is clamped to at least
        ``1/n`` — including *point* predicates (``lb == ub``, ubiquitous in
        GMRQB mixed workloads), whose bin coverage is zero-width and would
        otherwise estimate 0.0 and mis-rank every access path.
        """
        if np.isneginf(lb) and np.isposinf(ub):
            return 1.0
        if ub < lb:
            return 0.0  # empty range
        e, c = self.edges[d], self.counts[d]
        if ub < e[0] or lb > e[-1]:
            return 0.0  # disjoint from the observed domain
        lo = np.clip(lb, e[0], e[-1])
        hi = np.clip(ub, e[0], e[-1])
        widths = np.diff(e)
        # fraction of each bin covered by [lo, hi]
        cover = np.clip((np.minimum(hi, e[1:]) - np.maximum(lo, e[:-1])) / np.maximum(widths, 1e-30), 0.0, 1.0)
        frac = float((c * cover).sum() / max(self.n, 1))
        return min(1.0, max(frac, 1.0 / max(self.n, 1)))

    def selectivity(self, q: T.RangeQuery) -> float:
        """Independence-assumption estimate of query selectivity (§2.1).

        Floored at ``1/n`` unless some dimension is provably disjoint from
        the domain: an estimate of "at least one match" is the standard
        planner convention, and it keeps point queries rankable.
        """
        s = 1.0
        for d in np.nonzero(q.dims_mask)[0]:
            s *= self.dim_selectivity(int(d), float(q.lower[d]), float(q.upper[d]))
            if s == 0.0:
                return 0.0
        return max(s, 1.0 / max(self.n, 1))

    # -- vectorized estimation (batch planning) ----------------------------
    def dim_selectivity_batch(self, lower: np.ndarray, upper: np.ndarray
                              ) -> np.ndarray:
        """(Q, m) per-dimension selectivities in one numpy pass.

        Vectorizes ``dim_selectivity`` over queries *and* dimensions — the
        (Q, 2, m) bounds broadcast against the (m, BINS) histograms, so batch
        planning never loops per query per dim in Python. Values match the
        scalar method exactly per (query, dim), including the special cases:
        unconstrained dims (1.0), empty ranges and predicates disjoint from
        the observed domain (0.0), and the in-domain >= 1/n clamp that keeps
        point predicates rankable.
        """
        lo_q = np.asarray(lower, np.float64)
        up_q = np.asarray(upper, np.float64)
        e, c = self.edges, self.counts                     # (m, B+1), (m, B)
        e_lo, e_hi = e[:, 0], e[:, -1]                     # (m,)
        lo = np.clip(lo_q, e_lo, e_hi)                     # (Q, m)
        hi = np.clip(up_q, e_lo, e_hi)
        widths = np.maximum(np.diff(e, axis=1), 1e-30)     # (m, B)
        # fraction of each bin covered by [lo, hi] -> (Q, m, B)
        cover = np.clip(
            (np.minimum(hi[:, :, None], e[None, :, 1:])
             - np.maximum(lo[:, :, None], e[None, :, :-1])) / widths[None],
            0.0, 1.0)
        frac = (c[None] * cover).sum(axis=2) / max(self.n, 1)
        sel = np.minimum(1.0, np.maximum(frac, 1.0 / max(self.n, 1)))
        unconstrained = np.isneginf(lo_q) & np.isposinf(up_q)
        dead = (up_q < lo_q) | (up_q < e_lo) | (lo_q > e_hi)
        return np.where(unconstrained, 1.0, np.where(dead, 0.0, sel))

    def selectivity_batch(self, lower: np.ndarray, upper: np.ndarray,
                          dim_sels: Optional[np.ndarray] = None) -> np.ndarray:
        """(Q,) independence-assumption selectivities for a whole batch.

        One vectorized pass over the (Q, 2, m) bounds; per query the value is
        identical to scalar ``selectivity`` (pass ``dim_sels`` to reuse an
        existing ``dim_selectivity_batch`` result). The scalar method early-
        exits with 0.0 the moment a running product hits zero — a provably
        disjoint dim, or float underflow — and otherwise floors the final
        product at 1/n; the prefix-product check reproduces both exactly
        (unconstrained dims contribute an exact 1.0 factor, so interleaving
        them does not perturb the product).
        """
        if dim_sels is None:
            dim_sels = self.dim_selectivity_batch(lower, upper)
        prefix = np.cumprod(dim_sels, axis=1)
        dead = (prefix == 0.0).any(axis=1)
        return np.where(dead, 0.0,
                        np.maximum(prefix[:, -1], 1.0 / max(self.n, 1)))


@dataclasses.dataclass
class CostModel:
    """Analytic access-path cost model with calibratable machine constants."""

    n: int
    m: int
    tile_n: int = 1024
    bytes_per_val: int = 4
    # Devices the scan shards over (horizontal partitioning, §3.1 — the
    # paper's thread count t mapped to a mesh). Streamed bytes and the VPU
    # compute floor both divide by it; indexes stay single-device.
    n_devices: int = 1
    # machine constants — defaults in v5e roofline units (s); calibrate() refits.
    sec_per_byte: float = 1.0 / 819e9
    dispatch_overhead: float = 2e-6
    host_sync_overhead: float = 20e-6  # device->host->device visit-list turn
    visit_bw_discount: float = 0.6     # scattered tile DMA vs streaming scan
    sec_per_cmp: float = 2.5e-13       # VPU compare+AND per element (~4e12/s)
    collective_overhead: float = 5e-6  # per-launch shard_map dispatch + psum tax
    # Device->host payload + host-materialization rate (PCIe-ish, far below
    # HBM): what the ResultSpec layer's output-bytes term multiplies. Reduced
    # specs (count / top-k / aggregate) read back O(1)-O(k) bytes per query
    # where Ids/Mask read back the whole (or visited-fraction of the) mask —
    # this term is what makes ``plan_batch`` spec-dependent.
    sec_per_result_byte: float = 1.0 / 16e9
    # Live delta-segment rows layered over the frozen structures (DESIGN.md
    # §11). Every path's batch launch additionally scans the delta block, so
    # every cost picks up the same per-*launch* delta term — amortized over
    # the path's realized bucket. That amortization is what flips plans as
    # the delta grows: a minority-bucket index pick pays the delta scan over
    # a few queries where the big scan bucket splits it Q ways. The engine
    # refreshes this from the delta snapshot before each plan.
    delta_n: int = 0

    def _bytes_cost(self, nbytes: float, dispatches: float = 1.0,
                    batch: int = 1) -> float:
        return (nbytes * self.sec_per_byte
                + dispatches * self.dispatch_overhead / max(batch, 1))

    # -- delta-segment term (shared by every path cost) --------------------
    def _delta_cost(self, batch: int = 1) -> float:
        """Per-query seconds for the delta-block scan a batch launch folds
        in: streamed bytes amortize over the fused batch, the per-query
        compare floor does not (same shape as ``_scan_cost``)."""
        if self.delta_n <= 0:
            return 0.0
        elems = float(self.delta_n) * self.m
        stream = elems * self.bytes_per_val * self.sec_per_byte / max(batch, 1)
        return max(stream, elems * self.sec_per_cmp)

    def _delta_cost_batch(self, bucket: np.ndarray) -> np.ndarray:
        b = np.maximum(np.asarray(bucket, np.float64), 1.0)
        if self.delta_n <= 0:
            return np.zeros_like(b)
        elems = float(self.delta_n) * self.m
        stream = elems * self.bytes_per_val * self.sec_per_byte / b
        return np.maximum(stream, elems * self.sec_per_cmp)

    def spec_host_cost(self, spec, touched):
        """Result-payload seconds for ``spec`` on a path whose identity
        (mask) readback would be ``touched`` bytes (scalar or (Q,) array).

        ``spec=None`` prices the pure kernel side (the pre-spec cost surface
        — ``break_even_selectivity`` defaults to it so the recorded
        batch/device break-even tables stay comparable across PRs).
        """
        if spec is None:
            return np.zeros_like(np.asarray(touched, np.float64)) \
                if isinstance(touched, np.ndarray) else 0.0
        return spec.host_bytes(touched, self.n) * self.sec_per_result_byte

    def leaf_side(self) -> float:
        return (self.tile_n / max(self.n, 1)) ** (1.0 / max(self.m, 1))

    def est_leaf_frac(self, q: T.RangeQuery, sel: float) -> float:
        """Fraction of clustered leaves intersecting the query box."""
        mq = max(q.n_queried_dims, 1)
        side = sel ** (1.0 / mq)
        l = self.leaf_side()
        return float(min(1.0, (side + l) ** mq))

    def est_va_candidate_frac(self, q: T.RangeQuery, hist: Histograms) -> float:
        # Per queried dim the candidate cells overrun the query box by at most
        # one cell on each side: slack = 2/CELLS of the domain — derived from
        # the build's actual cell resolution, never hardcoded.
        f = 1.0
        for d in np.nonzero(q.dims_mask)[0]:
            s = hist.dim_selectivity(int(d), float(q.lower[d]), float(q.upper[d]))
            f *= min(1.0, s + 2.0 / VA_CELLS)
        return f

    # -- per-path costs ----------------------------------------------------
    # Every cost is *per query*; ``batch`` is the number of queries fused into
    # the same launch. Batched execution changes the cost structure two ways:
    # fixed taxes (dispatch, host sync) divide by the batch size, and the
    # fused scans re-use each HBM data tile for all queries of the batch, so
    # streamed bytes also divide by the batch — down to the VPU compute floor
    # (``sec_per_cmp``), at which point the fused scan is compute-bound.
    def _scan_cost(self, elems: float, batch: int, n_devices: int | None) -> float:
        """Shared scan cost shape: streamed bytes (amortized over the fused
        batch, sharded over devices) floored by the per-device VPU compute
        rate, plus the per-launch taxes. Multi-device launches additionally
        pay one collective (shard_map dispatch + count psum) per launch —
        also amortized over the batch."""
        d = max(n_devices if n_devices is not None else self.n_devices, 1)
        local = elems / d
        stream = local * self.bytes_per_val * self.sec_per_byte / max(batch, 1)
        cost = max(stream, local * self.sec_per_cmp) \
            + self.dispatch_overhead / max(batch, 1)
        if d > 1:
            cost += self.collective_overhead / max(batch, 1)
        return cost

    def cost_scan(self, q: T.RangeQuery, batch: int = 1,
                  n_devices: int | None = None, spec=None) -> float:
        return self._scan_cost(self.n * self.m, batch, n_devices) \
            + self._delta_cost(batch) \
            + self.spec_host_cost(spec, float(self.n))

    def cost_scan_vertical(self, q: T.RangeQuery, batch: int = 1,
                           n_devices: int | None = None, spec=None) -> float:
        # The distributed path implements only the full fused scan, so the
        # vertical scan executes on one device regardless of the mesh —
        # default to 1 here (not ``self.n_devices``) so the planner's cost
        # matches what actually runs; pass n_devices for what-if analysis.
        mq = max(q.n_queried_dims, 1)
        return self._scan_cost(self.n * mq, batch,
                               n_devices if n_devices is not None else 1) \
            + self._delta_cost(batch) \
            + self.spec_host_cost(spec, float(self.n))

    def cost_tree(self, q: T.RangeQuery, sel: float, batch: int = 1,
                  spec=None) -> float:
        n_leaves = -(-self.n // self.tile_n)
        # Batched prune reads the MBR hierarchy once per batch.
        prune = 2 * n_leaves * self.m * self.bytes_per_val / max(batch, 1)
        f = self.est_leaf_frac(q, sel)
        # Refinement visits are per query (each query has its own leaf list).
        refine = f * self.n * self.m * self.bytes_per_val / self.visit_bw_discount
        return self._bytes_cost(prune + refine, dispatches=2.0, batch=batch) \
            + self.host_sync_overhead / max(batch, 1) \
            + self._delta_cost(batch) \
            + self.spec_host_cost(spec, f * self.n)

    def cost_vafile(self, q: T.RangeQuery, hist: Histograms, batch: int = 1,
                    spec=None) -> float:
        words = -(-self.m // VA_DIMS_PER_WORD)  # packing density of the kernel
        # Both phases are fused per batch (``multi_va_filter`` +
        # ``multi_range_scan_visit``): the packed words stream from HBM once
        # per *batch* — down to the VPU unpack-compare floor — and both sync
        # halves (the phase-1 survivor-bit readback, now one (Q, n_blocks)
        # array, and the visit-mask readback) divide by the batch, as do the
        # two launches' dispatches. At batch=1 this is the single-query
        # two-phase cost structure.
        approx_bytes = self.n * words * 4
        approx = max(approx_bytes * self.sec_per_byte / max(batch, 1),
                     self.n * self.m * self.sec_per_cmp)
        cand = self.est_va_candidate_frac(q, hist)
        blk_frac = 1.0 - (1.0 - min(cand, 1.0)) ** self.tile_n
        refine = blk_frac * self.n * self.m * self.bytes_per_val / self.visit_bw_discount
        return approx + refine * self.sec_per_byte \
            + 2.0 * self.dispatch_overhead / max(batch, 1) \
            + self.host_sync_overhead / max(batch, 1) \
            + self._delta_cost(batch) \
            + self.spec_host_cost(spec, blk_frac * self.n)

    def modeled_bytes(self, method: str, sel: float, mq: int, bucket: int
                      ) -> Optional[float]:
        """Per-query bytes this model says ``method`` moves — the abscissa
        of ``calibrate``'s lstsq fit, computed from a trace's (selectivity,
        constrained dims, realized bucket) so production ``QueryTrace``
        records can feed calibration (``obs.audit.calibration_samples``).

        Mirrors the byte terms of the ``cost_*`` formulas (streamed bytes
        amortized over the fused bucket, refinement bytes under the visit
        bandwidth discount); per-launch taxes are what the fit's intercept
        absorbs. Returns None for paths without a byte model (a registered
        third-party path prices itself; it can calibrate itself too).
        """
        b = max(int(bucket), 1)
        mq = max(int(mq), 1)
        sel = min(max(float(sel), 1.0 / max(self.n, 1)), 1.0)
        # every batch launch also streams the delta block, bucket-amortized
        dbytes = self.delta_n * self.m * self.bytes_per_val / b
        if method == "scan":
            return self.n * self.m * self.bytes_per_val \
                / (b * max(self.n_devices, 1)) + dbytes
        if method == "scan_vertical":
            return self.n * mq * self.bytes_per_val / b + dbytes
        if method == "rowscan":
            return float(self.n * self.m * self.bytes_per_val) + dbytes
        if method in ("kdtree", "rstar"):
            n_leaves = -(-self.n // self.tile_n)
            prune = 2 * n_leaves * self.m * self.bytes_per_val / b
            side = sel ** (1.0 / mq)
            f = min(1.0, (side + self.leaf_side()) ** mq)
            return prune + f * self.n * self.m * self.bytes_per_val \
                / self.visit_bw_discount + dbytes
        if method == "vafile":
            words = -(-self.m // VA_DIMS_PER_WORD)
            # per-dim slack approximated from the whole-query selectivity
            # (the trace does not carry per-dim estimates)
            cand = min(1.0, (sel ** (1.0 / mq) + 2.0 / VA_CELLS) ** mq)
            blk_frac = 1.0 - (1.0 - cand) ** self.tile_n
            return self.n * words * 4 / b \
                + blk_frac * self.n * self.m * self.bytes_per_val \
                / self.visit_bw_discount + dbytes
        return None

    # -- vectorized per-path costs (batch planning) ------------------------
    # Same formulas as the scalar methods, evaluated for all Q queries of a
    # batch at once. ``bucket`` is the (Q,) per-query amortization size — the
    # realized size of the launch bucket each query lands in under the
    # planner's fixpoint, where the scalar methods take one ``batch`` int.
    def _scan_cost_batch(self, elems: np.ndarray, bucket: np.ndarray,
                         n_devices: int | None) -> np.ndarray:
        d = max(n_devices if n_devices is not None else self.n_devices, 1)
        local = np.asarray(elems, np.float64) / d
        b = np.maximum(np.asarray(bucket, np.float64), 1.0)
        stream = local * self.bytes_per_val * self.sec_per_byte / b
        cost = np.maximum(stream, local * self.sec_per_cmp) \
            + self.dispatch_overhead / b
        if d > 1:
            cost = cost + self.collective_overhead / b
        return cost

    def cost_scan_batch(self, n_queries: int, bucket: np.ndarray,
                        n_devices: int | None = None, spec=None) -> np.ndarray:
        """(Q,) full fused-scan costs (query-independent except amortization)."""
        elems = np.full((n_queries,), float(self.n) * self.m)
        return self._scan_cost_batch(elems, bucket, n_devices) \
            + self._delta_cost_batch(bucket) \
            + self.spec_host_cost(spec, np.full((n_queries,), float(self.n)))

    def cost_scan_vertical_batch(self, mq: np.ndarray, bucket: np.ndarray,
                                 n_devices: int | None = None,
                                 spec=None) -> np.ndarray:
        """(Q,) vertical-scan costs from per-query constrained-dim counts.

        Like the scalar method, defaults to one device: the distributed path
        implements only the full fused scan, so the vertical scan runs on one
        device regardless of the mesh.
        """
        elems = float(self.n) * np.maximum(np.asarray(mq, np.float64), 1.0)
        touched = np.full((np.asarray(mq).shape[0],), float(self.n))
        return self._scan_cost_batch(
            elems, bucket, n_devices if n_devices is not None else 1) \
            + self._delta_cost_batch(bucket) \
            + self.spec_host_cost(spec, touched)

    def cost_tree_batch(self, sels: np.ndarray, mq: np.ndarray,
                        bucket: np.ndarray, spec=None) -> np.ndarray:
        """(Q,) blocked-tree costs from per-query selectivities + dim counts."""
        b = np.maximum(np.asarray(bucket, np.float64), 1.0)
        n_leaves = -(-self.n // self.tile_n)
        prune = 2 * n_leaves * self.m * self.bytes_per_val / b
        mq1 = np.maximum(np.asarray(mq, np.float64), 1.0)
        side = np.asarray(sels, np.float64) ** (1.0 / mq1)
        f = np.minimum(1.0, (side + self.leaf_side()) ** mq1)
        refine = f * self.n * self.m * self.bytes_per_val / self.visit_bw_discount
        return (prune + refine) * self.sec_per_byte \
            + 2.0 * self.dispatch_overhead / b \
            + self.host_sync_overhead / b \
            + self._delta_cost_batch(bucket) \
            + self.spec_host_cost(spec, f * self.n)

    def cost_vafile_batch(self, dim_sels: np.ndarray, dims_mask: np.ndarray,
                          bucket: np.ndarray, spec=None) -> np.ndarray:
        """(Q,) VA-file costs from (Q, m) per-dim selectivities."""
        b = np.maximum(np.asarray(bucket, np.float64), 1.0)
        words = -(-self.m // VA_DIMS_PER_WORD)
        approx = np.maximum(self.n * words * 4 * self.sec_per_byte / b,
                            self.n * self.m * self.sec_per_cmp)
        cand = np.prod(
            np.where(dims_mask,
                     np.minimum(1.0, np.asarray(dim_sels, np.float64)
                                + 2.0 / VA_CELLS),
                     1.0),
            axis=1)
        blk_frac = 1.0 - (1.0 - np.minimum(cand, 1.0)) ** self.tile_n
        refine = blk_frac * self.n * self.m * self.bytes_per_val \
            / self.visit_bw_discount
        return approx + refine * self.sec_per_byte \
            + 2.0 * self.dispatch_overhead / b \
            + self.host_sync_overhead / b \
            + self._delta_cost_batch(bucket) \
            + self.spec_host_cost(spec, blk_frac * self.n)


@dataclasses.dataclass
class Plan:
    method: str
    est_selectivity: float
    costs: dict[str, float]


@dataclasses.dataclass
class BatchPlan:
    """Outcome of one vectorized batch-planning fixpoint (``plan_batch``).

    ``methods[k]`` is query k's access path; ``bucket_sizes`` the realized
    launch buckets the converged amortization priced (they are exactly the
    buckets ``MDRQEngine.query_batch`` executes). ``costs`` is the final
    (paths x Q) matrix over ``path_names`` — inf where a path is not
    applicable to a query.
    """

    methods: list[str]
    est_selectivity: np.ndarray      # (Q,)
    bucket_sizes: dict[str, int]
    n_iterations: int
    converged: bool
    path_names: tuple[str, ...]
    costs: np.ndarray                # (paths, Q) float64


class _PlanStub:
    """Structure-free stand-in for an access path (cost surface only).

    Lets a ``Planner`` be built from path *names* — cost-model studies and
    break-even sweeps price hypothetical configurations (e.g. n=10M) without
    building any structure. Execution methods are deliberately absent: a stub
    can be ranked, never queried.
    """

    plannable = True
    owns_storage = False
    nbytes_index = 0

    def __init__(self, name: str, hist: Histograms):
        self.name = name
        self.hist = hist


class _ScanStub(paths_mod.ScanCost, _PlanStub):
    pass


class _VerticalScanStub(paths_mod.VerticalScanCost, _PlanStub):
    pass


class _TreeStub(paths_mod.TreeCost, _PlanStub):
    pass


class _VAFileStub(paths_mod.VAFileCost, _PlanStub):
    pass


_STUB_KINDS = {
    "scan": _ScanStub,
    "scan_vertical": _VerticalScanStub,
    "kdtree": _TreeStub,
    "rstar": _TreeStub,
    "vafile": _VAFileStub,
}


@dataclasses.dataclass(frozen=True)
class CalibrationFit:
    """Outcome of fitting one machine constant."""

    constant: str
    fitted: float    # raw lstsq coefficient, whatever its sign
    accepted: bool   # written into the model only when positive
    reason: str


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """What ``Planner.calibrate`` did — a failed fit is distinguishable from
    a successful one (the seed silently kept stale constants on rejection)."""

    n_samples: int
    methods: tuple[str, ...]       # distinct access paths that contributed
    fits: tuple[CalibrationFit, ...]
    rms_rel_err: float             # relative residual of the lstsq fit

    @property
    def accepted(self) -> dict[str, bool]:
        return {f.constant: f.accepted for f in self.fits}

    @property
    def ok(self) -> bool:
        return bool(self.fits) and all(f.accepted for f in self.fits)


class Planner:
    """Chooses scan vs index per query — the paper's conclusion, operational.

    Ranks a set of access-path objects (``core.paths.AccessPath``): the
    engine hands over its registry (a shared name -> path dict, so paths
    registered later are planned without touching the planner), while a
    planner built from *names* gets structure-free cost stubs — the form the
    break-even and calibration studies use.
    """

    def __init__(self, hist: Histograms, model: CostModel,
                 available: tuple[str, ...] = ("scan", "scan_vertical", "kdtree", "vafile"),
                 paths: Union[dict, Sequence, None] = None):
        self.hist = hist
        self.model = model
        if paths is not None:
            self._paths = (paths if isinstance(paths, dict)
                           else {p.name: p for p in paths})
        else:
            self._paths = {}
            for name in available:
                kind = _STUB_KINDS.get(name)
                if kind is None:
                    raise ValueError(
                        f"no default cost model for path {name!r}; pass the "
                        f"path object via ``paths=`` instead")
                self._paths[name] = kind(name, hist)

    @property
    def available(self) -> tuple[str, ...]:
        """Names of the plannable paths, in registration order."""
        return tuple(name for name, p in self._paths.items() if p.plannable)

    def _plannable(self) -> list:
        return [(name, p) for name, p in self._paths.items() if p.plannable]

    # Pre-spec paths are priced as if every result were Ids (their
    # historical behavior) rather than erroring out of the planner; the
    # signature probe is cached per function (see ``paths.takes_spec``).
    _takes_spec = staticmethod(paths_mod.takes_spec)

    def explain(self, q: T.RangeQuery, batch_size: int = 1,
                spec: T.ResultSpec = T.IDS) -> Plan:
        """Rank access paths for q; ``batch_size`` amortizes the fixed taxes
        (and fused-scan bytes) over a batch of concurrently executed queries,
        and ``spec`` prices the result payload (reduced shapes read back
        O(k) bytes where Ids reads back a mask). Paths pricing themselves
        inf (not applicable) are omitted."""
        sel = self.hist.selectivity(q)
        costs: dict[str, float] = {}
        for name, p in self._plannable():
            if self._takes_spec(p.cost):
                c = float(p.cost(q, sel, batch_size, self.model, spec=spec))
            else:
                c = float(p.cost(q, sel, batch_size, self.model))
            if np.isfinite(c):
                costs[name] = c
        if not costs:
            raise ValueError("no applicable access path for query")
        method = min(costs, key=costs.get)
        return Plan(method=method, est_selectivity=sel, costs=costs)

    def plan_inputs(self, batch: T.QueryBatch) -> paths_mod.PlanInputs:
        """One vectorized estimation pass over the whole batch's bounds."""
        dims_mask = batch.dims_mask
        dim_sels = self.hist.dim_selectivity_batch(batch.lower, batch.upper)
        sels = self.hist.selectivity_batch(batch.lower, batch.upper,
                                           dim_sels=dim_sels)
        return paths_mod.PlanInputs(
            lower=batch.lower, upper=batch.upper, dims_mask=dims_mask,
            mq=dims_mask.sum(axis=1), dim_sels=dim_sels, sels=sels)

    def plan_batch(self, batch, max_iters: int = 4,
                   spec: T.ResultSpec = T.IDS) -> BatchPlan:
        """Plan a whole batch: vectorized costs + plan -> bucket -> replan.

        Iteration 1 prices every path under whole-batch amortization (the
        optimistic bound — every fused launch the size of the full batch).
        Each later iteration re-prices with the *realized* bucket sizes of
        the previous assignment: for query k, path p amortizes over p's
        current bucket (plus k itself if it would join), so a path that
        looked cheap only because the whole batch paid its fixed taxes loses
        its subsidy once its realized bucket is small. Amortized terms are
        monotone in bucket size, so assignments settle in 2-3 rounds;
        ``max_iters`` bounds the pathological case and ``converged`` reports
        which happened. No step loops over queries in Python.
        """
        if not isinstance(batch, T.QueryBatch):
            batch = T.QueryBatch.from_queries(list(batch))
        pi = self.plan_inputs(batch)
        entries = self._plannable()
        if not entries:
            raise ValueError("no plannable access paths registered")
        names = [name for name, _ in entries]
        q_n = len(batch)
        assign: Optional[np.ndarray] = None
        sizes = np.zeros((len(entries),), np.float64)
        converged = False
        costs = np.empty((len(entries), q_n), np.float64)
        n_iterations = 0
        takes_spec = [self._takes_spec(p.cost_batch) for _, p in entries]
        for n_iterations in range(1, max_iters + 1):
            for j, (_, p) in enumerate(entries):
                bucket = (np.full((q_n,), float(q_n)) if assign is None
                          else sizes[j] + (assign != j))
                c = (p.cost_batch(pi, bucket, self.model, spec=spec)
                     if takes_spec[j]
                     else p.cost_batch(pi, bucket, self.model))
                costs[j] = np.broadcast_to(np.asarray(c, np.float64), (q_n,))
            # NaN costs count as inapplicable, exactly like the scalar
            # ``explain``'s isfinite filter — otherwise argmin would treat
            # NaN as the minimum and silently assign the broken path.
            np.copyto(costs, np.inf, where=np.isnan(costs))
            new_assign = np.argmin(costs, axis=0)
            if assign is not None and np.array_equal(new_assign, assign):
                converged = True
                break
            assign = new_assign
            sizes = np.bincount(assign,
                                minlength=len(entries)).astype(np.float64)
        if np.isinf(costs[assign, np.arange(q_n)]).any():
            # every plannable path priced itself inapplicable for some query
            # — same condition (and error) as the scalar ``explain``
            raise ValueError("no applicable access path for query")
        counts = np.bincount(assign, minlength=len(entries))
        return BatchPlan(
            methods=[names[int(a)] for a in assign],
            est_selectivity=pi.sels,
            bucket_sizes={names[j]: int(c) for j, c in enumerate(counts) if c},
            n_iterations=n_iterations,
            converged=converged,
            path_names=tuple(names),
            costs=costs,
        )

    def explain_batch(self, queries, spec: T.ResultSpec = T.IDS) -> list[Plan]:
        """Per-query plans under whole-batch amortization — literally
        iteration 1 of ``plan_batch``'s fixpoint, reshaped into Plans (kept
        for cost introspection: every Plan carries the per-path cost dict)."""
        queries = list(queries)
        if not queries:
            return []
        bp = self.plan_batch(T.QueryBatch.from_queries(queries), max_iters=1,
                             spec=spec)
        plans = []
        for k in range(len(queries)):
            cd = {name: float(bp.costs[j, k])
                  for j, name in enumerate(bp.path_names)
                  if np.isfinite(bp.costs[j, k])}
            plans.append(Plan(method=bp.methods[k],
                              est_selectivity=float(bp.est_selectivity[k]),
                              costs=cd))
        return plans

    def choose(self, q: T.RangeQuery, batch_size: int = 1) -> str:
        return self.explain(q, batch_size=batch_size).method

    def break_even_selectivity(self, m_q: Optional[int] = None,
                               batch_size: int = 1,
                               index_path: str = "tree",
                               n_devices: Optional[int] = None,
                               spec: Optional[T.ResultSpec] = None) -> float:
        """Selectivity where the index (``index_path``) stops beating the scan.

        Bisects the cost model over complete-match queries — reproduces the
        paper's ~1% headline number for paper-like configurations. With
        ``batch_size`` > 1 the break-even reflects batched execution: the
        index's host-sync tax amortizes away (helping indexes at small n),
        but the fused scan's byte amortization pushes the scan toward its
        compute floor (helping scans at large batches) — the net shift is a
        machine-and-batch-size-dependent result the paper's single-query
        analysis (§8) cannot see. ``index_path="vafile"`` bisects the (now
        fully batch-fused) VA-file cost instead of the tree cost.

        ``n_devices`` adds the cross-device axis: the scan's streamed bytes
        (and compute floor) divide over the mesh while the indexes stay
        single-device, so every added device pushes the break-even further
        down — horizontal partitioning (§3.1) extends the paper's "scans win
        below ~1%" conclusion device-linearly, minus the per-launch
        collective tax.

        ``spec`` adds the result-shape axis: under ``Ids()`` the scan reads
        back an n-byte mask per query while the index reads only its visited
        fraction, so the break-even climbs (indexes win a wider band); under
        ``Count()``/``Agg``/``TopK`` the payload is O(1)-O(k) for every path
        and the break-even falls back to the pure kernel-side surface
        (``spec=None``, the default — keeps the recorded tables comparable).
        """
        mq = m_q or self.model.m
        lo_s, hi_s = 1e-8, 1.0

        def tree_wins(sel: float) -> bool:
            q = _synthetic_query(self.model.m, mq, sel)
            if index_path == "vafile":
                idx_cost = self.model.cost_vafile(q, self.hist,
                                                  batch=batch_size, spec=spec)
            else:
                idx_cost = self.model.cost_tree(q, sel, batch=batch_size,
                                                spec=spec)
            return idx_cost < self.model.cost_scan(q, batch=batch_size,
                                                   n_devices=n_devices,
                                                   spec=spec)

        if not tree_wins(lo_s):
            return 0.0
        if tree_wins(hi_s):
            return 1.0
        for _ in range(60):
            mid = np.sqrt(lo_s * hi_s)
            if tree_wins(mid):
                lo_s = mid
            else:
                hi_s = mid
        return float(np.sqrt(lo_s * hi_s))

    def calibrate(self, samples: list[tuple[str, float, float]]
                  ) -> "CalibrationReport":
        """Refit (sec_per_byte, dispatch_overhead) from measured runs.

        Args:
          samples: (method, modeled_bytes, measured_seconds) triples. The
            method names are recorded in the report so callers can see which
            access paths backed the fit.

        Returns:
          A ``CalibrationReport``: each constant is written into the model
          only when its fitted value is positive, and the report says per
          constant whether the fit was accepted — a rejected fit keeps the
          previous constant *visibly* instead of silently looking like a
          successful calibration.
        """
        if not samples:
            return CalibrationReport(n_samples=0, methods=(), fits=(),
                                     rms_rel_err=float("nan"))
        A = np.array([[b, 1.0] for _, b, _ in samples])
        y = np.array([t for _, _, t in samples])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        resid = (A @ coef - y) / np.maximum(np.abs(y), 1e-30)
        fits = []
        for name, val in (("sec_per_byte", float(coef[0])),
                          ("dispatch_overhead", float(coef[1]))):
            accepted = val > 0.0
            kept = getattr(self.model, name)
            if accepted:
                setattr(self.model, name, val)
            fits.append(CalibrationFit(
                constant=name, fitted=val, accepted=accepted,
                reason="fit accepted" if accepted else
                f"non-positive fit {val:.3e}; keeping {kept:.3e}"))
        return CalibrationReport(
            n_samples=len(samples),
            methods=tuple(sorted({m for m, _, _ in samples})),
            fits=tuple(fits),
            rms_rel_err=float(np.sqrt(np.mean(resid ** 2))),
        )


def _synthetic_query(m: int, mq: int, sel: float) -> T.RangeQuery:
    side = sel ** (1.0 / mq)
    preds = {d: (0.0, side) for d in range(mq)}
    return T.RangeQuery.partial(m, preds)
