"""Access-path planner: the paper's break-even rule as an operational cost model.

The paper's headline result is that the scan-vs-index break-even selectivity
drops from the classical 15-20% to ~1% on modern hardware (§8). Here that
conclusion becomes machinery: per-dimension equi-width histograms estimate
query selectivity (independence assumption, §2.1 — the paper notes it fails
for correlated dims, so estimates are clamped and calibration is exposed), and
an analytic byte-cost model ranks the available access paths.

Cost model (napkin terms, all in bytes moved + per-dispatch overhead):

  scan_full      : n * m * B
  scan_vertical  : n * m_q * B                      (partial match, §5.5)
  kdtree / rstar : nodes * m * 2B  +  f_leaf * n * m * B / visit_discount  + sync
  vafile         : n * ceil(m/16) * 4  +  f_blk * n * m * B / visit_discount + sync

with ``f_leaf ~= prod_over_queried (s^(1/m_q) + l)``, ``l = (tile/n)^(1/m)``
(query box side + leaf box side per dim) and the VA candidate fraction
``prod (s_j + 2/CELLS)``.

The two index-specific taxes are the TPU translation of the paper's
random-access penalty: two-phase execution needs a device->host->device round
trip (``host_sync_overhead``) to turn the prune mask into a visit list, and
the visit kernel's scattered tile DMAs run below streaming HBM bandwidth
(``visit_bw_discount``). These terms are what move the break-even point — with
them the model reproduces the paper's structure: scans always win at small n
(sync floor dominates, Fig. 7), indexes only win at high selectivity
(Fig. 6), and the break-even lands near 1% at the paper's 1M-object scale.
``calibrate()`` fits the machine constants from measured runs.

Batched execution: every cost accepts a ``batch`` size — the number of
queries fused into one launch (``MDRQEngine.query_batch``). Fixed taxes
(dispatch, host sync) divide by the batch, and the fused scans' streamed
bytes amortize down to a VPU compute floor (``sec_per_cmp``). The two effects
pull the scan-vs-index break-even in *opposite* directions, and
``break_even_selectivity(batch_size=...)`` reports the net — a result the
paper's single-query analysis cannot express.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import types as T

BINS = 64


@dataclasses.dataclass
class Histograms:
    """Per-dimension equi-width histograms for selectivity estimation."""

    edges: np.ndarray   # (m, BINS + 1)
    counts: np.ndarray  # (m, BINS)
    n: int

    @staticmethod
    def build(dataset: T.Dataset, bins: int = BINS) -> "Histograms":
        m, n = dataset.m, dataset.n
        edges = np.empty((m, bins + 1), np.float64)
        counts = np.empty((m, bins), np.float64)
        for d in range(m):
            c, e = np.histogram(dataset.cols[d], bins=bins)
            edges[d], counts[d] = e, c
        return Histograms(edges=edges, counts=counts, n=n)

    def dim_selectivity(self, d: int, lb: float, ub: float) -> float:
        """Estimated fraction of objects with attribute d in [lb, ub].

        Any predicate overlapping the observed domain is clamped to at least
        ``1/n`` — including *point* predicates (``lb == ub``, ubiquitous in
        GMRQB mixed workloads), whose bin coverage is zero-width and would
        otherwise estimate 0.0 and mis-rank every access path.
        """
        if np.isneginf(lb) and np.isposinf(ub):
            return 1.0
        if ub < lb:
            return 0.0  # empty range
        e, c = self.edges[d], self.counts[d]
        if ub < e[0] or lb > e[-1]:
            return 0.0  # disjoint from the observed domain
        lo = np.clip(lb, e[0], e[-1])
        hi = np.clip(ub, e[0], e[-1])
        widths = np.diff(e)
        # fraction of each bin covered by [lo, hi]
        cover = np.clip((np.minimum(hi, e[1:]) - np.maximum(lo, e[:-1])) / np.maximum(widths, 1e-30), 0.0, 1.0)
        frac = float((c * cover).sum() / max(self.n, 1))
        return min(1.0, max(frac, 1.0 / max(self.n, 1)))

    def selectivity(self, q: T.RangeQuery) -> float:
        """Independence-assumption estimate of query selectivity (§2.1).

        Floored at ``1/n`` unless some dimension is provably disjoint from
        the domain: an estimate of "at least one match" is the standard
        planner convention, and it keeps point queries rankable.
        """
        s = 1.0
        for d in np.nonzero(q.dims_mask)[0]:
            s *= self.dim_selectivity(int(d), float(q.lower[d]), float(q.upper[d]))
            if s == 0.0:
                return 0.0
        return max(s, 1.0 / max(self.n, 1))


@dataclasses.dataclass
class CostModel:
    """Analytic access-path cost model with calibratable machine constants."""

    n: int
    m: int
    tile_n: int = 1024
    bytes_per_val: int = 4
    # Devices the scan shards over (horizontal partitioning, §3.1 — the
    # paper's thread count t mapped to a mesh). Streamed bytes and the VPU
    # compute floor both divide by it; indexes stay single-device.
    n_devices: int = 1
    # machine constants — defaults in v5e roofline units (s); calibrate() refits.
    sec_per_byte: float = 1.0 / 819e9
    dispatch_overhead: float = 2e-6
    host_sync_overhead: float = 20e-6  # device->host->device visit-list turn
    visit_bw_discount: float = 0.6     # scattered tile DMA vs streaming scan
    sec_per_cmp: float = 2.5e-13       # VPU compare+AND per element (~4e12/s)
    collective_overhead: float = 5e-6  # per-launch shard_map dispatch + psum tax

    def _bytes_cost(self, nbytes: float, dispatches: float = 1.0,
                    batch: int = 1) -> float:
        return (nbytes * self.sec_per_byte
                + dispatches * self.dispatch_overhead / max(batch, 1))

    def leaf_side(self) -> float:
        return (self.tile_n / max(self.n, 1)) ** (1.0 / max(self.m, 1))

    def est_leaf_frac(self, q: T.RangeQuery, sel: float) -> float:
        """Fraction of clustered leaves intersecting the query box."""
        mq = max(q.n_queried_dims, 1)
        side = sel ** (1.0 / mq)
        l = self.leaf_side()
        return float(min(1.0, (side + l) ** mq))

    def est_va_candidate_frac(self, q: T.RangeQuery, hist: Histograms) -> float:
        f = 1.0
        for d in np.nonzero(q.dims_mask)[0]:
            s = hist.dim_selectivity(int(d), float(q.lower[d]), float(q.upper[d]))
            f *= min(1.0, s + 2.0 / 4.0)
        return f

    # -- per-path costs ----------------------------------------------------
    # Every cost is *per query*; ``batch`` is the number of queries fused into
    # the same launch. Batched execution changes the cost structure two ways:
    # fixed taxes (dispatch, host sync) divide by the batch size, and the
    # fused scans re-use each HBM data tile for all queries of the batch, so
    # streamed bytes also divide by the batch — down to the VPU compute floor
    # (``sec_per_cmp``), at which point the fused scan is compute-bound.
    def _scan_cost(self, elems: float, batch: int, n_devices: int | None) -> float:
        """Shared scan cost shape: streamed bytes (amortized over the fused
        batch, sharded over devices) floored by the per-device VPU compute
        rate, plus the per-launch taxes. Multi-device launches additionally
        pay one collective (shard_map dispatch + count psum) per launch —
        also amortized over the batch."""
        d = max(n_devices if n_devices is not None else self.n_devices, 1)
        local = elems / d
        stream = local * self.bytes_per_val * self.sec_per_byte / max(batch, 1)
        cost = max(stream, local * self.sec_per_cmp) \
            + self.dispatch_overhead / max(batch, 1)
        if d > 1:
            cost += self.collective_overhead / max(batch, 1)
        return cost

    def cost_scan(self, q: T.RangeQuery, batch: int = 1,
                  n_devices: int | None = None) -> float:
        return self._scan_cost(self.n * self.m, batch, n_devices)

    def cost_scan_vertical(self, q: T.RangeQuery, batch: int = 1,
                           n_devices: int | None = None) -> float:
        # The distributed path implements only the full fused scan, so the
        # vertical scan executes on one device regardless of the mesh —
        # default to 1 here (not ``self.n_devices``) so the planner's cost
        # matches what actually runs; pass n_devices for what-if analysis.
        mq = max(q.n_queried_dims, 1)
        return self._scan_cost(self.n * mq, batch,
                               n_devices if n_devices is not None else 1)

    def cost_tree(self, q: T.RangeQuery, sel: float, batch: int = 1) -> float:
        n_leaves = -(-self.n // self.tile_n)
        # Batched prune reads the MBR hierarchy once per batch.
        prune = 2 * n_leaves * self.m * self.bytes_per_val / max(batch, 1)
        f = self.est_leaf_frac(q, sel)
        # Refinement visits are per query (each query has its own leaf list).
        refine = f * self.n * self.m * self.bytes_per_val / self.visit_bw_discount
        return self._bytes_cost(prune + refine, dispatches=2.0, batch=batch) \
            + self.host_sync_overhead / max(batch, 1)

    def cost_vafile(self, q: T.RangeQuery, hist: Histograms, batch: int = 1) -> float:
        words = -(-self.m // 16)
        # Both phases are fused per batch (``multi_va_filter`` +
        # ``multi_range_scan_visit``): the packed words stream from HBM once
        # per *batch* — down to the VPU unpack-compare floor — and both sync
        # halves (the phase-1 survivor-bit readback, now one (Q, n_blocks)
        # array, and the visit-mask readback) divide by the batch, as do the
        # two launches' dispatches. At batch=1 this is the single-query
        # two-phase cost structure.
        approx_bytes = self.n * words * 4
        approx = max(approx_bytes * self.sec_per_byte / max(batch, 1),
                     self.n * self.m * self.sec_per_cmp)
        cand = self.est_va_candidate_frac(q, hist)
        blk_frac = 1.0 - (1.0 - min(cand, 1.0)) ** self.tile_n
        refine = blk_frac * self.n * self.m * self.bytes_per_val / self.visit_bw_discount
        return approx + refine * self.sec_per_byte \
            + 2.0 * self.dispatch_overhead / max(batch, 1) \
            + self.host_sync_overhead / max(batch, 1)


@dataclasses.dataclass
class Plan:
    method: str
    est_selectivity: float
    costs: dict[str, float]


@dataclasses.dataclass(frozen=True)
class CalibrationFit:
    """Outcome of fitting one machine constant."""

    constant: str
    fitted: float    # raw lstsq coefficient, whatever its sign
    accepted: bool   # written into the model only when positive
    reason: str


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """What ``Planner.calibrate`` did — a failed fit is distinguishable from
    a successful one (the seed silently kept stale constants on rejection)."""

    n_samples: int
    methods: tuple[str, ...]       # distinct access paths that contributed
    fits: tuple[CalibrationFit, ...]
    rms_rel_err: float             # relative residual of the lstsq fit

    @property
    def accepted(self) -> dict[str, bool]:
        return {f.constant: f.accepted for f in self.fits}

    @property
    def ok(self) -> bool:
        return bool(self.fits) and all(f.accepted for f in self.fits)


class Planner:
    """Chooses scan vs index per query — the paper's conclusion, operational."""

    def __init__(self, hist: Histograms, model: CostModel,
                 available: tuple[str, ...] = ("scan", "scan_vertical", "kdtree", "vafile")):
        self.hist = hist
        self.model = model
        self.available = available

    def explain(self, q: T.RangeQuery, batch_size: int = 1) -> Plan:
        """Rank access paths for q; ``batch_size`` amortizes the fixed taxes
        (and fused-scan bytes) over a batch of concurrently executed queries."""
        sel = self.hist.selectivity(q)
        costs: dict[str, float] = {}
        if "scan" in self.available:
            costs["scan"] = self.model.cost_scan(q, batch=batch_size)
        if "scan_vertical" in self.available and not q.is_complete_match:
            costs["scan_vertical"] = self.model.cost_scan_vertical(q, batch=batch_size)
        for tree in ("kdtree", "rstar"):
            if tree in self.available:
                costs[tree] = self.model.cost_tree(q, sel, batch=batch_size)
        if "vafile" in self.available:
            costs["vafile"] = self.model.cost_vafile(q, self.hist, batch=batch_size)
        method = min(costs, key=costs.get)
        return Plan(method=method, est_selectivity=sel, costs=costs)

    def explain_batch(self, queries) -> list[Plan]:
        """Per-query plans under whole-batch amortization.

        The amortization uses the total batch size for every query — a
        deliberate simplification (the true per-bucket size is only known
        after bucketing, which depends on these very plans).
        """
        queries = list(queries)
        return [self.explain(q, batch_size=len(queries)) for q in queries]

    def choose(self, q: T.RangeQuery, batch_size: int = 1) -> str:
        return self.explain(q, batch_size=batch_size).method

    def break_even_selectivity(self, m_q: Optional[int] = None,
                               batch_size: int = 1,
                               index_path: str = "tree",
                               n_devices: Optional[int] = None) -> float:
        """Selectivity where the index (``index_path``) stops beating the scan.

        Bisects the cost model over complete-match queries — reproduces the
        paper's ~1% headline number for paper-like configurations. With
        ``batch_size`` > 1 the break-even reflects batched execution: the
        index's host-sync tax amortizes away (helping indexes at small n),
        but the fused scan's byte amortization pushes the scan toward its
        compute floor (helping scans at large batches) — the net shift is a
        machine-and-batch-size-dependent result the paper's single-query
        analysis (§8) cannot see. ``index_path="vafile"`` bisects the (now
        fully batch-fused) VA-file cost instead of the tree cost.

        ``n_devices`` adds the cross-device axis: the scan's streamed bytes
        (and compute floor) divide over the mesh while the indexes stay
        single-device, so every added device pushes the break-even further
        down — horizontal partitioning (§3.1) extends the paper's "scans win
        below ~1%" conclusion device-linearly, minus the per-launch
        collective tax.
        """
        mq = m_q or self.model.m
        lo_s, hi_s = 1e-8, 1.0

        def tree_wins(sel: float) -> bool:
            q = _synthetic_query(self.model.m, mq, sel)
            if index_path == "vafile":
                idx_cost = self.model.cost_vafile(q, self.hist, batch=batch_size)
            else:
                idx_cost = self.model.cost_tree(q, sel, batch=batch_size)
            return idx_cost < self.model.cost_scan(q, batch=batch_size,
                                                   n_devices=n_devices)

        if not tree_wins(lo_s):
            return 0.0
        if tree_wins(hi_s):
            return 1.0
        for _ in range(60):
            mid = np.sqrt(lo_s * hi_s)
            if tree_wins(mid):
                lo_s = mid
            else:
                hi_s = mid
        return float(np.sqrt(lo_s * hi_s))

    def calibrate(self, samples: list[tuple[str, float, float]]
                  ) -> "CalibrationReport":
        """Refit (sec_per_byte, dispatch_overhead) from measured runs.

        Args:
          samples: (method, modeled_bytes, measured_seconds) triples. The
            method names are recorded in the report so callers can see which
            access paths backed the fit.

        Returns:
          A ``CalibrationReport``: each constant is written into the model
          only when its fitted value is positive, and the report says per
          constant whether the fit was accepted — a rejected fit keeps the
          previous constant *visibly* instead of silently looking like a
          successful calibration.
        """
        if not samples:
            return CalibrationReport(n_samples=0, methods=(), fits=(),
                                     rms_rel_err=float("nan"))
        A = np.array([[b, 1.0] for _, b, _ in samples])
        y = np.array([t for _, _, t in samples])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        resid = (A @ coef - y) / np.maximum(np.abs(y), 1e-30)
        fits = []
        for name, val in (("sec_per_byte", float(coef[0])),
                          ("dispatch_overhead", float(coef[1]))):
            accepted = val > 0.0
            kept = getattr(self.model, name)
            if accepted:
                setattr(self.model, name, val)
            fits.append(CalibrationFit(
                constant=name, fitted=val, accepted=accepted,
                reason="fit accepted" if accepted else
                f"non-positive fit {val:.3e}; keeping {kept:.3e}"))
        return CalibrationReport(
            n_samples=len(samples),
            methods=tuple(sorted({m for m, _, _ in samples})),
            fits=tuple(fits),
            rms_rel_err=float(np.sqrt(np.mean(resid ** 2))),
        )


def _synthetic_query(m: int, mq: int, sel: float) -> T.RangeQuery:
    side = sel ** (1.0 / mq)
    preds = {d: (0.0, side) for d in range(mq)}
    return T.RangeQuery.partial(m, preds)
