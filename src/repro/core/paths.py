"""The access-path layer: one protocol behind every MDRQ execution engine.

The paper's experimental matrix (§7.1.3) — scans, tree MDIS, VA-file — is a
set of interchangeable access paths behind one query interface. This module
makes that matrix explicit (DESIGN.md §6): ``AccessPath`` is the protocol
every path speaks, the ``*Path`` adapters put the concrete structures
(``ColumnarScan``, ``RowScan``, ``DistributedScan``, ``BlockedIndex``,
``VAFile``) behind it, and ``MDRQEngine`` becomes a name -> path registry —
adding a path (grid file, learned layout, ...) means registering one object,
not editing three dispatch chains.

Planning rides the same protocol: each path prices itself, scalar
(``cost``, the single-query ``Planner.explain`` hook) and vectorized
(``cost_batch``, the (paths x Q) matrix ``Planner.plan_batch`` builds from
one ``PlanInputs`` pass). The cost mixins delegate to ``CostModel`` so the
built-in paths and the planner's structure-free planning stubs share one set
of formulas; a registered third-party path brings its own.

Conventions:

  * ``cost``/``cost_batch`` return ``inf`` where the path is not applicable
    (e.g. the vertical scan on a complete-match query) — the planner skips
    non-finite entries.
  * ``plannable=False`` paths execute only when named explicitly
    (``rowscan``; the vertical scan on a meshed engine, where an "auto"
    choice would lazily re-place the dataset on one device).
  * ``owns_storage=False`` marks views over another path's arrays so
    ``memory_report`` never double-counts (the vertical scan shares the
    columnar scan's data).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Callable, Protocol, Union, runtime_checkable

import numpy as np

from repro.obs import tracing as obs_tracing
from repro.core import types as T


def _path_span(path, batch, spec, stage: str | None = None):
    """Span around one adapter batch execution.

    Returns the shared ``NULL_SPAN`` singleton unless a tracer is active —
    the ``enabled()`` guard also skips building the attrs dict, so the
    disabled hot path allocates nothing. ``stage="launch"`` marks the
    device-stage half of a split execution (the span deliberately does NOT
    block on the output — it measures dispatch, not compute).
    """
    if not obs_tracing.enabled():
        return obs_tracing.NULL_SPAN
    if stage is None:
        return obs_tracing.span("path", path=path.name, n_queries=len(batch),
                                spec=getattr(spec, "kind", str(spec)))
    return obs_tracing.span("path", path=path.name, n_queries=len(batch),
                            spec=getattr(spec, "kind", str(spec)), stage=stage)


def supports_launch(path) -> bool:
    """Whether a path offers the split-execution protocol:
    ``launch_batch(batch, spec, delta) -> (payload, finalize)`` where the
    caller owns the single ``ops.device_get(payload)`` (skipped when payload
    is None) and ``finalize(host_payload)`` types the per-query results.
    Paths without it still serve pipelined traffic — their buckets execute
    synchronously in the device stage."""
    return callable(getattr(path, "launch_batch", None))


@functools.lru_cache(maxsize=None)
def _fn_takes_spec(fn) -> bool:
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / C callables
        return False
    return "spec" in params or any(p.kind == p.VAR_KEYWORD
                                   for p in params.values())


def takes_spec(method) -> bool:
    """Whether a path hook (``query_batch``/``cost``/``cost_batch``) accepts
    the ``spec`` argument of the ResultSpec protocol.

    Paths registered against the pre-spec protocol keep working — the engine
    serves them the two legacy shapes and the planner prices them as Ids.
    The signature probe is cached on the underlying function object (a
    path's signature cannot change after registration), so the execution
    and planning hot paths never re-run ``inspect``.
    """
    return _fn_takes_spec(getattr(method, "__func__", method))


@functools.lru_cache(maxsize=None)
def _fn_takes_delta(fn) -> bool:
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / C callables
        return False
    return "delta" in params or any(p.kind == p.VAR_KEYWORD
                                    for p in params.values())


def takes_delta(method) -> bool:
    """Whether a path's ``query_batch`` accepts the ``delta`` argument of the
    versioned-dataset protocol (a ``core.delta.DeltaView``).

    The engine only hands a non-empty delta to paths that declare the
    parameter; registered paths that predate the mutable plane raise a
    "compact() first" error instead of silently serving stale results. Cached
    like ``takes_spec``.
    """
    return _fn_takes_delta(getattr(method, "__func__", method))

# Per-query results under some ResultSpec: id arrays (Ids/TopK), ints
# (Count), bool masks (Mask), or floats (Agg).
Results = Union["list[np.ndarray]", "list[int]", "list[float]"]


@dataclasses.dataclass(frozen=True)
class PlanInputs:
    """Per-query planning statistics for one batch, computed in one pass.

    ``Planner.plan_batch`` builds this once from the (Q, 2, m) bounds
    (``Histograms.dim_selectivity_batch`` / ``selectivity_batch``) and hands
    it to every path's ``cost_batch`` — no per-query Python loop anywhere in
    batch planning.
    """

    lower: np.ndarray      # (Q, m) float32 query lower bounds
    upper: np.ndarray      # (Q, m) float32 query upper bounds
    dims_mask: np.ndarray  # (Q, m) bool — True where a dim is constrained
    mq: np.ndarray         # (Q,) int — number of constrained dims
    dim_sels: np.ndarray   # (Q, m) per-dim selectivity (1.0 if unconstrained)
    sels: np.ndarray       # (Q,) independence-assumption query selectivity

    def __len__(self) -> int:
        return self.lower.shape[0]

    @property
    def is_complete(self) -> np.ndarray:
        """(Q,) bool — queries constraining every dimension."""
        return self.dims_mask.all(axis=1)


@runtime_checkable
class AccessPath(Protocol):
    """What the engine registry and the planner require of a path.

    Execution surface: ``query``/``count`` singles and
    ``query_batch(batch, spec)`` (one fused launch per bucket; ``spec`` is a
    ``types.ResultSpec`` — ids, count, mask, top-k, aggregate — whose
    on-device reducer the path's launch carries). Planning surface: ``cost``
    (scalar) and ``cost_batch`` (vectorized over a ``PlanInputs``), both
    taking the spec so reduced result shapes price their smaller host
    payload. ``PerQueryPath`` adapts anything that only has singles.
    """

    name: str
    plannable: bool
    owns_storage: bool

    @property
    def nbytes_index(self) -> int: ...

    def query(self, q: T.RangeQuery) -> np.ndarray: ...

    def count(self, q: T.RangeQuery) -> int: ...

    def query_batch(self, batch: T.QueryBatch,
                    spec: T.ResultSpec = T.IDS) -> Results: ...

    def cost(self, q: T.RangeQuery, sel: float, batch: int, model,
             spec: T.ResultSpec = T.IDS) -> float: ...

    def cost_batch(self, pi: PlanInputs, bucket: np.ndarray, model,
                   spec: T.ResultSpec = T.IDS) -> np.ndarray: ...


# -- cost mixins --------------------------------------------------------------
# One mixin per cost shape, delegating to the CostModel formulas so the real
# paths here and the planner's structure-free stubs cannot drift apart.
# ``bucket`` is the (Q,) per-query amortization size the planner's fixpoint
# converged on (realized bucket sizes, not the whole batch). ``spec`` threads
# into the CostModel so each path's result-payload/host-sync bytes are priced
# per result shape (reduced specs read back O(k) instead of a mask).

class ScanCost:
    """Full fused scan: cost is query-independent except for amortization."""

    def cost(self, q: T.RangeQuery, sel: float, batch: int, model,
             spec: T.ResultSpec = T.IDS) -> float:
        return model.cost_scan(q, batch=batch, spec=spec)

    def cost_batch(self, pi: PlanInputs, bucket: np.ndarray, model,
                   spec: T.ResultSpec = T.IDS) -> np.ndarray:
        return model.cost_scan_batch(len(pi), bucket, spec=spec)


class VerticalScanCost:
    """Partial-match scan: touches only constrained columns; inapplicable
    (inf) to complete-match queries, where it degenerates to the full scan."""

    def cost(self, q: T.RangeQuery, sel: float, batch: int, model,
             spec: T.ResultSpec = T.IDS) -> float:
        if q.is_complete_match:
            return float("inf")
        return model.cost_scan_vertical(q, batch=batch, spec=spec)

    def cost_batch(self, pi: PlanInputs, bucket: np.ndarray, model,
                   spec: T.ResultSpec = T.IDS) -> np.ndarray:
        return np.where(pi.is_complete, np.inf,
                        model.cost_scan_vertical_batch(pi.mq, bucket,
                                                       spec=spec))


class TreeCost:
    """Blocked tree MDIS (kd-tree / R*-tree): prune + visit two-phase cost."""

    def cost(self, q: T.RangeQuery, sel: float, batch: int, model,
             spec: T.ResultSpec = T.IDS) -> float:
        return model.cost_tree(q, sel, batch=batch, spec=spec)

    def cost_batch(self, pi: PlanInputs, bucket: np.ndarray, model,
                   spec: T.ResultSpec = T.IDS) -> np.ndarray:
        return model.cost_tree_batch(pi.sels, pi.mq, bucket, spec=spec)


class VAFileCost:
    """VA-file: packed approximation stream + candidate-block refinement."""

    hist: Any  # Histograms — the scalar candidate-fraction estimate needs it

    def cost(self, q: T.RangeQuery, sel: float, batch: int, model,
             spec: T.ResultSpec = T.IDS) -> float:
        return model.cost_vafile(q, self.hist, batch=batch, spec=spec)

    def cost_batch(self, pi: PlanInputs, bucket: np.ndarray, model,
                   spec: T.ResultSpec = T.IDS) -> np.ndarray:
        return model.cost_vafile_batch(pi.dim_sels, pi.dims_mask, bucket,
                                       spec=spec)


# -- adapters over the concrete structures ------------------------------------

class ColumnarScanPath(ScanCost):
    """``ColumnarScan`` as the "scan" path (single-device full fused scan)."""

    name = "scan"
    plannable = True
    owns_storage = True

    def __init__(self, scan):
        self._scan = scan

    @property
    def nbytes_index(self) -> int:
        return self._scan.nbytes_index

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return self._scan.query(q)

    def count(self, q: T.RangeQuery) -> int:
        return self._scan.count(q)

    def query_batch(self, batch: T.QueryBatch,
                    spec: T.ResultSpec = T.IDS, delta=None) -> Results:
        with _path_span(self, batch, spec) as sp:
            out = self._scan.query_batch(batch, spec=spec, delta=delta)
            sp.block_on(out)
        return out

    def launch_batch(self, batch: T.QueryBatch,
                     spec: T.ResultSpec = T.IDS, delta=None) -> tuple:
        with _path_span(self, batch, spec, stage="launch"):
            return self._scan.launch_batch(batch, spec=spec, delta=delta)


class DistributedScanPath(ScanCost):
    """``DistributedScan`` as the "scan" path — one collective launch per
    batch, data sharded over the mesh (horizontal partitioning, §3.1)."""

    name = "scan"
    plannable = True
    owns_storage = True

    def __init__(self, dist):
        self._dist = dist

    @property
    def nbytes_index(self) -> int:
        return self._dist.nbytes_index

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return self._dist.query(q)

    def count(self, q: T.RangeQuery) -> int:
        return self._dist.count(q)

    def query_batch(self, batch: T.QueryBatch,
                    spec: T.ResultSpec = T.IDS, delta=None) -> Results:
        with _path_span(self, batch, spec) as sp:
            out = self._dist.query_batch(batch, spec=spec, delta=delta)
            sp.block_on(out)
        return out

    def launch_batch(self, batch: T.QueryBatch,
                     spec: T.ResultSpec = T.IDS, delta=None) -> tuple:
        with _path_span(self, batch, spec, stage="launch"):
            return self._dist.launch_batch(batch, spec=spec, delta=delta)


class VerticalScanPath(VerticalScanCost):
    """The partial-match vertical scan (§5.5) as its own path.

    A *view* over the columnar scan's storage (``owns_storage=False``),
    built lazily through ``scan_ref`` so a meshed engine — where this path is
    ``plannable=False`` and only runs on explicit request — doesn't place a
    second full copy of the dataset on one device just by existing.
    """

    name = "scan_vertical"
    owns_storage = False

    def __init__(self, scan_ref: Callable[[], Any], plannable: bool = True):
        self._scan_ref = scan_ref
        self.plannable = plannable

    @property
    def nbytes_index(self) -> int:
        return 0

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return self._scan_ref().query_partial(q)

    def count(self, q: T.RangeQuery) -> int:
        return self._scan_ref().count_partial(q)

    def query_batch(self, batch: T.QueryBatch,
                    spec: T.ResultSpec = T.IDS, delta=None) -> Results:
        with _path_span(self, batch, spec) as sp:
            out = self._scan_ref().query_batch(batch, partial=True, spec=spec,
                                               delta=delta)
            sp.block_on(out)
        return out

    def launch_batch(self, batch: T.QueryBatch,
                     spec: T.ResultSpec = T.IDS, delta=None) -> tuple:
        with _path_span(self, batch, spec, stage="launch"):
            return self._scan_ref().launch_batch(batch, partial=True,
                                                 spec=spec, delta=delta)


class BlockedIndexPath(TreeCost):
    """A ``BlockedIndex`` (kd-tree or packed STR R*-tree) as a path."""

    plannable = True
    owns_storage = True

    def __init__(self, index):
        self._index = index
        self.name = index.name

    @property
    def nbytes_index(self) -> int:
        return self._index.nbytes_index

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return self._index.query(q)

    def count(self, q: T.RangeQuery) -> int:
        return self._index.count(q)

    def query_batch(self, batch: T.QueryBatch,
                    spec: T.ResultSpec = T.IDS, delta=None) -> Results:
        with _path_span(self, batch, spec) as sp:
            out = self._index.query_batch(batch, spec=spec, delta=delta)
            sp.block_on(out)
        return out

    def launch_batch(self, batch: T.QueryBatch,
                     spec: T.ResultSpec = T.IDS, delta=None) -> tuple:
        with _path_span(self, batch, spec, stage="launch"):
            return self._index.launch_batch(batch, spec=spec, delta=delta)


class VAFilePath(VAFileCost):
    """A ``VAFile`` as a path (two-phase approximation scan)."""

    name = "vafile"
    plannable = True
    owns_storage = True

    def __init__(self, vafile, hist):
        self._vafile = vafile
        self.hist = hist

    @property
    def nbytes_index(self) -> int:
        return self._vafile.nbytes_index

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return self._vafile.query(q)

    def count(self, q: T.RangeQuery) -> int:
        return self._vafile.count(q)

    def query_batch(self, batch: T.QueryBatch,
                    spec: T.ResultSpec = T.IDS, delta=None) -> Results:
        with _path_span(self, batch, spec) as sp:
            out = self._vafile.query_batch(batch, spec=spec, delta=delta)
            sp.block_on(out)
        return out

    def launch_batch(self, batch: T.QueryBatch,
                     spec: T.ResultSpec = T.IDS, delta=None) -> tuple:
        with _path_span(self, batch, spec, stage="launch"):
            return self._vafile.launch_batch(batch, spec=spec, delta=delta)


class PerQueryPath:
    """Generic adapter: any object with single-query ``query``/``count``
    becomes a full ``AccessPath`` whose batch execution is a per-query loop.

    This is the fallback rung of the layer — structures without a fused batch
    kernel (``RowScan``, prototypes, test doubles) still ride the registry,
    paying Q launches instead of one. Reduced result shapes ride the spec's
    *host* fallback: ids materialize per query and ``ResultSpec.from_ids``
    finalizes against the host columns (pass ``cols`` to enable — specs that
    read attribute values need it). Not plannable by default: a path whose
    batch cost is Q times its single cost should stay an explicit opt-in
    until it prices itself (subclass and override ``cost``/``cost_batch``,
    then pass ``plannable=True``).
    """

    owns_storage = True

    def __init__(self, name: str, impl, plannable: bool = False,
                 cols: np.ndarray | None = None):
        self.name = name
        self._impl = impl
        self.plannable = plannable
        self._cols = cols

    @property
    def nbytes_index(self) -> int:
        return int(getattr(self._impl, "nbytes_index", 0))

    def query(self, q: T.RangeQuery) -> np.ndarray:
        return self._impl.query(q)

    def count(self, q: T.RangeQuery) -> int:
        return self._impl.count(q)

    def query_batch(self, batch: T.QueryBatch,
                    spec: T.ResultSpec = T.IDS, delta=None) -> Results:
        spec = T.validate_mode(spec)
        with _path_span(self, batch, spec):
            if delta is not None and not delta.is_empty:
                return self._query_batch_delta(batch, spec, delta)
            if spec.kind == "ids":
                return [self.query(batch[k]) for k in range(len(batch))]
            if spec.kind == "count":
                # the impl's own count (device-reduced where it has one)
                return [self.count(batch[k]) for k in range(len(batch))]
            if self._cols is None:
                raise ValueError(
                    f"path {self.name!r} has no host columns for result spec "
                    f"{spec.kind!r}; construct PerQueryPath(..., cols=...)")
            return [spec.from_ids(self.query(batch[k]), self._cols)
                    for k in range(len(batch))]

    def _query_batch_delta(self, batch: T.QueryBatch, spec: T.ResultSpec,
                           delta) -> Results:
        # Host-side delta merge: the wrapped singles see only the frozen
        # base, so per query drop base tombstones, append the delta's host
        # match, and re-finalize every spec from ids against the combined
        # columns (this rung already pays Q host round trips — one numpy
        # filter more does not change its cost class).
        cols = delta.combined_cols()
        out = []
        for k in range(len(batch)):
            q = batch[k]
            ids = np.asarray(self.query(q), np.int64)
            if delta.has_base_tombs:
                ids = ids[~delta.base_tomb[ids]]
            ids = np.concatenate([ids, delta.match_delta_ids(q)])
            out.append(ids if spec.kind == "ids" else spec.from_ids(ids, cols))
        return out

    # A plannable=False path is never priced; keep the protocol total anyway.
    def cost(self, q: T.RangeQuery, sel: float, batch: int, model,
             spec: T.ResultSpec = T.IDS) -> float:
        return float("inf")

    def cost_batch(self, pi: PlanInputs, bucket: np.ndarray, model,
                   spec: T.ResultSpec = T.IDS) -> np.ndarray:
        # host-side planner cost, not a device sentinel: f64 inf is exact
        return np.full((len(pi),), np.inf, np.float64)
