"""VA-file (TPU adaptation of the paper's §2.2.3 / §5.3).

Kept nearly literal — the VA-file is already a branch-free two-phase scan and
therefore the most TPU-friendly of the paper's MDIS:

  * build: quantize every dimension to 2 bits (4 cells, paper's static
    ``b_j = 2``), boundaries either equal-width over the observed domain (the
    paper's choice) or equal-frequency (exposed as an option, which the paper
    lists as an obvious improvement direction, §8);
  * phase 1: the ``va_filter`` Pallas kernel compares packed approximations
    (16 dims / int32 word) against the approximated query — ints instead of
    floats, 16x less HBM traffic than the exact scan;
  * phase 2: leaf blocks containing at least one candidate are refined with
    the exact ``range_scan_visit`` kernel. Blocks with zero candidates are
    never touched — the paper's "buckets whose approximation intersects".

Unlike the tree MDIS, data stays in storage order (no permutation): the
VA-file is a *scan accelerator*, not a clustering structure.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import types as T
from repro.kernels import ops
from repro.kernels.va_filter import pack_codes, DIMS_PER_WORD

CELLS = 4  # 2 bits per dimension (paper §2.2.3)


_next_pow2 = T.next_pow2


@dataclasses.dataclass
class VAFile:
    """A built VA-file instance."""

    data_dev: jax.Array      # (m_pad, n_pad) exact columnar data, storage order
    packed_dev: jax.Array    # (w, n_pad) int32 packed 2-bit approximations
    boundaries: np.ndarray   # (m, CELLS - 1) inner cell boundaries per dim
    tile_n: int
    m: int
    n: int

    last_candidate_frac: float = 0.0
    last_visited_blocks: int = 0

    @property
    def nbytes_index(self) -> int:
        """Approximation storage (the VA-file's memory cost vs a plain scan)."""
        return int(np.prod(self.packed_dev.shape)) * 4

    def query_cells(self, q: T.RangeQuery) -> tuple[np.ndarray, np.ndarray]:
        """Approximate the query: per-dim [cell_lo, cell_hi] intersected cells."""
        cell_lo = np.zeros((self.m,), np.int32)
        cell_hi = np.full((self.m,), CELLS - 1, np.int32)
        for d in range(self.m):
            b = self.boundaries[d]
            # cell of x = #boundaries <= x  (boundaries are inner edges)
            cell_lo[d] = np.searchsorted(b, q.lower[d], side="right") if np.isfinite(q.lower[d]) else 0
            cell_hi[d] = np.searchsorted(b, q.upper[d], side="right") if np.isfinite(q.upper[d]) else CELLS - 1
        return cell_lo, cell_hi

    def query(self, q: T.RangeQuery) -> np.ndarray:
        """Two-phase query -> sorted matching object ids."""
        survivors = self._candidate_blocks(q)
        self.last_visited_blocks = int(survivors.size)
        if survivors.size == 0:
            return np.empty((0,), np.int64)
        n_visit = _next_pow2(survivors.size)
        ids = np.full((n_visit,), -1, np.int32)
        ids[: survivors.size] = survivors
        qlo_f, qhi_f = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        masks = np.asarray(
            ops.range_scan_visit(self.data_dev, jnp.asarray(ids), qlo_f, qhi_f,
                                 tile_n=self.tile_n)
        )[: survivors.size]
        pos = survivors[:, None] * self.tile_n + np.arange(self.tile_n)[None, :]
        pos = pos[masks > 0]
        return np.sort(pos[pos < self.n]).astype(np.int64)

    def _candidate_blocks(self, q: T.RangeQuery) -> np.ndarray:
        """Phase 1 for one query: block ids containing >= 1 VA candidate."""
        cell_lo, cell_hi = self.query_cells(q)
        m_s = -(-self.m // 8) * 8
        qlo = np.zeros((m_s, 1), np.int32)
        qhi = np.full((m_s, 1), CELLS - 1, np.int32)
        qlo[: self.m, 0] = cell_lo
        qhi[: self.m, 0] = cell_hi
        cand = np.asarray(ops.va_filter(
            self.packed_dev, jnp.asarray(qlo), jnp.asarray(qhi), self.m,
            tile_n=self.tile_n,
        )) > 0
        self.last_candidate_frac = float(cand[: self.n].mean())
        n_blocks = self.data_dev.shape[1] // self.tile_n
        block_any = cand[: n_blocks * self.tile_n].reshape(
            n_blocks, self.tile_n).any(axis=1)
        return np.nonzero(block_any)[0].astype(np.int32)

    def query_batch(self, batch: T.QueryBatch) -> list[np.ndarray]:
        """Batched two-phase query: per-query approximation filters feed one
        fused exact-refinement launch.

        Phase 1 stays per-query (the packed filter kernel is single-query —
        batching it is an open item); phase 2 flattens every surviving
        (query, block) pair into a single ``multi_range_scan_visit`` call, so
        the refinement dispatch + host sync amortize over the batch.
        """
        from repro.core.blockindex import run_fused_visit, scatter_visit_results

        q_n = len(batch)
        qids_l: list[np.ndarray] = []
        bids_l: list[np.ndarray] = []
        for k in range(q_n):
            blocks = self._candidate_blocks(batch[k])
            qids_l.append(np.full((blocks.size,), k, np.int32))
            bids_l.append(blocks)
        qids = np.concatenate(qids_l) if qids_l else np.empty((0,), np.int32)
        bids = np.concatenate(bids_l) if bids_l else np.empty((0,), np.int32)
        self.last_visited_blocks = int(qids.size)
        if qids.size == 0:
            return [np.empty((0,), np.int64) for _ in range(q_n)]
        masks = run_fused_visit(self.data_dev, qids, bids, batch, self.tile_n)
        return scatter_visit_results(
            masks, qids, bids, q_n, self.tile_n, self.n, perm=None,
        )


def build_vafile(
    dataset: T.Dataset, tile_n: int = 1024, scheme: str = "equal_width"
) -> VAFile:
    """Build a VA-file.

    Args:
      dataset: columnar dataset.
      tile_n: refinement block size.
      scheme: "equal_width" (paper default) or "equal_freq" (quantile cells).
    """
    cols = dataset.cols
    m, n = cols.shape
    if scheme == "equal_width":
        lo = cols.min(axis=1, keepdims=True)
        hi = cols.max(axis=1, keepdims=True)
        steps = np.arange(1, CELLS)[None, :] / CELLS  # (1, 3)
        boundaries = lo + (hi - lo) * steps  # (m, 3)
    elif scheme == "equal_freq":
        qs = np.arange(1, CELLS) / CELLS
        boundaries = np.quantile(cols, qs, axis=1).T  # (m, 3)
    else:
        raise ValueError(scheme)

    codes = np.zeros((m, n), np.uint8)
    for d in range(m):
        codes[d] = np.searchsorted(boundaries[d], cols[d], side="right").astype(np.uint8)
    packed = pack_codes(codes)
    # Pad objects: word 0 of padding must NOT alias cell 0 matches. We pad the
    # exact data with +inf (never matches); approximations may produce false
    # candidates in the padded tail, which the exact refine rejects.
    packed = T.pad_axis(packed, 1, tile_n, 0)
    data_padded, _, _ = ops.prepare_columnar(cols, tile_n=tile_n)
    return VAFile(
        data_dev=jnp.asarray(data_padded),
        packed_dev=jnp.asarray(packed),
        boundaries=boundaries.astype(np.float32),
        tile_n=tile_n,
        m=m,
        n=n,
    )
