"""VA-file (TPU adaptation of the paper's §2.2.3 / §5.3).

Kept nearly literal — the VA-file is already a branch-free two-phase scan and
therefore the most TPU-friendly of the paper's MDIS:

  * build: quantize every dimension to 2 bits (4 cells, paper's static
    ``b_j = 2``), boundaries either equal-width over the observed domain (the
    paper's choice) or equal-frequency (exposed as an option, which the paper
    lists as an obvious improvement direction, §8);
  * phase 1: the ``va_filter`` Pallas kernel compares packed approximations
    (16 dims / int32 word) against the approximated query — ints instead of
    floats, 16x less HBM traffic than the exact scan;
  * phase 2: leaf blocks containing at least one candidate are refined with
    the exact ``range_scan_visit`` kernel. Blocks with zero candidates are
    never touched — the paper's "buckets whose approximation intersects".

Unlike the tree MDIS, data stays in storage order (no permutation): the
VA-file is a *scan accelerator*, not a clustering structure.

Batched execution runs *both* phases fused: phase 1 is one
``multi_va_filter`` launch per batch (grid ``(n_tiles, Q)``, packed words
fetched from HBM once per batch) whose candidate masks reduce to per-
(query, block) survivor bits on device — a single small (Q, n_blocks) bool
readback replaces Q per-query mask transfers — and phase 2 flattens the
surviving pairs into one ``multi_range_scan_visit`` launch, exactly like the
tree MDIS. The per-query phases-1 regime this replaced was the one term the
cost model could not amortize (see ``planner.cost_vafile``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import types as T
from repro.kernels import ops
from repro.kernels.va_filter import BITS_PER_DIM, pack_codes, DIMS_PER_WORD

# Cells per dimension, derived from the kernel's bit width (paper §2.2.3:
# static b_j = 2 -> 4 cells). The planner's VA cost derives its slack and
# word counts from here too — one constant governs build, kernel, and plan.
CELLS = 1 << BITS_PER_DIM


_next_pow2 = T.next_pow2


@dataclasses.dataclass
class VAFile:
    """A built VA-file instance."""

    data_dev: jax.Array      # (m_pad, n_pad) exact columnar data, storage order
    packed_dev: jax.Array    # (w, n_pad) int32 packed 2-bit approximations
    boundaries: np.ndarray   # (m, CELLS - 1) inner cell boundaries per dim
    tile_n: int
    m: int
    n: int

    last_candidate_frac: float = 0.0
    last_visited_blocks: int = 0

    @property
    def nbytes_index(self) -> int:
        """Approximation storage (the VA-file's memory cost vs a plain scan)."""
        return int(np.prod(self.packed_dev.shape)) * 4

    @property
    def _m_sublane(self) -> int:
        return -(-self.m // 8) * 8

    def query_cells(self, q: T.RangeQuery) -> tuple[np.ndarray, np.ndarray]:
        """Approximate the query: per-dim [cell_lo, cell_hi] intersected cells."""
        cell_lo = np.zeros((self.m,), np.int32)
        cell_hi = np.full((self.m,), CELLS - 1, np.int32)
        for d in range(self.m):
            b = self.boundaries[d]
            # cell of x = #boundaries <= x  (boundaries are inner edges)
            cell_lo[d] = np.searchsorted(b, q.lower[d], side="right") if np.isfinite(q.lower[d]) else 0
            cell_hi[d] = np.searchsorted(b, q.upper[d], side="right") if np.isfinite(q.upper[d]) else CELLS - 1
        return cell_lo, cell_hi

    def query_cells_batch(self, batch: T.QueryBatch, q_pad: int | None = None
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Query-minor (m_s, q_pad or Q) cell bounds for the batched filter.

        Sublane-padded rows — and padding query columns beyond Q — carry
        [0, CELLS-1] match-all bounds (padding queries' rows are dropped by
        the caller). Per-query values are identical to ``query_cells``:
        ``searchsorted`` maps -inf to cell 0 and +inf to the last cell.
        """
        q_n = len(batch)
        width = q_pad or q_n
        cell_lo = np.zeros((self._m_sublane, width), np.int32)
        cell_hi = np.full((self._m_sublane, width), CELLS - 1, np.int32)
        for d in range(self.m):
            b = self.boundaries[d]
            cell_lo[d, :q_n] = np.searchsorted(b, batch.lower[:, d], side="right")
            cell_hi[d, :q_n] = np.searchsorted(b, batch.upper[:, d], side="right")
        return cell_lo, cell_hi

    def query(self, q: T.RangeQuery) -> np.ndarray:
        """Two-phase query -> sorted matching object ids."""
        survivors = self._candidate_blocks(q)
        self.last_visited_blocks = int(survivors.size)
        if survivors.size == 0:
            return np.empty((0,), np.int64)
        masks = self._refine(survivors, q)
        pos = survivors[:, None] * self.tile_n + np.arange(self.tile_n)[None, :]
        pos = pos[masks > 0]  # already on host: _refine syncs via device_get
        return np.sort(pos[pos < self.n]).astype(np.int64)

    def count(self, q: T.RangeQuery) -> int:
        """Count-only query: refinement masks are summed on device (object
        padding is +inf and never survives the exact compare)."""
        survivors = self._candidate_blocks(q)
        self.last_visited_blocks = int(survivors.size)
        if survivors.size == 0:
            return 0
        masks = self._refine(survivors, q, to_host=False)
        return int(ops.device_get(jnp.sum(masks != 0)))

    def _refine(self, survivors: np.ndarray, q: T.RangeQuery,
                to_host: bool = True):
        """Phase 2: exact visit scan of the surviving blocks -> (v, tile_n)."""
        n_visit = _next_pow2(survivors.size)
        ids = np.full((n_visit,), -1, np.int32)
        ids[: survivors.size] = survivors
        qlo_f, qhi_f = ops.query_bounds_device(q, self.data_dev.shape[0], self.data_dev.dtype)
        masks = ops.range_scan_visit(self.data_dev, jnp.asarray(ids), qlo_f,
                                     qhi_f, tile_n=self.tile_n)
        masks = masks[: survivors.size]  # padding visits (id -1) drop
        return ops.device_get(masks) if to_host else masks

    def _candidate_blocks(self, q: T.RangeQuery) -> np.ndarray:
        """Phase 1 for one query: block ids containing >= 1 VA candidate."""
        cell_lo, cell_hi = self.query_cells(q)
        m_s = self._m_sublane
        qlo = np.zeros((m_s, 1), np.int32)
        qhi = np.full((m_s, 1), CELLS - 1, np.int32)
        qlo[: self.m, 0] = cell_lo
        qhi[: self.m, 0] = cell_hi
        cand = ops.device_get(ops.va_filter(
            self.packed_dev, jnp.asarray(qlo), jnp.asarray(qhi), m=self.m,
            tile_n=self.tile_n,
        )) > 0
        self.last_candidate_frac = float(cand[: self.n].mean())
        n_blocks = self.data_dev.shape[1] // self.tile_n
        block_any = cand[: n_blocks * self.tile_n].reshape(
            n_blocks, self.tile_n).any(axis=1)
        return np.nonzero(block_any)[0].astype(np.int32)

    def _candidate_blocks_batch(self, batch: T.QueryBatch
                                ) -> tuple[np.ndarray, np.ndarray]:
        """Batched phase 1: one fused filter launch, one small host sync.

        ``multi_va_filter`` evaluates every query's approximation in a single
        (n_tiles, Q) launch and reduces the candidate masks to per-
        (query, block) survivor bits on device, so the only device->host
        transfer of the phase is one (Q, n_blocks) bool array — the batch
        counterpart of the Q mask readbacks the per-query path paid.
        """
        q_n = len(batch)
        q_pad = _next_pow2(q_n)  # pow2 query bucket bounds jit retraces
        cell_lo, cell_hi = self.query_cells_batch(batch, q_pad)
        block_any = ops.multi_va_filter(
            self.packed_dev, jnp.asarray(cell_lo), jnp.asarray(cell_hi),
            m=self.m, tile_n=self.tile_n, block_n=self.tile_n,
        )
        surv = ops.device_get(block_any)[:q_n]  # padding queries drop
        qids, bids = np.nonzero(surv)
        return qids.astype(np.int32), bids.astype(np.int32)

    def query_batch(self, batch: T.QueryBatch, spec: T.ResultSpec = T.IDS,
                    delta=None) -> list:
        """Batched two-phase query: both phases fused, one launch each.

        Phase 1 is a single ``multi_va_filter`` launch for the whole batch
        (one host sync for the (Q, n_blocks) survivor bits); phase 2
        flattens every surviving (query, block) pair into a single
        ``multi_visit_reduce`` call carrying the ResultSpec's on-device
        reducer — reduced shapes (count, top-k, aggregate) ship only their
        payload across the second sync. All per-query dispatch and readback
        taxes amortize over the batch.
        """
        payload, fin = self.launch_batch(batch, spec=spec, delta=delta)
        return fin(ops.device_get(payload) if payload is not None else None)

    def launch_batch(self, batch: T.QueryBatch, spec: T.ResultSpec = T.IDS,
                     delta=None) -> tuple:
        """Device half of the batched two-phase query -> (payload, finalize).

        Phase 1 (the packed filter + its small survivor-bits sync — a
        shape-deciding mid-stage sync, like the tree's prune) and the fused
        visit *launch* run here; the returned ``finalize`` defers the payload
        sync + host finalizers to the caller (the pipelined server's
        finalizer thread). ``payload`` is None when no block survived on a
        frozen dataset.
        """
        from repro.core.blockindex import launch_visits_batch

        spec = T.validate_mode(spec).validate(self.m)
        q_n = len(batch)
        qids, bids = self._candidate_blocks_batch(batch)
        self.last_visited_blocks = int(qids.size)
        return launch_visits_batch(
            self.data_dev, qids, bids, batch, self.tile_n, q_n, spec,
            self.n, perm=None, delta=delta,
        )


def build_vafile(
    dataset: T.Dataset, tile_n: int = 1024, scheme: str = "equal_width"
) -> VAFile:
    """Build a VA-file.

    Args:
      dataset: columnar dataset.
      tile_n: refinement block size.
      scheme: "equal_width" (paper default) or "equal_freq" (quantile cells).
    """
    cols = dataset.cols
    m, n = cols.shape
    if scheme == "equal_width":
        lo = cols.min(axis=1, keepdims=True)
        hi = cols.max(axis=1, keepdims=True)
        steps = np.arange(1, CELLS)[None, :] / CELLS  # (1, 3)
        boundaries = lo + (hi - lo) * steps  # (m, 3)
    elif scheme == "equal_freq":
        qs = np.arange(1, CELLS) / CELLS
        boundaries = np.quantile(cols, qs, axis=1).T  # (m, 3)
    else:
        raise ValueError(scheme)

    codes = np.zeros((m, n), np.uint8)
    for d in range(m):
        codes[d] = np.searchsorted(boundaries[d], cols[d], side="right").astype(np.uint8)
    packed = pack_codes(codes)
    # Pad objects: word 0 of padding must NOT alias cell 0 matches. We pad the
    # exact data with +inf (never matches); approximations may produce false
    # candidates in the padded tail, which the exact refine rejects.
    packed = T.pad_axis(packed, 1, tile_n, 0)
    data_padded, _, _ = ops.prepare_columnar(cols, tile_n=tile_n)
    return VAFile(
        data_dev=jnp.asarray(data_padded),
        packed_dev=jnp.asarray(packed),
        boundaries=boundaries.astype(np.float32),
        tile_n=tile_n,
        m=m,
        n=n,
    )
