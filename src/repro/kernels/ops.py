"""Jit'd public wrappers around the MDRQ Pallas kernels.

Handles layout/padding policy (pad m to sublanes with match-all bounds, n to
the tile size with +inf sentinel objects that never match), dtype casting of
the bounds, and interpret-mode selection (interpret=True on CPU so the kernel
body executes as the oracle-checked reference path; compiled Mosaic on TPU).

Batched execution: the ``multi_range_scan*`` wrappers drive the fused
multi-query kernels (``kernels.multi_scan``) — (m_pad, Q) query-minor bounds,
one launch for a whole query batch — and ``multi_va_filter`` does the same
for the VA-file's packed approximation phase. On the XLA backend they route
to the per-dimension-accumulating refs in ``ref.py``, which are also the
honest CPU throughput proxy for ``benchmarks/bench_throughput.py``.

Instrumentation: every public op is built by ``_counted`` — a plain-Python
wrapper that bumps a named launch counter before delegating to the jitted
implementation — and ``device_get`` is the counted device->host transfer
point. Tests use the counters to assert launch/sync budgets (e.g. "one
phase-1 launch and one host sync per VA-file batch") that wall-clock
measurements on CPU cannot see.

AOT serving cache: inside ``aot_capture()`` every counted call additionally
``jit_fn.lower(...).compile()``s its executable and stores it keyed by
(op, arg shapes/dtypes, statics); afterwards calls whose key is cached
dispatch straight to the compiled executable — no jit argument hashing, and
*provably* no retrace (the ``note_trace`` probe sits first in every jitted
body, so a retrace is observable as a log entry rather than inferred from
timing). ``serve.pipeline`` warms this cache at server construction; the
counters still see every call because the bump happens before the lookup.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import multi_scan as _ms
from repro.kernels import range_scan as _rs
from repro.kernels import ref as _ref
from repro.kernels import va_filter as _va

import os

# Kernel execution backend:
#   auto      — Mosaic on TPU, interpret-mode Pallas on CPU (correctness path)
#   interpret — force interpret-mode Pallas
#   xla       — execute the ref.py jnp implementations (identical semantics).
#               Benchmarks use this on CPU: interpret-mode runs the grid as a
#               Python loop, so its wall-time says nothing about the kernel;
#               the XLA path is the honest CPU proxy for throughput numbers.
_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "auto")


def use_xla() -> bool:
    return _BACKEND == "xla"


def set_backend(name: str) -> str:
    """Switch the kernel backend mid-process; returns the previous backend.

    The backend is read at *trace* time inside the jitted ops, and jit caches
    key on shapes/statics only — an executable traced under the old backend
    would be silently reused for any already-seen shape, so a switch must
    drop the compilation caches to actually take effect.
    """
    global _BACKEND
    prev = _BACKEND
    if name != prev:
        _BACKEND = name
        jax.clear_caches()
        # AOT executables bake the backend at trace time exactly like the jit
        # caches do — a stale one would silently serve the old backend.
        clear_aot_cache()
    return prev


def default_interpret() -> bool:
    if _BACKEND == "interpret":
        return True
    return jax.default_backend() != "tpu"


# -- launch / transfer instrumentation ---------------------------------------
# Counters live outside jit (wrappers bump them per call, not per trace), so a
# count of 1 really means one kernel launch / one device->host round trip.
#
# The store is the obs metrics registry (family "mdrq_launches_total",
# labeled by op) rather than a module-private dict: spans attribute their
# launch/sync budgets from the same counters tests assert on, and the
# exporters ship them without a second accounting path. The historical
# ``counter``/``counters``/``reset_counters`` API is preserved on top —
# launch-budget tests run unchanged against the new backend.

from repro.obs import metrics as _obs_metrics

_LAUNCH_FAMILY = "mdrq_launches_total"
_LAUNCH_HELP = ("Kernel launches (and device->host transfers, op=host_sync) "
                "counted per public op wrapper call")
# op name -> its registry Counter. Cached so the per-launch cost is one dict
# lookup + one float add; registry reset() keeps these objects live.
_COUNTERS: dict[str, _obs_metrics.Counter] = {}


def _launch_counter(name: str) -> _obs_metrics.Counter:
    c = _COUNTERS.get(name)
    if c is None:
        c = _obs_metrics.registry().counter(_LAUNCH_FAMILY, help=_LAUNCH_HELP,
                                            op=name)
        _COUNTERS[name] = c
    return c


def _bump(name: str) -> None:
    _launch_counter(name).inc()


def counter(name: str) -> int:
    """Launches of op ``name`` (or ``"host_sync"`` transfers) since reset."""
    c = _COUNTERS.get(name)
    return int(c.value) if c is not None else 0


def counters() -> dict[str, int]:
    """Nonzero per-op launch counts since the last reset. AOT cache events
    ride the same store (for registry-reset liveness) but report through
    ``aot_counters`` — launch-budget equality assertions stay exact."""
    return {name: int(c.value) for name, c in _COUNTERS.items()
            if c.value and not name.startswith("aot:")}


def reset_counters() -> None:
    for c in _COUNTERS.values():
        c.reset()


def device_get(x):
    """Counted device->host transfer — the host-sync tax the cost model prices.

    Accepts a single array or a payload pytree (tuple/list — the ResultSpec
    reducers return e.g. ``(values, indices, counts)``); either way it is one
    logical synchronization, counted once.
    """
    _bump("host_sync")
    if isinstance(x, (tuple, list)):
        return jax.device_get(x)
    return np.asarray(x)


# -- retrace observability ----------------------------------------------------
# ``note_trace(op)`` is the first statement of every jitted implementation
# body: it runs when (and only when) jax traces the function — never per
# execution — so ``trace_log()`` is a direct record of (re)compilations. The
# serving pipeline's "zero retraces after warmup" guarantee is asserted on
# this log, not inferred from wall time.

_TRACE_LOG: list[str] = []


def note_trace(name: str) -> None:
    """Trace-time probe (call first inside a jitted body)."""
    _TRACE_LOG.append(name)


def trace_log() -> tuple[str, ...]:
    """Op names in (re)trace order since the last ``reset_trace_log``."""
    return tuple(_TRACE_LOG)


def reset_trace_log() -> None:
    _TRACE_LOG.clear()


# -- AOT executable cache -----------------------------------------------------
# (op name, per-arg (shape, dtype) abstraction, statics) -> the compiled
# executable from ``jit_fn.lower(...).compile()``. A hit bypasses the jit
# dispatch entirely (``exe(*args)`` — statics are baked in), so a warmed
# serving path cannot retrace no matter what jax's own caches do. Population
# only happens inside ``aot_capture()`` (the server warmup pass); outside it
# the cache is read-only, and the lookup itself costs one tuple build + one
# dict get per call. Reads are GIL-safe from any thread; capture is expected
# single-threaded (one warmup pass).

_AOT_CACHE: dict = {}
_AOT_CAPTURE: bool = False
_AOT_FAMILY = "mdrq_aot_total"
_AOT_HELP = ("AOT executable cache events: compile (warmup capture), hit "
             "(dispatched to a compiled executable), miss (warmed process "
             "fell back to jit dispatch)")


def _aot_bump(event: str) -> None:
    key = "aot:" + event
    c = _COUNTERS.get(key)
    if c is None:
        c = _obs_metrics.registry().counter(_AOT_FAMILY, help=_AOT_HELP,
                                            event=event)
        _COUNTERS[key] = c
    c.inc()


def _abstract(x):
    """Hashable cache-key atom for one call argument: arrays collapse to
    (shape, dtype) — exactly what decides a retrace — statics stay as-is."""
    if x is None:
        return None
    if isinstance(x, (tuple, list)):
        return ("seq", type(x).__name__, tuple(_abstract(e) for e in x))
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is not None and dtype is not None:
        return ("arr", tuple(shape), str(dtype))
    return ("static", x)


def _aot_key(name: str, args: tuple, kwargs: dict):
    return (name, tuple(_abstract(a) for a in args),
            tuple(sorted((k, _abstract(v)) for k, v in kwargs.items())))


@contextlib.contextmanager
def aot_capture():
    """Within this context every counted call lower+compiles (and caches) its
    executable on a key miss. The call still executes and returns normally —
    warmup doubles as a correctness-visible dry run."""
    global _AOT_CAPTURE
    prev = _AOT_CAPTURE
    _AOT_CAPTURE = True
    try:
        yield
    finally:
        _AOT_CAPTURE = prev


def aot_cache_size() -> int:
    return len(_AOT_CACHE)


def aot_cache_keys() -> tuple:
    return tuple(_AOT_CACHE)


def clear_aot_cache() -> None:
    _AOT_CACHE.clear()


def aot_counters() -> dict[str, int]:
    """Nonzero AOT cache event counts ("compile" / "hit" / "miss")."""
    out = {}
    for key, c in _COUNTERS.items():
        if key.startswith("aot:") and c.value:
            out[key[4:]] = int(c.value)
    return out


def counted(name: str, doc: str):
    """Build a public op: bump the named launch counter, consult the AOT
    executable cache, and otherwise delegate to the jitted implementation.
    One definition keeps every op in the accounting — a hand-written wrapper
    that forgets the bump silently escapes it. Other modules that own jitted
    entry points (e.g. ``core.distributed``) register them through this same
    hook so no launch path escapes the counters — and so every op is AOT
    warmable for free."""
    def deco(jit_fn):
        def wrapper(*args, **kwargs):
            _bump(name)
            try:
                key = _aot_key(name, args, kwargs)
                exe = _AOT_CACHE.get(key)
            except TypeError:  # unhashable static — not AOT-cacheable
                return jit_fn(*args, **kwargs)
            if exe is None:
                if not _AOT_CAPTURE:
                    if _AOT_CACHE:
                        # a warmed process fell off the compiled set — the
                        # "zero retraces" budget tests watch this counter
                        _aot_bump("miss")
                    return jit_fn(*args, **kwargs)
                exe = jit_fn.lower(*args, **kwargs).compile()
                try:
                    # convention check before caching: executables take the
                    # dynamic args positionally with statics baked in, so a
                    # call site passing a static *positionally* produces an
                    # executable we cannot redispatch to — skip it (the op
                    # still works through jit; fix the call site to pass
                    # statics as keywords to make it AOT-cacheable)
                    out = exe(*args)
                except TypeError:
                    return jit_fn(*args, **kwargs)
                _AOT_CACHE[key] = exe
                _aot_bump("compile")
                return out
            else:
                _aot_bump("hit")
            return exe(*args)
        wrapper.__name__ = wrapper.__qualname__ = name
        wrapper.__doc__ = doc
        wrapper.__wrapped__ = jit_fn
        return wrapper
    return deco


_counted = counted  # historical spelling used by the in-module registrations


def prepare_columnar(
    cols: np.ndarray, tile_n: int = _rs.DEFAULT_TILE_N, dtype=jnp.float32
) -> tuple[np.ndarray, int, int]:
    """Pad (m, n) columnar data for the kernel.

    Dim padding rows are 0.0 (queried with match-all bounds); object padding
    columns are +inf (never match any finite upper bound).

    Returns (padded array, m, n) with original sizes.
    """
    from repro.core import types as T  # deferred: breaks ops<->core cycle
    m, n = cols.shape
    x = T.pad_axis(cols, 0, _rs.SUBLANES, 0.0)
    x = T.pad_axis(x, 1, tile_n, np.inf)
    return np.asarray(x, dtype=np.float32 if dtype == jnp.float32 else x.dtype), m, n


def query_bounds_device(q: T.RangeQuery, m_pad: int, dtype) -> tuple[jax.Array, jax.Array]:
    """(m_pad, 1) finite device bounds for a query (pad rows = match-all).

    ``dtype`` threads into the match-all substitution so the extrema stay
    finite *in the comparison dtype* (float32 extrema round to +inf under a
    bfloat16 cast and would match the +inf padding sentinels).
    """
    from repro.core import types as T  # deferred: breaks ops<->core cycle
    lo, up = T.padded_query_bounds(q, m_pad)
    lo, up = T.finite_query_bounds(lo, up, dtype=dtype)
    lo_d = jnp.asarray(lo, dtype=dtype).reshape(-1, 1)
    up_d = jnp.asarray(up, dtype=dtype).reshape(-1, 1)
    return lo_d, up_d


def batch_bounds_device(batch, m_pad: int, dtype,
                        q_pad: int | None = None) -> tuple[jax.Array, jax.Array]:
    """(m_pad, q_pad or Q) finite device bounds for a QueryBatch.

    Pad rows — and padding query columns beyond Q when ``q_pad`` rounds the
    batch to a jit bucket — are match-all in ``dtype``'s finite extrema;
    callers drop their output rows.
    """
    from repro.core import types as T  # deferred: breaks ops<->core cycle
    if not isinstance(batch, T.QueryBatch):
        batch = T.QueryBatch.from_queries(list(batch))
    lo, up = batch.bounds_columnar(m_pad, q_pad, dtype=dtype)
    return jnp.asarray(lo, dtype=dtype), jnp.asarray(up, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _range_scan_jit(
    data_cm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("range_scan")
    if use_xla():
        return _ref.range_scan_ref(data_cm, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_tiles(
        data_cm, lower, upper, tile_n=tile_n, interpret=interpret
    )


range_scan = _counted(
    "range_scan",
    "Full vectorized range scan over padded columnar data -> (n_pad,) int8.",
)(_range_scan_jit)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _range_scan_visit_jit(
    data_cm: jax.Array,
    block_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("range_scan_visit")
    if use_xla():
        m_pad, n_pad = data_cm.shape
        blocks = data_cm.reshape(m_pad, n_pad // tile_n, tile_n).transpose(1, 0, 2)
        return _ref.range_scan_blocks_ref(blocks, block_ids,
                                          lower[:, 0], upper[:, 0])
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_visit(
        data_cm, block_ids, lower, upper, tile_n=tile_n, interpret=interpret
    )


range_scan_visit = _counted(
    "range_scan_visit",
    "Scan only the listed tile ids -> (n_visit, tile_n) int8 masks.",
)(_range_scan_visit_jit)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _range_scan_vertical_jit(
    data_cm: jax.Array,
    dim_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("range_scan_vertical")
    if use_xla():
        rows = data_cm[dim_ids]  # touch only the queried dimensions' columns
        return _ref.range_scan_ref(rows, lower[dim_ids, 0], upper[dim_ids, 0])
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_vertical(
        data_cm, dim_ids, lower, upper, tile_n=tile_n, interpret=interpret
    )


range_scan_vertical = _counted(
    "range_scan_vertical",
    "Partial-match scan touching only queried dims -> (n_pad,) int8.",
)(_range_scan_vertical_jit)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _multi_range_scan_jit(
    data_cm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("multi_range_scan")
    if use_xla():
        return _ref.multi_scan_ref(data_cm, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _ms.multi_scan_tiles(
        data_cm, lower, upper, tile_n=tile_n, interpret=interpret
    )


multi_range_scan = _counted(
    "multi_range_scan",
    "Fused full scan of a query batch -> (Q, n_pad) int8 masks.",
)(_multi_range_scan_jit)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _multi_range_scan_vertical_jit(
    data_cm: jax.Array,
    dim_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("multi_range_scan_vertical")
    if use_xla():
        return _ref.multi_scan_vertical_ref(data_cm, dim_ids, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _ms.multi_scan_vertical(
        data_cm, dim_ids, lower, upper, tile_n=tile_n, interpret=interpret
    )


multi_range_scan_vertical = _counted(
    "multi_range_scan_vertical",
    "Batched partial-match scan -> (Q, n_pad) int8 masks.",
)(_multi_range_scan_vertical_jit)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _multi_range_scan_visit_jit(
    data_cm: jax.Array,
    query_ids: jax.Array,
    block_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("multi_range_scan_visit")
    if use_xla():
        m_pad, n_pad = data_cm.shape
        blocks = data_cm.reshape(m_pad, n_pad // tile_n, tile_n).transpose(1, 0, 2)
        return _ref.multi_scan_blocks_ref(blocks, query_ids, block_ids, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _ms.multi_scan_visit(
        data_cm, query_ids, block_ids, lower, upper, tile_n=tile_n,
        interpret=interpret,
    )


multi_range_scan_visit = _counted(
    "multi_range_scan_visit",
    "Batched two-phase refinement over a (query, block) visit list "
    "-> (V, tile_n) int8 per-visit masks.",
)(_multi_range_scan_visit_jit)


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def _range_scan_rows_jit(
    data_rm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("range_scan_rows")
    if use_xla():
        ok = jnp.logical_and(data_rm >= lower, data_rm <= upper)
        return jnp.all(ok, axis=1).astype(jnp.int8)
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_rows(
        data_rm, lower, upper, tile_rows=tile_rows, interpret=interpret
    )


range_scan_rows = _counted(
    "range_scan_rows",
    "Row-major (horizontal layout) scan -> (n_pad,) int8.",
)(_range_scan_rows_jit)


@functools.partial(jax.jit, static_argnames=("m", "tile_n", "interpret"))
def _va_filter_jit(
    packed: jax.Array,
    cell_lo: jax.Array,
    cell_hi: jax.Array,
    m: int,
    *,
    tile_n: int = _va.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("va_filter")
    if use_xla():
        return _ref.va_filter_packed_ref(packed, cell_lo[:, 0], cell_hi[:, 0], m)
    if interpret is None:
        interpret = default_interpret()
    return _va.va_filter_packed(
        packed, cell_lo, cell_hi, m, tile_n=tile_n, interpret=interpret
    )


va_filter = _counted(
    "va_filter",
    "Packed VA-file approximation filter -> (n_pad,) int8 candidate mask.",
)(_va_filter_jit)


@functools.partial(jax.jit, static_argnames=("m", "tile_n", "block_n", "interpret"))
def _multi_va_filter_jit(
    packed: jax.Array,
    cell_lo: jax.Array,
    cell_hi: jax.Array,
    m: int,
    *,
    tile_n: int = _va.DEFAULT_TILE_N,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("multi_va_filter")
    if use_xla():
        out = _ref.multi_va_filter_packed_ref(packed, cell_lo, cell_hi, m)
    else:
        if interpret is None:
            interpret = default_interpret()
        out = _va.multi_va_filter_packed(
            packed, cell_lo, cell_hi, m, tile_n=tile_n, interpret=interpret
        )
    if block_n is not None:
        q_n, n_pad = out.shape
        # Reduce to per-(query, block) survivor bits *on device*: only the
        # small (Q, n_blocks) array ever crosses to the host.
        out = jnp.any((out != 0).reshape(q_n, n_pad // block_n, block_n), axis=2)
    return out


multi_va_filter = _counted(
    "multi_va_filter",
    "Batched packed VA filter, one launch per query batch: (Q, n_pad) int8 "
    "candidate masks, or — with ``block_n`` — the on-device reduction to "
    "(Q, n_pad // block_n) bool per-block survivor bits (the phase-2 visit "
    "list seed; the reduction rides in the same jit).",
)(_multi_va_filter_jit)


# -- fused spec-reduce launches (the ResultSpec layer's device half) ----------
# Each op composes a mask-producing kernel with the spec's on-device reducer
# in ONE jit (the spec is a frozen dataclass and rides as a static argument),
# so a reduced result shape — count, top-k, aggregate — is exactly one device
# launch and, with the single ``device_get`` of the payload, one host sync
# per batch. The identity specs (Ids/Mask) flow through unchanged: their
# "payload" is the mask itself.
#
# Mutable data plane (DESIGN.md §11): each op takes two optional extras that
# ride in the SAME jit, so a non-empty delta costs zero additional launches —
#   * ``base_tomb`` — (n_pad,) int8 tombstone flags in the data's storage
#     order, ANDed into the base match masks before the reducer sees them;
#   * ``delta_cm``  — the delta segment as a (m_pad, d_pad) columnar block
#     (same padding contract as ``data_cm``; tombstoned delta rows are +inf
#     poisoned at build time). When present the op scans it with the same
#     bounds, reduces it with the same spec, and returns a (base_payload,
#     delta_payload) pair — one ``device_get`` of the pair is still one host
#     sync, and the spec's ``merge_delta`` folds the halves on the host.


def _multi_scan_masks(data_cm, lower, upper, *, tile_n, interpret):
    """Backend-dispatched fused multi-query mask kernel (trace-time helper)."""
    if use_xla():
        return _ref.multi_scan_ref(data_cm, lower, upper)
    return _ms.multi_scan_tiles(data_cm, lower, upper, tile_n=tile_n,
                                interpret=interpret)


def _delta_payload(delta_cm, lower, upper, *, spec, tile_n, interpret):
    """Scan + reduce the delta block with the batch's bounds (same jit)."""
    dmask = _multi_scan_masks(delta_cm, lower, upper, tile_n=tile_n,
                              interpret=interpret)
    return spec.device_reduce(dmask, delta_cm, tile_n=tile_n,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("spec", "tile_n", "interpret"))
def _multi_scan_reduce_jit(
    data_cm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    delta_cm: jax.Array | None = None,
    base_tomb: jax.Array | None = None,
    *,
    spec,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
):
    note_trace("multi_scan_reduce")
    if interpret is None:
        interpret = default_interpret()
    mask = _multi_scan_masks(data_cm, lower, upper, tile_n=tile_n,
                             interpret=interpret)
    if base_tomb is not None:
        from repro.kernels import reducers as _red
        mask = _red.fold_tombstones(mask, base_tomb)
    base = spec.device_reduce(mask, data_cm, tile_n=tile_n,
                              interpret=interpret)
    if delta_cm is None:
        return base
    return base, _delta_payload(delta_cm, lower, upper, spec=spec,
                                tile_n=tile_n, interpret=interpret)


multi_scan_reduce = _counted(
    "multi_scan_reduce",
    "Fused full scan of a query batch + the ResultSpec's on-device reducer "
    "in one launch -> the spec's payload (masks for Ids/Mask, (Q,) counts, "
    "(Q, k) top-k values/positions, (Q,) aggregates).",
)(_multi_scan_reduce_jit)


@functools.partial(jax.jit, static_argnames=("spec", "tile_n", "interpret"))
def _multi_scan_vertical_reduce_jit(
    data_cm: jax.Array,
    dim_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    delta_cm: jax.Array | None = None,
    base_tomb: jax.Array | None = None,
    *,
    spec,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
):
    note_trace("multi_scan_vertical_reduce")
    if interpret is None:
        interpret = default_interpret()
    if use_xla():
        mask = _ref.multi_scan_vertical_ref(data_cm, dim_ids, lower, upper)
    else:
        mask = _ms.multi_scan_vertical(data_cm, dim_ids, lower, upper,
                                       tile_n=tile_n, interpret=interpret)
    if base_tomb is not None:
        from repro.kernels import reducers as _red
        mask = _red.fold_tombstones(mask, base_tomb)
    base = spec.device_reduce(mask, data_cm, tile_n=tile_n,
                              interpret=interpret)
    if delta_cm is None:
        return base
    # The delta is tiny: a full multi-scan over it is exact (unconstrained
    # dims carry match-all bounds) and avoids a second vertical variant.
    return base, _delta_payload(delta_cm, lower, upper, spec=spec,
                                tile_n=tile_n, interpret=interpret)


multi_scan_vertical_reduce = _counted(
    "multi_scan_vertical_reduce",
    "Batched partial-match scan + ResultSpec reducer in one launch.",
)(_multi_scan_vertical_reduce_jit)


@functools.partial(jax.jit,
                   static_argnames=("spec", "tile_n", "n_queries", "interpret"))
def _multi_visit_reduce_jit(
    data_cm: jax.Array,
    query_ids: jax.Array,
    block_ids: jax.Array,
    valid: jax.Array,
    visit_index: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    delta_cm: jax.Array | None = None,
    base_tomb: jax.Array | None = None,
    *,
    spec,
    tile_n: int = _rs.DEFAULT_TILE_N,
    n_queries: int = 1,
    interpret: bool | None = None,
):
    note_trace("multi_visit_reduce")
    if interpret is None:
        interpret = default_interpret()
    if use_xla():
        m_pad, n_pad = data_cm.shape
        blocks = data_cm.reshape(m_pad, n_pad // tile_n, tile_n).transpose(1, 0, 2)
        masks = _ref.multi_scan_blocks_ref(blocks, query_ids, block_ids,
                                           lower, upper)
    else:
        masks = _ms.multi_scan_visit(data_cm, query_ids, block_ids, lower,
                                     upper, tile_n=tile_n, interpret=interpret)
    if base_tomb is not None:
        from repro.kernels import reducers as _red
        masks = _red.fold_tombstones(
            masks, _red.gather_tomb_blocks(base_tomb, block_ids, tile_n))
    base = spec.reduce_visits(masks, data_cm, query_ids, block_ids, valid,
                              visit_index, tile_n=tile_n,
                              n_queries=n_queries, interpret=interpret)
    if delta_cm is None:
        return base
    # The (m_pad, q_pad) bounds already cover the whole batch, so the delta
    # scans once for every query regardless of which blocks it visited.
    return base, _delta_payload(delta_cm, lower, upper, spec=spec,
                                tile_n=tile_n, interpret=interpret)


multi_visit_reduce = _counted(
    "multi_visit_reduce",
    "Batched two-phase refinement over a (query, block) visit list + the "
    "ResultSpec's on-device visit reducer in one launch (shared by the tree "
    "MDIS and the VA-file phase 2).",
)(_multi_visit_reduce_jit)


@jax.jit
def _mask_counts_jit(mask: jax.Array) -> jax.Array:
    note_trace("mask_counts")
    return jnp.sum(mask != 0, axis=-1).astype(jnp.int32)


mask_counts = _counted(
    "mask_counts",
    "On-device match counts over the object axis (count-only result mode). "
    "Works for both (n_pad,) single-query and (Q, n_pad) batched masks; "
    "padding objects are +inf sentinels that never match, so summing the "
    "padded axis is exact. The sum is the distributed_count pattern "
    "localized to one device: the result crossing to host is O(Q) ints, "
    "never an id array.",
)(_mask_counts_jit)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _kv_visit_attention_jit(
    q: jax.Array,
    k_blocks: jax.Array,
    v_blocks: jax.Array,
    block_ids: jax.Array,
    pos: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    note_trace("kv_visit_attention")
    from repro.kernels import kv_visit as _kvv
    if use_xla():
        return _ref.kv_visit_attention_ref(q, k_blocks, v_blocks, block_ids, pos)
    if interpret is None:
        interpret = default_interpret()
    return _kvv.kv_visit_attention(q, k_blocks, v_blocks, block_ids, pos,
                                   interpret=interpret)


kv_visit_attention = _counted(
    "kv_visit_attention",
    "Block-visit decode attention (zone-map-pruned KV) -> (B, KV, G, hd).",
)(_kv_visit_attention_jit)
