"""Jit'd public wrappers around the MDRQ Pallas kernels.

Handles layout/padding policy (pad m to sublanes with match-all bounds, n to
the tile size with +inf sentinel objects that never match), dtype casting of
the bounds, and interpret-mode selection (interpret=True on CPU so the kernel
body executes as the oracle-checked reference path; compiled Mosaic on TPU).

Batched execution: the ``multi_range_scan*`` wrappers drive the fused
multi-query kernels (``kernels.multi_scan``) — (m_pad, Q) query-minor bounds,
one launch for a whole query batch. On the XLA backend they route to the
per-dimension-accumulating refs in ``ref.py``, which are also the honest CPU
throughput proxy for ``benchmarks/bench_throughput.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import types as T
from repro.kernels import multi_scan as _ms
from repro.kernels import range_scan as _rs
from repro.kernels import ref as _ref
from repro.kernels import va_filter as _va

import os

# Kernel execution backend:
#   auto      — Mosaic on TPU, interpret-mode Pallas on CPU (correctness path)
#   interpret — force interpret-mode Pallas
#   xla       — execute the ref.py jnp implementations (identical semantics).
#               Benchmarks use this on CPU: interpret-mode runs the grid as a
#               Python loop, so its wall-time says nothing about the kernel;
#               the XLA path is the honest CPU proxy for throughput numbers.
_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "auto")


def use_xla() -> bool:
    return _BACKEND == "xla"


def default_interpret() -> bool:
    if _BACKEND == "interpret":
        return True
    return jax.default_backend() != "tpu"


def prepare_columnar(
    cols: np.ndarray, tile_n: int = _rs.DEFAULT_TILE_N, dtype=jnp.float32
) -> tuple[np.ndarray, int, int]:
    """Pad (m, n) columnar data for the kernel.

    Dim padding rows are 0.0 (queried with match-all bounds); object padding
    columns are +inf (never match any finite upper bound).

    Returns (padded array, m, n) with original sizes.
    """
    m, n = cols.shape
    x = T.pad_axis(cols, 0, _rs.SUBLANES, 0.0)
    x = T.pad_axis(x, 1, tile_n, np.inf)
    return np.asarray(x, dtype=np.float32 if dtype == jnp.float32 else x.dtype), m, n


def query_bounds_device(q: T.RangeQuery, m_pad: int, dtype) -> tuple[jax.Array, jax.Array]:
    """(m_pad, 1) finite device bounds for a query (pad rows = match-all)."""
    lo, up = T.padded_query_bounds(q, m_pad)
    lo, up = T.finite_query_bounds(lo, up)
    lo_d = jnp.asarray(lo, dtype=dtype).reshape(-1, 1)
    up_d = jnp.asarray(up, dtype=dtype).reshape(-1, 1)
    return lo_d, up_d


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def range_scan(
    data_cm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    """Full vectorized range scan over padded columnar data -> (n_pad,) int8."""
    if use_xla():
        return _ref.range_scan_ref(data_cm, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_tiles(
        data_cm, lower, upper, tile_n=tile_n, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def range_scan_visit(
    data_cm: jax.Array,
    block_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    """Scan only the listed tile ids -> (n_visit, tile_n) int8 masks."""
    if use_xla():
        m_pad, n_pad = data_cm.shape
        blocks = data_cm.reshape(m_pad, n_pad // tile_n, tile_n).transpose(1, 0, 2)
        return _ref.range_scan_blocks_ref(blocks, block_ids,
                                          lower[:, 0], upper[:, 0])
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_visit(
        data_cm, block_ids, lower, upper, tile_n=tile_n, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def range_scan_vertical(
    data_cm: jax.Array,
    dim_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    """Partial-match scan touching only queried dims -> (n_pad,) int8."""
    if use_xla():
        rows = data_cm[dim_ids]  # touch only the queried dimensions' columns
        return _ref.range_scan_ref(rows, lower[dim_ids, 0], upper[dim_ids, 0])
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_vertical(
        data_cm, dim_ids, lower, upper, tile_n=tile_n, interpret=interpret
    )


def batch_bounds_device(batch, m_pad: int, dtype) -> tuple[jax.Array, jax.Array]:
    """(m_pad, Q) finite device bounds for a QueryBatch (pad rows = match-all)."""
    if not isinstance(batch, T.QueryBatch):
        batch = T.QueryBatch.from_queries(list(batch))
    lo, up = batch.bounds_columnar(m_pad)
    return jnp.asarray(lo, dtype=dtype), jnp.asarray(up, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def multi_range_scan(
    data_cm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused full scan of a query batch -> (Q, n_pad) int8 masks."""
    if use_xla():
        return _ref.multi_scan_ref(data_cm, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _ms.multi_scan_tiles(
        data_cm, lower, upper, tile_n=tile_n, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def multi_range_scan_vertical(
    data_cm: jax.Array,
    dim_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched partial-match scan -> (Q, n_pad) int8 masks."""
    if use_xla():
        return _ref.multi_scan_vertical_ref(data_cm, dim_ids, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _ms.multi_scan_vertical(
        data_cm, dim_ids, lower, upper, tile_n=tile_n, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def multi_range_scan_visit(
    data_cm: jax.Array,
    query_ids: jax.Array,
    block_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = _rs.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched two-phase refinement over a (query, block) visit list
    -> (V, tile_n) int8 per-visit masks."""
    if use_xla():
        m_pad, n_pad = data_cm.shape
        blocks = data_cm.reshape(m_pad, n_pad // tile_n, tile_n).transpose(1, 0, 2)
        return _ref.multi_scan_blocks_ref(blocks, query_ids, block_ids, lower, upper)
    if interpret is None:
        interpret = default_interpret()
    return _ms.multi_scan_visit(
        data_cm, query_ids, block_ids, lower, upper, tile_n=tile_n,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def range_scan_rows(
    data_rm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Row-major (horizontal layout) scan -> (n_pad,) int8."""
    if use_xla():
        ok = jnp.logical_and(data_rm >= lower, data_rm <= upper)
        return jnp.all(ok, axis=1).astype(jnp.int8)
    if interpret is None:
        interpret = default_interpret()
    return _rs.range_scan_rows(
        data_rm, lower, upper, tile_rows=tile_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("m", "tile_n", "interpret"))
def va_filter(
    packed: jax.Array,
    cell_lo: jax.Array,
    cell_hi: jax.Array,
    m: int,
    *,
    tile_n: int = _va.DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed VA-file approximation filter -> (n_pad,) int8 candidate mask."""
    if use_xla():
        return _ref.va_filter_packed_ref(packed, cell_lo[:, 0], cell_hi[:, 0], m)
    if interpret is None:
        interpret = default_interpret()
    return _va.va_filter_packed(
        packed, cell_lo, cell_hi, m, tile_n=tile_n, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_visit_attention(
    q: jax.Array,
    k_blocks: jax.Array,
    v_blocks: jax.Array,
    block_ids: jax.Array,
    pos: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Block-visit decode attention (zone-map-pruned KV) -> (B, KV, G, hd)."""
    from repro.kernels import kv_visit as _kvv
    if use_xla():
        return _ref.kv_visit_attention_ref(q, k_blocks, v_blocks, block_ids, pos)
    if interpret is None:
        interpret = default_interpret()
    return _kvv.kv_visit_attention(q, k_blocks, v_blocks, block_ids, pos,
                                   interpret=interpret)
