"""Pallas TPU kernel: vectorized multidimensional range scan.

TPU-native adaptation of the paper's Listing 2 (AVX compare of a query object
against data objects). Differences forced by the hardware (see DESIGN.md §2):

  * layout is **dimension-major** ``(m, n)`` — the lane axis runs over objects,
    so one VPU op compares 128 objects of one attribute against one bound;
  * there is no per-lane early break; the AND across dimensions happens in
    vector registers (the paper's vertical-partitioning bitmask-merge, §3.2,
    collapsed into a single in-register reduction);
  * blocks are (m_pad, TN) VMEM tiles: m is padded to a multiple of 8
    (sublanes), TN is a multiple of 128 (lanes).

Two entry points:

  * ``range_scan_tiles``     — full scan: grid over all n/TN tiles.
  * ``range_scan_visit``     — two-phase scan: a scalar-prefetched list of
    block ids selects which tiles are visited (kd-tree / R-tree / VA-file
    refinement). Grid size = number of visited blocks, so pruned blocks cost
    *nothing* — the TPU analogue of "skip subtrees".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
SUBLANES = 8
DEFAULT_TILE_N = 1024


def _scan_kernel(lower_ref, upper_ref, data_ref, out_ref):
    """Compare one (m_pad, TN) columnar tile against the query bounds."""
    x = data_ref[...]
    lo = lower_ref[...]  # (m_pad, 1), broadcasts over lanes
    up = upper_ref[...]
    ok = jnp.logical_and(x >= lo, x <= up)
    out_ref[...] = jnp.all(ok, axis=0, keepdims=True).astype(jnp.int8)


def range_scan_tiles(
    data_cm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Full columnar range scan.

    Args:
      data_cm: (m_pad, n_pad) columnar data; m_pad % 8 == 0, n_pad % tile_n == 0.
        Padding dims must carry match-all bounds; padding objects are dropped by
        the caller.
      lower, upper: (m_pad, 1) bounds in data dtype (finite — caller replaces
        +-inf with dtype extrema).

    Returns:
      (n_pad,) int8 match mask.
    """
    m_pad, n_pad = data_cm.shape
    assert m_pad % SUBLANES == 0, m_pad
    assert n_pad % tile_n == 0 and tile_n % LANES == 0, (n_pad, tile_n)
    assert lower.shape == (m_pad, 1) and upper.shape == (m_pad, 1)

    grid = (n_pad // tile_n,)
    out = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((m_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((m_pad, tile_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.int8),
        interpret=interpret,
    )(lower.astype(data_cm.dtype), upper.astype(data_cm.dtype), data_cm)
    return out[0]


def _vertical_kernel(dim_ids_ref, lower_ref, upper_ref, data_ref, out_ref):
    """One grid step = one (queried dimension, tile) pair — vertical partitioning.

    Grid is (n_tiles, n_qdims); the out tile is revisited across j and the
    per-dimension masks are AND-merged in place (the paper's bitmask
    intersection, §3.2, without materializing per-dimension bitmasks in HBM).
    """
    j = pl.program_id(1)
    d = dim_ids_ref[j]
    x = data_ref[...]  # (1, TN) — only the queried dimension's row is fetched
    lo = lower_ref[d, 0]
    up = upper_ref[d, 0]
    ok = jnp.logical_and(x >= lo, x <= up).astype(jnp.int8)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = ok

    @pl.when(j > 0)
    def _merge():
        out_ref[...] = jnp.logical_and(out_ref[...] > 0, ok > 0).astype(jnp.int8)


def range_scan_vertical(
    data_cm: jax.Array,
    dim_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Partial-match vertical scan: touch only the queried dimensions' columns.

    Args:
      data_cm: (m_pad, n_pad) columnar data.
      dim_ids: (n_qdims,) int32 ids of the queried dimensions.
      lower, upper: (m_pad, 1) finite bounds (indexed by dim_ids in-kernel).

    Returns:
      (n_pad,) int8 match mask over the queried dimensions only.
    """
    m_pad, n_pad = data_cm.shape
    n_qdims = dim_ids.shape[0]
    assert n_pad % tile_n == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // tile_n, n_qdims),
        in_specs=[
            pl.BlockSpec((m_pad, 1), lambda i, j, ids: (0, 0)),
            pl.BlockSpec((m_pad, 1), lambda i, j, ids: (0, 0)),
            pl.BlockSpec((1, tile_n), lambda i, j, ids: (ids[j], i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, j, ids: (0, i)),
    )
    out = pl.pallas_call(
        _vertical_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.int8),
        interpret=interpret,
    )(
        dim_ids.astype(jnp.int32),
        lower.astype(data_cm.dtype),
        upper.astype(data_cm.dtype),
        data_cm,
    )
    return out[0]


def _rows_kernel(lower_ref, upper_ref, data_ref, out_ref):
    """Row-major (horizontal-layout) tile: lanes run over dimensions."""
    x = data_ref[...]  # (TR, m_pad)
    lo = lower_ref[...]  # (1, m_pad)
    up = upper_ref[...]
    ok = jnp.logical_and(x >= lo, x <= up)
    out_ref[...] = jnp.all(ok, axis=1, keepdims=True).astype(jnp.int8)


def range_scan_rows(
    data_rm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Row-major scan (the paper's horizontal layout, §3.1/§5.4).

    Exists for the layout ablation (Fig. 4): lane-axis = dimensions wastes
    128-m lanes for small m and forces a cross-lane reduction, which is why
    the columnar layout is the TPU-canonical one.

    Args:
      data_rm: (n_pad, m_pad) row-major data, n_pad % tile_rows == 0.
      lower, upper: (1, m_pad) finite bounds.

    Returns:
      (n_pad,) int8 match mask.
    """
    n_pad, m_pad = data_rm.shape
    assert n_pad % tile_rows == 0

    grid = (n_pad // tile_rows,)
    out = pl.pallas_call(
        _rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((tile_rows, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.int8),
        interpret=interpret,
    )(lower.astype(data_rm.dtype), upper.astype(data_rm.dtype), data_rm)
    return out[:, 0]


def _visit_kernel(ids_ref, lower_ref, upper_ref, data_ref, out_ref):
    """Scan the tile selected by the prefetched block-id list."""
    x = data_ref[...]
    lo = lower_ref[...]
    up = upper_ref[...]
    ok = jnp.logical_and(x >= lo, x <= up)
    out_ref[...] = jnp.all(ok, axis=0, keepdims=True).astype(jnp.int8)


def range_scan_visit(
    data_cm: jax.Array,
    block_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Two-phase scan: visit only the listed (m_pad, tile_n) blocks.

    Args:
      data_cm: (m_pad, n_pad) columnar data, n_pad % tile_n == 0.
      block_ids: (n_visit,) int32 tile indices into [0, n_pad / tile_n); padding
        entries are negative (clamped to 0; callers drop their output rows).
      lower, upper: (m_pad, 1) finite bounds.

    Returns:
      (n_visit, tile_n) int8 per-visit masks.
    """
    m_pad, n_pad = data_cm.shape
    n_visit = block_ids.shape[0]
    assert m_pad % SUBLANES == 0 and n_pad % tile_n == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_visit,),
        in_specs=[
            pl.BlockSpec((m_pad, 1), lambda i, ids: (0, 0)),
            pl.BlockSpec((m_pad, 1), lambda i, ids: (0, 0)),
            pl.BlockSpec((m_pad, tile_n), lambda i, ids: (0, jnp.maximum(ids[i], 0))),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, ids: (i, 0)),
    )
    out = pl.pallas_call(
        _visit_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_visit, tile_n), jnp.int8),
        interpret=interpret,
    )(
        block_ids.astype(jnp.int32),
        lower.astype(data_cm.dtype),
        upper.astype(data_cm.dtype),
        data_cm,
    )
    return out
