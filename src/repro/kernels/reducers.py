"""Pallas TPU kernels: batched masked reducers over (Q, n) match masks.

The ResultSpec layer (``core.types``, DESIGN.md §9) pushes result reduction
onto the device: instead of shipping a (Q, n) match mask to the host and
materializing ids there, a spec's reducer turns the mask into its payload —
top-k values/positions, an aggregate, a count — *inside the same jit* as the
kernel that produced the mask, so only O(Q·k) / O(Q) bytes ever cross the
device->host boundary.

Two Pallas kernels, both on the fused-batch grid ``(n_tiles, Q)`` family the
multi-query scans use (query axis innermost, so the streamed values tile is
fetched from HBM once per batch):

  * ``masked_fill_tiles`` — elementwise select: matching lanes keep the
    attribute value, non-matching lanes take the reduction identity. The
    filled (Q, n_pad) array feeds ``jax.lax.top_k`` in the same jit — the
    TPU-native way to run a batched masked top-k (sorting networks inside a
    Mosaic kernel are not a win over XLA's top_k).
  * ``masked_agg_tiles`` — lane-parallel accumulation: grid ``(Q, n_tiles)``
    with the tile axis innermost revisits one (1, tile_n) accumulator block
    per query (init at tile 0, combine after — the ``multi_scan_vertical``
    in-place-merge idiom), leaving a (Q, tile_n) lane partial whose final
    cross-lane reduce rides in the wrapping jit.

The jnp ``visit_*`` reducers cover the two-phase paths' (V, tile_n) visit
masks (segment reductions by query id). XLA oracles live in ``ref.py``;
the counted public entry points (``multi_scan_reduce`` & co.) in ``ops.py``
compose mask kernel + reducer into one launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.range_scan import DEFAULT_TILE_N, LANES, SUBLANES  # noqa: F401

# Reduction identities, keyed by agg op.
AGG_FILL = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}
_AGG_COMBINE = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}
_AGG_FINAL = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}


def _masked_fill_kernel(mask_ref, val_ref, out_ref, *, fill):
    """Matching lanes keep the value; the rest take the identity ``fill``."""
    out_ref[...] = jnp.where(mask_ref[...] != 0, val_ref[...],
                             jnp.float32(fill))


def masked_fill_tiles(
    masks: jax.Array,
    values: jax.Array,
    fill: float,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Batched masked fill (the top-k front half).

    Args:
      masks: (Q, n_pad) int8 match masks, n_pad % tile_n == 0.
      values: (n_pad,) attribute values (one dataset row, storage order).
      fill: value for non-matching lanes (the reduction identity).

    Returns:
      (Q, n_pad) float32 filled values.
    """
    q_n, n_pad = masks.shape
    assert n_pad % tile_n == 0 and tile_n % LANES == 0, (n_pad, tile_n)
    assert values.shape == (n_pad,), values.shape

    # Query axis innermost: the values tile's index map is constant across q,
    # so each (1, tile_n) HBM tile is fetched once per batch.
    grid = (n_pad // tile_n, q_n)
    return pl.pallas_call(
        functools.partial(_masked_fill_kernel, fill=float(fill)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i, q: (q, i)),
            pl.BlockSpec((1, tile_n), lambda i, q: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, q: (q, i)),
        out_shape=jax.ShapeDtypeStruct((q_n, n_pad), jnp.float32),
        interpret=interpret,
    )(masks, values.astype(jnp.float32).reshape(1, n_pad))


def _masked_agg_kernel(mask_ref, val_ref, out_ref, *, op, fill):
    """Accumulate one masked tile into the query's (1, tile_n) lane partial."""
    i = pl.program_id(1)
    part = jnp.where(mask_ref[...] != 0, val_ref[...], jnp.float32(fill))

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _combine():
        out_ref[...] = _AGG_COMBINE[op](out_ref[...], part)


def masked_agg_tiles(
    masks: jax.Array,
    values: jax.Array,
    op: str,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Batched masked aggregate, reduced to per-query lane partials.

    Args:
      masks: (Q, n_pad) int8 match masks.
      values: (n_pad,) attribute values.
      op: "sum" | "min" | "max".

    Returns:
      (Q, tile_n) float32 lane partials — the caller's final cross-lane
      ``sum/min/max(axis=-1)`` produces the (Q,) aggregates.
    """
    q_n, n_pad = masks.shape
    assert n_pad % tile_n == 0 and tile_n % LANES == 0, (n_pad, tile_n)
    assert values.shape == (n_pad,), values.shape
    fill = AGG_FILL[op]

    # Tile axis innermost: each query's (1, tile_n) accumulator block is
    # revisited on consecutive grid steps (the in-place merge idiom of
    # ``multi_scan_vertical``), so the output flushes once per query.
    grid = (q_n, n_pad // tile_n)
    return pl.pallas_call(
        functools.partial(_masked_agg_kernel, op=op, fill=fill),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda q, i: (q, i)),
            pl.BlockSpec((1, tile_n), lambda q, i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda q, i: (q, 0)),
        out_shape=jax.ShapeDtypeStruct((q_n, tile_n), jnp.float32),
        interpret=interpret,
    )(masks, values.astype(jnp.float32).reshape(1, n_pad))


# -- backend-dispatched reducers (called inside the counted ops' jits) --------

def masked_topk(masks, values, k: int, largest: bool, *, tile_n: int,
                interpret: bool):
    """(Q, n_pad) masks + (n_pad,) values -> ((Q,k) vals, (Q,k) idx, (Q,) counts).

    Matching lanes keep their value (Pallas fill kernel or the XLA ref, per
    backend), a device ``top_k`` selects the k extremes, and the per-query
    match count rides along so the host finalizer can truncate queries with
    fewer than k matches. Positions are storage-order column indices (the
    caller maps them through a permutation where one exists). Ties order by
    ascending position — XLA top_k semantics.
    """
    from repro.kernels import ops as _ops
    from repro.kernels import ref as _ref

    fill = -jnp.inf if largest else jnp.inf
    if _ops.use_xla():
        filled = _ref.masked_fill_ref(masks, values, fill)
    else:
        filled = masked_fill_tiles(masks, values, float(fill), tile_n=tile_n,
                                   interpret=interpret)
    key = filled if largest else -filled
    kk = min(int(k), key.shape[-1])
    v, idx = jax.lax.top_k(key, kk)
    counts = jnp.sum(masks != 0, axis=-1).astype(jnp.int32)
    return (v if largest else -v), idx.astype(jnp.int32), counts


def masked_agg(masks, values, op: str, *, tile_n: int, interpret: bool):
    """(Q, n_pad) masks + (n_pad,) values -> ((Q,) aggregates, (Q,) counts).

    Empty matches produce the reduction identity; the host finalizer turns
    them into 0.0 (sum) / NaN (min, max) using the count.
    """
    from repro.kernels import ops as _ops
    from repro.kernels import ref as _ref

    if _ops.use_xla():
        agg = _ref.masked_agg_ref(masks, values, op)
    else:
        lanes = masked_agg_tiles(masks, values, op, tile_n=tile_n,
                                 interpret=interpret)
        agg = _AGG_FINAL[op](lanes, axis=-1)
    counts = jnp.sum(masks != 0, axis=-1).astype(jnp.int32)
    return agg, counts


# -- tombstone folds (mutable data plane, DESIGN.md §11) ----------------------

def fold_tombstones(masks, tomb):
    """AND tombstone flags into match masks: a tombstoned object never matches.

    ``tomb`` is int8 (1 = dead) and broadcasts against ``masks`` — (n_pad,)
    against the (Q, n_pad) scan masks, or a pre-gathered (V, tile_n) block
    against the visit masks. Runs inside the fused reduce jits, before the
    spec's reducer, so every payload shape (counts, top-k, aggregates) sees
    tombstones folded at zero extra launches.
    """
    return masks * (tomb == 0).astype(masks.dtype)


def gather_tomb_blocks(tomb, bids, tile_n: int):
    """(V, tile_n) tombstone flags of the visited blocks (padding visits ->
    block 0; harmless — downstream reducers mask them via ``valid``)."""
    return tomb.reshape(-1, tile_n)[jnp.maximum(bids, 0)]


# -- visit-shaped reducers (two-phase paths; plain jnp segment reductions) ----

def gather_visit_values(data_cm, dim: int, bids, tile_n: int):
    """(V, tile_n) attribute values of the visited blocks (padding -> block 0,
    masked out downstream via ``valid``)."""
    n_blocks = data_cm.shape[1] // tile_n
    blocks = data_cm[dim].reshape(n_blocks, tile_n)
    return blocks[jnp.maximum(bids, 0)]


def visit_mask_counts(masks, qids, valid, n_queries: int):
    """(V, tile_n) visit masks -> (n_queries,) per-query match counts."""
    per_visit = jnp.sum(masks != 0, axis=-1).astype(jnp.int32) * valid
    return jnp.zeros((n_queries,), jnp.int32).at[qids].add(per_visit)


def visit_agg(masks, vblocks, qids, valid, op: str, n_queries: int):
    """Segment-aggregate visit masks by query id -> (n_queries,) float32."""
    fill = jnp.float32(AGG_FILL[op])
    live = jnp.logical_and(masks != 0, valid[:, None] > 0)
    filled = jnp.where(live, vblocks.astype(jnp.float32), fill)
    per_visit = _AGG_FINAL[op](filled, axis=-1)  # (V,)
    init = jnp.full((n_queries,), fill, jnp.float32)
    if op == "sum":
        return init.at[qids].add(per_visit)
    if op == "min":
        return init.at[qids].min(per_visit)
    return init.at[qids].max(per_visit)


def visit_topk(masks, vblocks, bids, valid, visit_index, k: int,
               largest: bool, tile_n: int):
    """Per-query top-k over scattered visit masks, in two stages.

    Stage 1 reduces each (1, tile_n) visit row to its own top-k' partial
    (k' = min(k, tile_n)) plus the matching storage positions. Stage 2
    gathers the partials through ``visit_index`` — the host-built
    (n_queries, M) table of padded-visit row indices per query (M =
    pow2-padded max visits of any query; empty slots point one past the
    last row) — into (Q, M·k') and re-selects the global top-k per query.
    The per-visit pre-reduction keeps the dense gather at Q·M·k' elements
    (vs Q·M·tile_n for a direct gather), so one broad query visiting every
    block costs ~k/tile_n of the naive memory, not a device OOM.

    Returns ((Q, k'') values, (Q, k'') int32 positions), k'' = min(k, M·k').
    """
    fill = jnp.float32(-jnp.inf if largest else jnp.inf)
    live = jnp.logical_and(masks != 0, valid[:, None] > 0)
    key = jnp.where(live, vblocks.astype(jnp.float32), fill)     # (V, t)
    if not largest:
        key = -key
    k1 = min(int(k), tile_n)
    v1, off1 = jax.lax.top_k(key, k1)                            # (V, k1)
    pos1 = jnp.maximum(bids, 0)[:, None] * tile_n + off1         # (V, k1)
    pad_v = jnp.full((1, k1), -jnp.inf, jnp.float32)             # key space
    pad_p = jnp.zeros((1, k1), pos1.dtype)
    g_v = jnp.concatenate([v1, pad_v], axis=0)[visit_index]      # (Q, M, k1)
    g_p = jnp.concatenate([pos1, pad_p], axis=0)[visit_index]
    q_n, m_vis, _ = g_v.shape
    k2 = min(int(k), m_vis * k1)
    v2, j = jax.lax.top_k(g_v.reshape(q_n, m_vis * k1), k2)      # (Q, k2)
    pos = jnp.take_along_axis(g_p.reshape(q_n, m_vis * k1), j, axis=1)
    return (v2 if largest else -v2), pos.astype(jnp.int32)
