"""Pallas TPU kernel: block-visit decode attention (zone-map-pruned KV).

§Perf cell 3 showed that XLA cannot keep a pruned-KV gather shard-local: the
`take_along_axis` over the block axis lowers to cross-device all-gathers and
the gather's HLO cost counts the full cache operand. This kernel is the
TPU-native fix — the same scalar-prefetch visit-list idiom as
``range_scan_visit`` (the MDRQ engine's two-phase refine), applied to
attention:

  * the host (or a tiny jnp prune pass over the zone maps) produces a per
    (batch, kv-head) list of key-block ids to visit;
  * the grid is (B, KV, n_visit) — each step DMAs exactly ONE (bs, hd) key
    block and value block selected by the prefetched id; unselected blocks
    are never touched;
  * softmax is streamed across visits (running max / denominator / weighted
    accumulator in VMEM scratch), so no (S,) score row ever materializes.

Cache layout is block-major ``(B, KV, nb, bs, hd)`` — the layout a pruned
production cache would use natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import numerics

F32 = jnp.float32
NEG = numerics.mask_fill(jnp.bfloat16)  # finite under every score dtype


def _kernel(ids_ref, pos_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *, bs: int, scale: float):
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    n_visit = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(F32)            # (G, hd)
    k = k_ref[0, 0, 0].astype(F32)         # (bs, hd)
    v = v_ref[0, 0, 0].astype(F32)

    blk = ids_ref[b, h, j]
    slots = blk * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    # padding entries are -1 (index_map clamps the DMA to block 0; the mask
    # kills the contribution so nothing is double-counted)
    valid = (slots <= pos_ref[b]) & (blk >= 0)  # (1, bs)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bs)
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[...]                    # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                 # (G, bs)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))    # (G, hd)
    m_ref[...] = m_new

    @pl.when(j == n_visit - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = (acc_ref[...] / denom).astype(out_ref.dtype)


def kv_visit_attention(
    q: jax.Array,           # (B, KV, G, hd) grouped query for one token
    k_blocks: jax.Array,    # (B, KV, nb, bs, hd)
    v_blocks: jax.Array,    # (B, KV, nb, bs, hd)
    block_ids: jax.Array,   # (B, KV, n_visit) int32 (may repeat; host-dedup)
    pos: jax.Array,         # (B,) int32 current decode positions
    *,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over only the listed key blocks -> (B, KV, G, hd)."""
    b, kv, g, hd = q.shape
    nb, bs = k_blocks.shape[2], k_blocks.shape[3]
    n_visit = block_ids.shape[-1]
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, n_visit),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi, j, ids, pos: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1, bs, hd),
                         lambda bi, hi, j, ids, pos: (bi, hi, jnp.maximum(ids[bi, hi, j], 0), 0, 0)),
            pl.BlockSpec((1, 1, 1, bs, hd),
                         lambda bi, hi, j, ids, pos: (bi, hi, jnp.maximum(ids[bi, hi, j], 0), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, hi, j, ids, pos: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), F32),
            pltpu.VMEM((g, 1), F32),
            pltpu.VMEM((g, hd), F32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_ids.astype(jnp.int32), pos.astype(jnp.int32), q, k_blocks, v_blocks)
