"""Pallas TPU kernels: fused multi-query (batched) range scans.

Batched execution — the inter-query-parallelism counterpart of the paper's
intra-query parallel scans (§5): analytical MDRQ workloads are *streams* of
queries (GMRQB issues eight templates concurrently, §6), and a single-query
launch pays the full dispatch + host-sync tax per query. These kernels
evaluate a (Q, m) batch of query boxes against the (m, n) columnar dataset in
one launch, so the fixed overheads amortize over Q and — crucially — each
VMEM data tile is fetched from HBM *once* and reused for all Q queries (the
query axis is the innermost grid dimension, so the data block index map is
constant across it and Pallas skips the re-fetch).

Three variants, mirroring the single-query entry points in ``range_scan``:

  * ``multi_scan_tiles``    — fused full scan: grid ``(n_tiles, Q)`` writing a
    (Q, n_pad) int8 mask; per-tile HBM traffic is paid once per *batch*.
  * ``multi_scan_vertical`` — batched partial-match scan: grid
    ``(n_tiles, Q, D_max)`` touching only each query's constrained dimensions
    (padded dim lists repeat a query's own dims — AND is idempotent).
  * ``multi_scan_visit``    — batched two-phase refinement: a flattened
    (query_id, block_id) visit list drives scattered tile scans for *all*
    queries of a batch in one launch (kd-tree / R*-tree / VA-file phase 2).

Query bounds are laid out **query-minor**: ``lower``/``upper`` are
``(m_pad, Q)`` with one column per query, so a (m_pad, 1) bounds block is the
same shape the single-query kernels use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.range_scan import DEFAULT_TILE_N, LANES, SUBLANES


def _multi_scan_kernel(lower_ref, upper_ref, data_ref, out_ref):
    """Compare one (m_pad, TN) data tile against one query's bounds column."""
    x = data_ref[...]
    lo = lower_ref[...]  # (m_pad, 1), broadcasts over lanes
    up = upper_ref[...]
    ok = jnp.logical_and(x >= lo, x <= up)
    out_ref[...] = jnp.all(ok, axis=0, keepdims=True).astype(jnp.int8)


def multi_scan_tiles(
    data_cm: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Fused full scan of a query batch.

    Args:
      data_cm: (m_pad, n_pad) columnar data; m_pad % 8 == 0, n_pad % tile_n == 0.
      lower, upper: (m_pad, Q) finite bounds, one column per query.

    Returns:
      (Q, n_pad) int8 match masks, row q = query q.
    """
    m_pad, n_pad = data_cm.shape
    q_n = lower.shape[1]
    assert m_pad % SUBLANES == 0, m_pad
    assert n_pad % tile_n == 0 and tile_n % LANES == 0, (n_pad, tile_n)
    assert lower.shape == (m_pad, q_n) and upper.shape == (m_pad, q_n)

    # Query axis innermost: the data block index map is constant across q, so
    # each (m_pad, tile_n) tile is fetched once per batch, not once per query.
    grid = (n_pad // tile_n, q_n)
    out = pl.pallas_call(
        _multi_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_pad, 1), lambda i, q: (0, q)),
            pl.BlockSpec((m_pad, 1), lambda i, q: (0, q)),
            pl.BlockSpec((m_pad, tile_n), lambda i, q: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, q: (q, i)),
        out_shape=jax.ShapeDtypeStruct((q_n, n_pad), jnp.int8),
        interpret=interpret,
    )(lower.astype(data_cm.dtype), upper.astype(data_cm.dtype), data_cm)
    return out


def _multi_vertical_kernel(dim_ids_ref, lower_ref, upper_ref, data_ref, out_ref):
    """One grid step = (tile, query, queried-dim); AND-merge in place over j."""
    q = pl.program_id(1)
    j = pl.program_id(2)
    d = dim_ids_ref[q, j]
    x = data_ref[...]  # (1, TN) — only the queried dimension's row is fetched
    lo = lower_ref[d, 0]
    up = upper_ref[d, 0]
    ok = jnp.logical_and(x >= lo, x <= up).astype(jnp.int8)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = ok

    @pl.when(j > 0)
    def _merge():
        out_ref[...] = jnp.logical_and(out_ref[...] > 0, ok > 0).astype(jnp.int8)


def multi_scan_vertical(
    data_cm: jax.Array,
    dim_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Batched partial-match vertical scan.

    Args:
      data_cm: (m_pad, n_pad) columnar data.
      dim_ids: (Q, D_max) int32 per-query constrained-dimension ids. Rows with
        fewer than D_max constrained dims must pad by *repeating* one of the
        query's own dims (AND is idempotent); a match-all query uses dim 0,
        whose bounds column carries dtype extrema and accepts everything.
      lower, upper: (m_pad, Q) finite bounds (indexed by dim_ids in-kernel).

    Returns:
      (Q, n_pad) int8 match masks over each query's constrained dims.
    """
    m_pad, n_pad = data_cm.shape
    q_n, d_max = dim_ids.shape
    assert n_pad % tile_n == 0
    assert lower.shape == (m_pad, q_n) and upper.shape == (m_pad, q_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // tile_n, q_n, d_max),
        in_specs=[
            pl.BlockSpec((m_pad, 1), lambda i, q, j, ids: (0, q)),
            pl.BlockSpec((m_pad, 1), lambda i, q, j, ids: (0, q)),
            pl.BlockSpec((1, tile_n), lambda i, q, j, ids: (ids[q, j], i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, q, j, ids: (q, i)),
    )
    out = pl.pallas_call(
        _multi_vertical_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q_n, n_pad), jnp.int8),
        interpret=interpret,
    )(
        dim_ids.astype(jnp.int32),
        lower.astype(data_cm.dtype),
        upper.astype(data_cm.dtype),
        data_cm,
    )
    return out


def _multi_visit_kernel(qids_ref, bids_ref, lower_ref, upper_ref, data_ref, out_ref):
    """Scan the tile selected by the flattened (query, block) visit list."""
    x = data_ref[...]
    lo = lower_ref[...]  # (m_pad, 1) — the visiting query's bounds column
    up = upper_ref[...]
    ok = jnp.logical_and(x >= lo, x <= up)
    out_ref[...] = jnp.all(ok, axis=0, keepdims=True).astype(jnp.int8)


def multi_scan_visit(
    data_cm: jax.Array,
    query_ids: jax.Array,
    block_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Batched two-phase refinement: visit each (query, block) pair once.

    Args:
      data_cm: (m_pad, n_pad) columnar data, n_pad % tile_n == 0.
      query_ids: (V,) int32 — which query's bounds each visit uses.
      block_ids: (V,) int32 tile indices; padding entries are negative
        (clamped to 0; callers drop their output rows).
      lower, upper: (m_pad, Q) finite bounds, one column per query.

    Returns:
      (V, tile_n) int8 per-visit masks.
    """
    m_pad, n_pad = data_cm.shape
    n_visit = block_ids.shape[0]
    assert query_ids.shape == (n_visit,)
    assert m_pad % SUBLANES == 0 and n_pad % tile_n == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_visit,),
        in_specs=[
            pl.BlockSpec((m_pad, 1), lambda i, qids, bids: (0, qids[i])),
            pl.BlockSpec((m_pad, 1), lambda i, qids, bids: (0, qids[i])),
            pl.BlockSpec(
                (m_pad, tile_n), lambda i, qids, bids: (0, jnp.maximum(bids[i], 0))
            ),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, qids, bids: (i, 0)),
    )
    out = pl.pallas_call(
        _multi_visit_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_visit, tile_n), jnp.int8),
        interpret=interpret,
    )(
        query_ids.astype(jnp.int32),
        block_ids.astype(jnp.int32),
        lower.astype(data_cm.dtype),
        upper.astype(data_cm.dtype),
        data_cm,
    )
    return out
