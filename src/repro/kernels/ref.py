"""Pure-jnp oracles for the MDRQ Pallas kernels.

Each function is the semantic ground truth the kernels are validated against
(tests sweep shapes and dtypes with ``assert_allclose`` / exact equality — the
outputs are discrete masks, so equality is exact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import numerics
from repro.kernels.va_filter import BITS_PER_DIM, CODE_MASK, DIMS_PER_WORD


def range_scan_ref(data_cm: jax.Array, lower: jax.Array, upper: jax.Array) -> jax.Array:
    """Oracle for the columnar range-scan kernel.

    Args:
      data_cm: (m, n) columnar data, any float dtype.
      lower, upper: (m,) or (m, 1) query bounds (same dtype as data after cast).

    Returns:
      (n,) int8 mask — 1 where ``all_j lower_j <= x_ji <= upper_j``.
    """
    lo = lower.reshape(-1, 1).astype(data_cm.dtype)
    up = upper.reshape(-1, 1).astype(data_cm.dtype)
    ok = jnp.logical_and(data_cm >= lo, data_cm <= up)
    return jnp.all(ok, axis=0).astype(jnp.int8)


def range_scan_blocks_ref(
    data_blocks: jax.Array, block_ids: jax.Array, lower: jax.Array, upper: jax.Array
) -> jax.Array:
    """Oracle for the block-visit range scan (two-phase tree/VA refinement).

    Args:
      data_blocks: (n_blocks, m, tn) columnar leaf blocks.
      block_ids: (n_visit,) int32 ids of blocks to scan (may repeat; negative
        ids are treated as padding and clamped to 0 — callers drop those rows).
      lower, upper: (m,) bounds.

    Returns:
      (n_visit, tn) int8 per-visit masks.
    """
    ids = jnp.maximum(block_ids, 0)
    blocks = data_blocks[ids]  # (v, m, tn)
    lo = lower.reshape(1, -1, 1).astype(data_blocks.dtype)
    up = upper.reshape(1, -1, 1).astype(data_blocks.dtype)
    ok = jnp.logical_and(blocks >= lo, blocks <= up)
    return jnp.all(ok, axis=1).astype(jnp.int8)


def multi_scan_ref(data_cm: jax.Array, lower: jax.Array, upper: jax.Array) -> jax.Array:
    """Oracle for the fused multi-query full scan.

    Args:
      data_cm: (m, n) columnar data.
      lower, upper: (m, Q) per-query bounds, one column per query.

    Returns:
      (Q, n) int8 masks — row q is query q's match mask.
    """
    # Per-dimension accumulation: one (Q, n) sweep per dim instead of a
    # (Q, m, n) broadcast — ~9x faster on CPU XLA (no giant intermediate)
    # and the same merge order the Pallas vertical kernel uses.
    lo = lower.T.astype(data_cm.dtype)  # (Q, m)
    up = upper.T.astype(data_cm.dtype)
    acc = None
    for j in range(data_cm.shape[0]):
        row = data_cm[j][None, :]  # (1, n)
        ok = jnp.logical_and(row >= lo[:, j, None], row <= up[:, j, None])
        acc = ok if acc is None else jnp.logical_and(acc, ok)
    return acc.astype(jnp.int8)


def multi_scan_vertical_ref(
    data_cm: jax.Array, dim_ids: jax.Array, lower: jax.Array, upper: jax.Array
) -> jax.Array:
    """Oracle for the batched vertical (partial-match) scan.

    Args:
      data_cm: (m, n) columnar data.
      dim_ids: (Q, D_max) per-query constrained-dim ids (padding repeats a
        valid dim of the same query — AND is idempotent).
      lower, upper: (m, Q) per-query bounds.

    Returns:
      (Q, n) int8 masks over each query's constrained dims.
    """
    lo_t = lower.T.astype(data_cm.dtype)  # (Q, m)
    up_t = upper.T.astype(data_cm.dtype)
    acc = None
    for j in range(dim_ids.shape[1]):
        d = dim_ids[:, j]            # (Q,)
        rows = data_cm[d]            # (Q, n) — one constrained dim per query
        lo = jnp.take_along_axis(lo_t, d[:, None], axis=1)  # (Q, 1)
        up = jnp.take_along_axis(up_t, d[:, None], axis=1)
        ok = jnp.logical_and(rows >= lo, rows <= up)
        acc = ok if acc is None else jnp.logical_and(acc, ok)
    return acc.astype(jnp.int8)


def multi_scan_blocks_ref(
    data_blocks: jax.Array,
    query_ids: jax.Array,
    block_ids: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
) -> jax.Array:
    """Oracle for the batched block-visit scan.

    Args:
      data_blocks: (n_blocks, m, tn) columnar leaf blocks.
      query_ids: (V,) int32 — which query's bounds each visit uses.
      block_ids: (V,) int32 block ids (negative = padding, clamped to 0).
      lower, upper: (m, Q) per-query bounds.

    Returns:
      (V, tn) int8 per-visit masks.
    """
    blocks = data_blocks[jnp.maximum(block_ids, 0)]  # (V, m, tn)
    lo = lower.T[query_ids].astype(data_blocks.dtype)  # (V, m)
    up = upper.T[query_ids].astype(data_blocks.dtype)
    acc = None
    for j in range(data_blocks.shape[1]):
        ok = jnp.logical_and(blocks[:, j, :] >= lo[:, j, None],
                             blocks[:, j, :] <= up[:, j, None])
        acc = ok if acc is None else jnp.logical_and(acc, ok)
    return acc.astype(jnp.int8)


def kv_visit_attention_ref(
    q: jax.Array, k_blocks: jax.Array, v_blocks: jax.Array,
    block_ids: jax.Array, pos: jax.Array,
) -> jax.Array:
    """Oracle for the block-visit decode attention kernel.

    q: (B, KV, G, hd); k/v_blocks: (B, KV, nb, bs, hd);
    block_ids: (B, KV, n_visit) (-1 = padding); pos: (B,).
    Returns (B, KV, G, hd).
    """
    b, kv, g, hd = q.shape
    nb, bs = k_blocks.shape[2], k_blocks.shape[3]
    ids = jnp.maximum(block_ids, 0)
    k_sel = jnp.take_along_axis(k_blocks, ids[..., None, None], axis=2)
    v_sel = jnp.take_along_axis(v_blocks, ids[..., None, None], axis=2)
    slots = ids[..., None] * bs + jnp.arange(bs)[None, None, None, :]
    valid = (slots <= pos[:, None, None, None]) & (block_ids[..., None] >= 0)
    s = jnp.einsum("bkgh,bkjth->bkgjt", q.astype(jnp.float32),
                   k_sel.astype(jnp.float32)) * (hd ** -0.5)
    s = jnp.where(valid[:, :, None, :, :], s,
                  numerics.mask_fill(jnp.bfloat16))
    nv = block_ids.shape[-1]
    s = s.reshape(b, kv, g, nv * bs)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bkth->bkgh", w,
                     v_sel.astype(jnp.float32).reshape(b, kv, nv * bs, hd))
    return out.astype(q.dtype)


def masked_fill_ref(masks: jax.Array, values: jax.Array, fill) -> jax.Array:
    """Oracle for the batched masked fill (the top-k front half).

    Args:
      masks: (Q, n) int8 match masks.
      values: (n,) attribute values (one dataset row).
      fill: reduction identity for non-matching lanes.

    Returns:
      (Q, n) float32 — value where the mask is set, ``fill`` elsewhere.
    """
    return jnp.where(masks != 0, values[None, :].astype(jnp.float32),
                     jnp.float32(fill))


def masked_agg_ref(masks: jax.Array, values: jax.Array, op: str) -> jax.Array:
    """Oracle for the batched masked aggregate.

    Args:
      masks: (Q, n) int8 match masks.
      values: (n,) attribute values.
      op: "sum" | "min" | "max".

    Returns:
      (Q,) float32 aggregates (reduction identity where nothing matches).
    """
    from repro.kernels.reducers import AGG_FILL
    filled = masked_fill_ref(masks, values, AGG_FILL[op])
    red = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[op]
    return red(filled, axis=-1)


def va_filter_ref(codes: jax.Array, cell_lo: jax.Array, cell_hi: jax.Array) -> jax.Array:
    """Oracle for the VA-file approximation filter on *unpacked* codes.

    Args:
      codes: (m, n) integer cell codes in [0, 3] (2 bits/dim, paper §2.2.3).
      cell_lo, cell_hi: (m,) int32 query cell bounds per dimension.

    Returns:
      (n,) int8 candidate mask — 1 where every dim's code intersects the query.
    """
    lo = cell_lo.reshape(-1, 1).astype(codes.dtype)
    hi = cell_hi.reshape(-1, 1).astype(codes.dtype)
    ok = jnp.logical_and(codes >= lo, codes <= hi)
    return jnp.all(ok, axis=0).astype(jnp.int8)


def va_filter_packed_ref(
    packed: jax.Array, cell_lo: jax.Array, cell_hi: jax.Array, m: int
) -> jax.Array:
    """Oracle for the packed VA filter: unpack 16 2-bit fields per int32 word.

    Args:
      packed: (w, n) int32, word w holds dims [16w, 16w+16) in 2-bit fields.
      cell_lo, cell_hi: (m,) int32 query cell bounds.
      m: true number of dimensions (w = ceil(m / 16)).
    """
    w, n = packed.shape
    acc = jnp.ones((n,), dtype=jnp.bool_)
    for wi in range(w):
        word = packed[wi]
        for k in range(DIMS_PER_WORD):
            d = wi * DIMS_PER_WORD + k
            if d >= m:
                break
            field = jnp.bitwise_and(jnp.right_shift(word, BITS_PER_DIM * k),
                                    CODE_MASK)
            acc = jnp.logical_and(
                acc, jnp.logical_and(field >= cell_lo[d], field <= cell_hi[d])
            )
    return acc.astype(jnp.int8)


def multi_va_filter_packed_ref(
    packed: jax.Array, cell_lo: jax.Array, cell_hi: jax.Array, m: int
) -> jax.Array:
    """Oracle for the batched packed VA filter: one unpack sweep, all queries.

    Args:
      packed: (w, n) int32, word w holds dims [16w, 16w+16) in 2-bit fields.
      cell_lo, cell_hi: (m_s, Q) int32 per-query cell bounds, query-minor
        (padded rows carry [0, 3] match-all bounds).
      m: true number of dimensions (w = ceil(m / 16)).

    Returns:
      (Q, n) int8 candidate masks, row q = query q.
    """
    w, n = packed.shape
    q_n = cell_lo.shape[1]
    acc = jnp.ones((q_n, n), dtype=jnp.bool_)
    for wi in range(w):
        word = packed[wi]  # (n,)
        for k in range(DIMS_PER_WORD):
            d = wi * DIMS_PER_WORD + k
            if d >= m:
                break
            field = jnp.bitwise_and(jnp.right_shift(word, BITS_PER_DIM * k),
                                    CODE_MASK)  # (n,)
            ok = jnp.logical_and(field[None, :] >= cell_lo[d, :, None],
                                 field[None, :] <= cell_hi[d, :, None])
            acc = jnp.logical_and(acc, ok)
    return acc.astype(jnp.int8)
