"""Pallas TPU kernel: VA-file approximation filter on packed 2-bit codes.

The paper's VA-file (§2.2.3, §5.3) quantizes every dimension to 2 bits and
scans the *approximations* first; only buckets whose approximation intersects
the approximated query are refined against the exact data. On TPU this is the
most natural of the three MDIS: the approximation scan is a branch-free packed
integer compare that is 16x denser than the float scan (16 dims per int32
word), converting the first phase from HBM-bandwidth-bound to nearly free.

Packing: word ``w`` of object ``i`` holds dims ``[16w, 16w+16)`` — dim
``16w + k`` occupies bits ``[2k, 2k+2)``. The kernel unpacks with static
shift/mask ops (VPU int32 lanes) and AND-reduces across dims in registers.

Two entry points:

  * ``va_filter_packed``       — single query: grid ``(n_tiles,)``.
  * ``multi_va_filter_packed`` — a whole query batch in one launch: grid
    ``(n_tiles, Q)`` with the query axis innermost, so the packed-word tile's
    block index map is constant across q and each (w, tile_n) tile streams
    from HBM once per *batch* — the same fusion ``multi_scan`` applies to the
    exact scans, here applied to the approximation phase.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_TILE_N = 2048
# The paper's static cell resolution (b_j = 2, §2.2.3). Everything downstream
# — word packing density, the planner's candidate-fraction slack and
# approximation byte count, ``vafile.CELLS`` — derives from this one constant
# so a resolution change cannot silently skew one layer against another.
BITS_PER_DIM = 2
CODE_MASK = (1 << BITS_PER_DIM) - 1
DIMS_PER_WORD = 32 // BITS_PER_DIM


def pack_codes(codes: np.ndarray) -> np.ndarray:
    """Pack (m, n) uint8 cell codes into (ceil(m/DIMS_PER_WORD), n) int32."""
    m, n = codes.shape
    w = -(-m // DIMS_PER_WORD)
    out = np.zeros((w, n), dtype=np.int32)
    for d in range(m):
        wi, k = divmod(d, DIMS_PER_WORD)
        out[wi] |= codes[d].astype(np.int32) << (BITS_PER_DIM * k)
    return out


def _va_kernel(qlo_ref, qhi_ref, packed_ref, out_ref, *, m: int):
    words = packed_ref[...]  # (w, tn) int32
    w = words.shape[0]
    acc = None
    for wi in range(w):
        word = words[wi]
        for k in range(DIMS_PER_WORD):
            d = wi * DIMS_PER_WORD + k
            if d >= m:
                break
            field = jnp.bitwise_and(jnp.right_shift(word, BITS_PER_DIM * k),
                                    CODE_MASK)
            ok = jnp.logical_and(field >= qlo_ref[d, 0], field <= qhi_ref[d, 0])
            acc = ok if acc is None else jnp.logical_and(acc, ok)
    out_ref[...] = acc[None, :].astype(jnp.int8)


def va_filter_packed(
    packed: jax.Array,
    cell_lo: jax.Array,
    cell_hi: jax.Array,
    m: int,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Candidate mask from packed approximations.

    Args:
      packed: (w, n_pad) int32 packed codes, n_pad % tile_n == 0.
      cell_lo, cell_hi: (m_s, 1) int32 query cell bounds, m_s >= m (padded rows
        carry [0, 3] match-all bounds and are skipped by the static loop bound).
      m: true dimensionality.

    Returns:
      (n_pad,) int8 candidate mask.
    """
    w, n_pad = packed.shape
    assert n_pad % tile_n == 0 and tile_n % LANES == 0
    m_s = cell_lo.shape[0]
    assert m_s >= m and cell_lo.shape == cell_hi.shape == (m_s, 1)

    grid = (n_pad // tile_n,)
    out = pl.pallas_call(
        functools.partial(_va_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_s, 1), lambda i: (0, 0)),
            pl.BlockSpec((m_s, 1), lambda i: (0, 0)),
            pl.BlockSpec((w, tile_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.int8),
        interpret=interpret,
    )(cell_lo.astype(jnp.int32), cell_hi.astype(jnp.int32), packed)
    return out[0]


def multi_va_filter_packed(
    packed: jax.Array,
    cell_lo: jax.Array,
    cell_hi: jax.Array,
    m: int,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """Candidate masks for a whole query batch from one launch.

    The kernel body is the single-query unpack-compare (``_va_kernel``); only
    the grid changes: ``(n_tiles, Q)`` with the query axis innermost, so the
    (w, tile_n) packed-word tile is fetched from HBM once per batch and
    compared against every query's cell bounds while resident in VMEM.

    Args:
      packed: (w, n_pad) int32 packed codes, n_pad % tile_n == 0.
      cell_lo, cell_hi: (m_s, Q) int32 per-query cell bounds, query-minor
        (one column per query, like the ``multi_scan`` bounds layout); padded
        rows carry [0, 3] match-all bounds.
      m: true dimensionality.

    Returns:
      (Q, n_pad) int8 candidate masks, row q = query q.
    """
    w, n_pad = packed.shape
    assert n_pad % tile_n == 0 and tile_n % LANES == 0
    m_s, q_n = cell_lo.shape
    assert m_s >= m and cell_lo.shape == cell_hi.shape == (m_s, q_n)

    grid = (n_pad // tile_n, q_n)
    out = pl.pallas_call(
        functools.partial(_va_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_s, 1), lambda i, q: (0, q)),
            pl.BlockSpec((m_s, 1), lambda i, q: (0, q)),
            pl.BlockSpec((w, tile_n), lambda i, q: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, q: (q, i)),
        out_shape=jax.ShapeDtypeStruct((q_n, n_pad), jnp.int8),
        interpret=interpret,
    )(cell_lo.astype(jnp.int32), cell_hi.astype(jnp.int32), packed)
    return out
