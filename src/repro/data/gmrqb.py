"""GMRQB — the Genomic Multidimensional Range Query Benchmark (paper §6).

The paper's benchmark: 10M genomic variation records with 19 attributes
derived from the 1000 Genomes Project, plus eight parameterized query
templates whose average selectivities span 10.76% down to 1e-7 (Table 1).

The original dataset is a 724 MB download that is not redistributable inside
this offline container, so ``build`` synthesizes a *shape-faithful* stand-in:
every attribute reproduces the published domain/cardinality structure
(chromosome 1–23, location up to 2.5e8 with variation-rich/poor regions,
hashed categoricals for population/family/sample, skewed quality/depth, beta-
distributed allele frequencies, …). Template instantiation follows §6.2: all
templates constrain the genomic position (chromosome + location); higher
templates add attributes until template 8 is a 19-dim complete-match query.
Achieved selectivities are *measured* by the benchmark harness and reported
next to Table 1's numbers rather than assumed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import types as T

ATTRS = [
    "chromosome",        # 0: 1..23
    "location",          # 1: 0..2.5e8, clustered (variation-rich regions)
    "quality",           # 2: 0..100 skewed high
    "depth",             # 3: 1..5000 log-normal-ish
    "reference_genome",  # 4: 3 distinct
    "variation_id",      # 5: ~unique
    "allele_freq",       # 6: beta(0.2, 2) in [0,1]
    "allele_count",      # 7: 1..5008
    "ref_base",          # 8: 4 distinct
    "alt_base",          # 9: 4 distinct
    "ancestral_allele",  # 10: 5 distinct
    "variant_type",      # 11: 6 distinct
    "sample_id",         # 12: 2504 distinct
    "gender",            # 13: 2 distinct
    "family_id",         # 14: ~1800 distinct
    "population",        # 15: 26 distinct
    "relationship",      # 16: 9 distinct
    "genotype",          # 17: 3 distinct
    "age",               # 18: 1..90 (patient metadata; §1 genomics use case)
]
M = len(ATTRS)
LOC_MAX = 2.5e8


def build(n: int, seed: int = 0) -> T.Dataset:
    rng = np.random.default_rng(seed)
    cols = np.empty((M, n), dtype=np.float32)
    cols[0] = rng.integers(1, 24, size=n)
    # variation-rich regions: mixture of uniform background + dense hotspots
    hot = rng.random(n) < 0.6
    centers = rng.choice(np.linspace(0.05, 0.95, 40), size=n) * LOC_MAX
    cols[1] = np.where(
        hot,
        np.clip(centers + rng.normal(0, LOC_MAX * 0.004, size=n), 0, LOC_MAX),
        rng.random(n) * LOC_MAX,
    )
    cols[2] = 100.0 * rng.beta(5.0, 1.5, size=n)
    cols[3] = np.minimum(5000, np.exp(rng.normal(3.5, 1.0, size=n))).astype(np.float32)
    cols[4] = rng.integers(0, 3, size=n)
    cols[5] = rng.permutation(n).astype(np.float32)
    cols[6] = rng.beta(0.2, 2.0, size=n)
    cols[7] = np.ceil(cols[6] * 5008.0) + 1.0
    cols[8] = rng.integers(0, 4, size=n)
    cols[9] = rng.integers(0, 4, size=n)
    cols[10] = rng.integers(0, 5, size=n)
    cols[11] = rng.integers(0, 6, size=n)
    cols[12] = rng.integers(0, 2504, size=n)
    cols[13] = rng.integers(0, 2, size=n)
    cols[14] = (cols[12] // 1.4).astype(np.float32)  # families group samples
    cols[15] = (cols[12] % 26).astype(np.float32)    # population from sample
    cols[16] = rng.integers(0, 9, size=n)
    cols[17] = rng.integers(0, 3, size=n)
    cols[18] = np.clip(rng.normal(45, 18, size=n), 1, 90)
    return T.Dataset(cols)


def _loc_range(rng: np.random.Generator, frac: float) -> tuple[float, float]:
    width = frac * LOC_MAX
    start = rng.random() * (LOC_MAX - width)
    return (start, start + width)


def template(k: int, rng: np.random.Generator, dataset: T.Dataset | None = None) -> T.RangeQuery:
    """Instantiate GMRQB query template k (1..8), paper §6.2 / Table 1.

    All templates constrain chromosome + location; higher templates add
    attributes. Template 8 is the complete-match query over all 19 dims
    (instantiated around a random record, selectivity ~ 1/n like the paper's
    1e-7).
    """
    chrom = float(rng.integers(1, 24))
    if k == 1:      # 2 dims, ~10%
        lo, hi = _loc_range(rng, 0.40)
        return T.RangeQuery.partial(M, {0: (chrom, min(23.0, chrom + 5)), 1: (lo, hi)})
    if k == 2:      # 5 dims, ~2%
        lo, hi = _loc_range(rng, 0.45)
        return T.RangeQuery.partial(M, {
            0: (chrom, min(23.0, chrom + 4)), 1: (lo, hi),
            2: (10.0, 100.0), 3: (10.0, 1000.0), 6: (0.03, 1.0),
        })
    if k == 3:      # 3 dims, ~5%
        lo, hi = _loc_range(rng, 0.35)
        return T.RangeQuery.partial(M, {
            0: (chrom, min(23.0, chrom + 4)), 1: (lo, hi), 2: (40.0, 100.0),
        })
    if k == 4:      # 4 dims, ~0.2%
        lo, hi = _loc_range(rng, 0.15)
        return T.RangeQuery.partial(M, {
            0: (chrom, chrom), 1: (lo, hi), 3: (10.0, 1000.0), 6: (0.05, 0.9),
        })
    if k == 5:      # 5 dims, ~0.2%
        lo, hi = _loc_range(rng, 0.25)
        return T.RangeQuery.partial(M, {
            0: (chrom, chrom), 1: (lo, hi), 2: (20.0, 95.0),
            13: (0.0, 0.0), 6: (0.01, 0.8),
        })
    if k == 6:      # 6 dims, ~0.1%
        lo, hi = _loc_range(rng, 0.3)
        pop = float(rng.integers(0, 26))
        return T.RangeQuery.partial(M, {
            0: (chrom, chrom), 1: (lo, hi), 2: (10.0, 100.0),
            15: (pop, pop + 3), 3: (5.0, 2000.0), 18: (20.0, 70.0),
        })
    if k == 7:      # 7 dims, ~0.05%
        lo, hi = _loc_range(rng, 0.35)
        gt = float(rng.integers(0, 3))
        return T.RangeQuery.partial(M, {
            0: (chrom, chrom), 1: (lo, hi), 2: (20.0, 100.0), 3: (10.0, 1500.0),
            6: (0.02, 0.95), 17: (gt, gt), 13: (1.0, 1.0),
        })
    if k == 8:      # 19 dims complete match, ~1e-7
        assert dataset is not None, "template 8 needs the dataset to center on"
        rec = dataset.cols[:, rng.integers(dataset.n)]
        lo = rec.copy()
        hi = rec.copy()
        lo[1] = max(0.0, rec[1] - 5e4)
        hi[1] = rec[1] + 5e4
        lo[2], hi[2] = max(0, rec[2] - 5), min(100, rec[2] + 5)
        lo[3], hi[3] = max(1, rec[3] * 0.5), rec[3] * 2.0
        lo[6], hi[6] = max(0, rec[6] - 0.05), min(1, rec[6] + 0.05)
        lo[18], hi[18] = max(1, rec[18] - 10), min(90, rec[18] + 10)
        lo[5], hi[5] = 0.0, float(dataset.n)  # variation_id: full range
        return T.RangeQuery.complete(lo, hi)
    raise ValueError(f"template k must be 1..8, got {k}")


def mixed_workload(
    dataset: T.Dataset, n_queries: int, seed: int = 0
) -> list[tuple[int, T.RangeQuery]]:
    """The paper's Mixed Workload: all templates randomly interleaved."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_queries):
        k = int(rng.integers(1, 9))
        out.append((k, template(k, rng, dataset)))
    return out


@dataclasses.dataclass
class Table1Row:
    template: int
    avg_selectivity: float
    std_selectivity: float
    avg_dims: float


PAPER_TABLE1 = [
    Table1Row(1, 0.1076, 0.0724, 2),
    Table1Row(2, 0.0219, 0.0227, 5),
    Table1Row(3, 0.0536, 0.0361, 3),
    Table1Row(4, 0.0022, 0.0015, 4),
    Table1Row(5, 0.0020, 0.0015, 5),
    Table1Row(6, 0.0011, 0.0011, 6),
    Table1Row(7, 0.0005, 0.0006, 7),
    Table1Row(8, 1e-7, 2e-7, 19),
]


def measure_table1(n: int = 200_000, n_inst: int = 50, seed: int = 0):
    """Measure achieved template selectivities (benchmark-reported Table 1)."""
    ds = build(n, seed)
    rng = np.random.default_rng(seed + 1)
    rows = []
    for k in range(1, 9):
        sels = []
        dims = []
        for _ in range(n_inst):
            q = template(k, rng, ds)
            sels.append(ds.selectivity(q))
            dims.append(q.n_queried_dims)
        rows.append(Table1Row(k, float(np.mean(sels)), float(np.std(sels)),
                              float(np.mean(dims))))
    return ds, rows
