"""Deterministic training data pipeline with MDRQ sample selection.

This is where the paper's technique becomes a first-class framework feature
(DESIGN.md §3): every training sample carries a multidimensional feature
vector (quality score, length, dedup distance, language score, toxicity, ...)
and the pipeline's admission filter is a partial-match MDRQ executed through
``repro.core`` — planner-selected access path, same engine the benchmarks
exercise. On a real cluster the filter runs over billions of sample records;
the ~1% break-even rule decides scan vs index per filter change.

Determinism & fault tolerance: batches are a pure function of
``(seed, step)`` — resume after preemption replays the exact same stream with
no state beyond the step counter (checkpointed by the trainer). A background
prefetch thread hides generation latency; bounded queue depth provides
back-pressure (straggler tolerance knob).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.core import Dataset, MDRQEngine, RangeQuery

FEATURES = [
    "quality", "length_log", "dedup_dist", "lang_score",
    "toxicity", "perplexity", "domain", "age_days",
]


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_pool: int = 65536          # candidate sample pool size
    seed: int = 0
    filter_query: Optional[dict[int, tuple[float, float]]] = None
    # structure of the synthetic LM stream (gives a learnable distribution)
    zipf_a: float = 1.2
    markov_mix: float = 0.7


def default_filter() -> dict[int, tuple[float, float]]:
    """Admit high-quality, low-toxicity, deduped samples (partial-match MDRQ)."""
    return {0: (0.5, 1.0), 2: (0.2, 1.0), 4: (0.0, 0.3)}


class FilteredTokenPipeline:
    """MDRQ-filtered, deterministic, prefetching token pipeline."""

    def __init__(self, cfg: DataConfig, prefetch: int = 4):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        feats = np.stack([
            rng.random(cfg.n_pool),                      # quality
            rng.random(cfg.n_pool),                      # length_log
            rng.random(cfg.n_pool),                      # dedup_dist
            rng.beta(5, 2, cfg.n_pool),                  # lang_score
            rng.beta(1, 8, cfg.n_pool),                  # toxicity
            rng.random(cfg.n_pool),                      # perplexity
            rng.integers(0, 16, cfg.n_pool),             # domain
            rng.random(cfg.n_pool) * 365,                # age_days
        ]).astype(np.float32)
        self.features = Dataset(feats)
        self.engine = MDRQEngine(self.features, structures=("scan", "kdtree"))
        fq = cfg.filter_query if cfg.filter_query is not None else default_filter()
        self.query = RangeQuery.partial(len(FEATURES), fq)
        self.admitted = self.engine.query(self.query, method="auto")
        if self.admitted.size == 0:
            raise ValueError("MDRQ filter admitted zero samples")
        self.filter_stats = self.engine.last_stats
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------
    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Batch for ``step`` — a pure function of (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed * 1_000_003 + step) & 0x7FFFFFFF)
        sample_ids = self.admitted[
            rng.integers(0, self.admitted.size, size=cfg.global_batch)
        ]
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        for b, sid in enumerate(sample_ids):
            toks[b] = self._sample_tokens(int(sid), step, b)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "sample_ids": sample_ids.astype(np.int32),
        }

    def _sample_tokens(self, sid: int, step: int, b: int) -> np.ndarray:
        """Zipf-with-Markov-structure synthetic stream (learnable, per-sample)."""
        cfg = self.cfg
        rng = np.random.default_rng((sid * 2_654_435_761 + step * 97 + b) & 0x7FFFFFFF)
        v = cfg.vocab_size
        draws = rng.zipf(cfg.zipf_a, size=cfg.seq_len + 1).astype(np.int64)
        draws = (draws - 1) % v
        toks = np.empty(cfg.seq_len + 1, np.int64)
        toks[0] = draws[0]
        mix = rng.random(cfg.seq_len) < cfg.markov_mix
        for t in range(1, cfg.seq_len + 1):
            # markov component: deterministic successor of the previous token
            toks[t] = (toks[t - 1] * 31 + 7) % v if mix[t - 1] else draws[t]
        return toks.astype(np.int32)

    # ------------------------------------------------------------------
    def iterate(self, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
        """Prefetching iterator from ``start_step`` (exact resume point)."""
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._queue.put(self.batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        while True:
            yield self._queue.get()

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
