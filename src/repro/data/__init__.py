"""repro.data — datasets, workloads, and the training data pipeline."""
from repro.data.synthetic import (
    power, random_pair_query, selectivity_targeted_query, synt_clust, synt_uni,
    workload,
)
from repro.data.pipeline import DataConfig, FilteredTokenPipeline, default_filter

__all__ = [
    "power", "random_pair_query", "selectivity_targeted_query", "synt_clust",
    "synt_uni", "workload", "DataConfig", "FilteredTokenPipeline",
    "default_filter",
]
