"""Synthetic datasets and workloads from the paper's evaluation (§7.2, Table 2).

  * SYNT-UNI   — uniform in [0,1]^m, 10k..10M objects, 5..100 dims.
  * SYNT-CLUST — 1..20 uniform clusters in subspace boxes (Müller et al. [29]
    generator, re-implemented: cluster centers uniform, per-cluster box with
    side ~10% of the domain, points uniform inside their cluster's box).
  * POWER      — DEBS 2012 smart-meter challenge shape: 3 dims with a
    monotone timestamp-like dimension and two skewed, correlated load
    dimensions (the real CSV is not redistributable; the generator matches the
    published domains/distinct-counts of Table 2).

Query workloads follow the paper's protocol: pick two random data objects and
use their per-dimension min/max as the range (§7.2.1) — yielding the same
wide selectivity spread the paper reports.
"""
from __future__ import annotations

import numpy as np

from repro.core import types as T


def synt_uni(n: int, m: int, seed: int = 0) -> T.Dataset:
    rng = np.random.default_rng(seed)
    return T.Dataset(rng.random((m, n), dtype=np.float32))


def synt_clust(n: int, m: int, n_clusters: int, seed: int = 0,
               cluster_side: float = 0.1) -> T.Dataset:
    """Clustered data: uniform inside per-cluster boxes (paper §7.2.2)."""
    rng = np.random.default_rng(seed)
    centers = rng.random((n_clusters, m))
    assign = rng.integers(0, n_clusters, size=n)
    lo = np.clip(centers[assign] - cluster_side / 2, 0.0, 1.0 - cluster_side)
    pts = lo + rng.random((n, m)) * cluster_side
    return T.Dataset(pts.astype(np.float32).T)


def power(n: int, seed: int = 0) -> T.Dataset:
    """DEBS-2012-shaped 3-dim data (timestamp, two skewed correlated loads)."""
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(2_556_001, 2_556_001 + n, size=n)).astype(np.float64)
    base = 12_466 + 4_000 * rng.beta(2.0, 5.0, size=n)
    wobble = 800 * np.sin(ts / 977.0) + rng.normal(0, 250, size=n)
    d2 = base + wobble
    d3 = d2 * rng.normal(1.1, 0.03, size=n) + rng.normal(0, 180, size=n)
    cols = np.stack([ts, d2, d3]).astype(np.float32)
    return T.Dataset(cols)


def random_pair_query(dataset: T.Dataset, rng: np.random.Generator) -> T.RangeQuery:
    """The paper's query generator: bounds from two random objects (§7.2.1)."""
    i, j = rng.integers(dataset.n), rng.integers(dataset.n)
    a, b = dataset.cols[:, i], dataset.cols[:, j]
    return T.RangeQuery.complete(np.minimum(a, b), np.maximum(a, b))


def workload(dataset: T.Dataset, n_queries: int, seed: int = 0) -> list[T.RangeQuery]:
    rng = np.random.default_rng(seed)
    return [random_pair_query(dataset, rng) for _ in range(n_queries)]


def selectivity_targeted_query(
    dataset: T.Dataset, target_sel: float, rng: np.random.Generator
) -> T.RangeQuery:
    """Complete-match query with approximately the requested selectivity.

    Used for the Fig. 6 sweep: centers a box on a random data object with side
    ``target_sel**(1/m)`` per dimension (exact under uniformity; measured
    selectivity is reported by the benchmarks, not assumed).
    """
    m = dataset.m
    side = float(target_sel) ** (1.0 / m)
    center = dataset.cols[:, rng.integers(dataset.n)]
    lo = center - side / 2
    hi = lo + side
    return T.RangeQuery.complete(lo, hi)
