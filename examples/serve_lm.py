"""Batched serving demo: continuous batching + MDRQ admission control.

A small model is briefly trained so generations are structured, then a mixed
request queue (varying priority / cost features) is served through the
BatchServer: the admission filter is a partial-match MDRQ over request
features (the paper's engine as the serving router).

  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.data import DataConfig, FilteredTokenPipeline
from repro.models.registry import build_model
from repro.serve import BatchServer, Request, admission_query
from repro.train import OptConfig, Trainer, TrainerConfig

import tempfile


def main() -> None:
    cfg = get_config("smollm_360m").replace(
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=1024, head_dim=32, remat="none")
    model = build_model(cfg)
    pipe = FilteredTokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=48, global_batch=8,
                                            n_pool=4096, seed=0))
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(model, pipe, OptConfig(peak_lr=2e-3, warmup_steps=10,
                                            decay_steps=120), d,
                     TrainerConfig(num_steps=120, ckpt_every=1000,
                                   log_every=60))
        tr.init_state()
        log = tr.run()
    print(f"warmup train: loss {log[0]['loss']:.2f} -> {log[-1]['loss']:.2f}")

    rng = np.random.default_rng(0)
    requests = []
    for i in range(12):
        prio = float(rng.random())
        cost = float(rng.random())
        requests.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12))).astype(np.int32),
            max_new=8,
            features=np.array([prio, 8, 100.0, cost], np.float32)))

    srv = BatchServer(model, tr.params, slots=4, max_len=64)
    q = admission_query(max_cost=0.8, min_priority=0.2)
    done = srv.serve(requests, q)
    print(f"\nadmitted & served {len(done)}/{len(requests)} requests "
          f"(others rejected by the MDRQ admission filter):")
    for r in done:
        print(f"  req {r.rid:2d} prio={r.features[0]:.2f} "
              f"cost={r.features[3]:.2f} -> {r.output.tolist()}")


if __name__ == "__main__":
    main()
