"""GMRQB: the paper's genomic benchmark end-to-end (paper §6, Fig. 10).

Builds the 19-dimensional shape-faithful GMRQB stand-in, measures Table 1
selectivities, and runs each template through scan / vertical scan / kd-tree /
VA-file with the planner's choice last.

  PYTHONPATH=src python examples/gmrqb_demo.py [n_objects]
"""
import os
os.environ.setdefault("REPRO_KERNEL_BACKEND", "xla")

import sys
import time

import numpy as np

from repro.core import MDRQEngine
from repro.data import gmrqb


def main() -> None:
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 300_000
    print(f"building GMRQB ({n} variation records, 19 attributes) ...")
    ds = gmrqb.build(n, seed=0)
    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
    rng = np.random.default_rng(1)

    print(f"\n{'T':>2} {'dims':>5} {'sel (measured)':>15} {'paper':>9}  "
          f"{'scan':>9} {'vertical':>9} {'kdtree':>9} {'vafile':>9}  planner")
    for k in range(1, 9):
        qs = [gmrqb.template(k, rng, ds) for _ in range(5)]
        sel = float(np.mean([ds.selectivity(q) for q in qs]))
        times = {}
        for meth in ("scan", "scan_vertical", "kdtree", "vafile"):
            t0 = time.perf_counter()
            for q in qs:
                eng.query(q, meth)
            times[meth] = (time.perf_counter() - t0) / len(qs) * 1e3
        choice = eng.planner.choose(qs[0])
        paper = gmrqb.PAPER_TABLE1[k - 1].avg_selectivity
        print(f"{k:>2} {qs[0].n_queried_dims:>5} {sel:>14.5%} {paper:>8.4%}  "
              f"{times['scan']:>7.1f}ms {times['scan_vertical']:>7.1f}ms "
              f"{times['kdtree']:>7.1f}ms {times['vafile']:>7.1f}ms  {choice}")

    mixed = [q for _, q in gmrqb.mixed_workload(ds, 20, seed=3)]
    t0 = time.perf_counter()
    for q in mixed:
        eng.query(q, "auto")
    dt = (time.perf_counter() - t0) / len(mixed) * 1e3
    print(f"\nmixed workload via planner: {dt:.1f} ms/query "
          f"({1000/dt:.0f} qps)")


if __name__ == "__main__":
    main()
