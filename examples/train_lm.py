"""End-to-end training driver: MDRQ-filtered pipeline -> train -> checkpoint
-> resume, with the fault-tolerant trainer.

Presets (CPU box):
  demo  — ~13M-param llama-family model, 200 steps (~3 min)
  100m  — ~100M-param model, --steps as budget allows

  PYTHONPATH=src python examples/train_lm.py --preset demo --steps 200
"""
import argparse
import os
import tempfile

import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, FilteredTokenPipeline
from repro.models.params import count_params, split_tree
from repro.models.registry import build_model
from repro.train import OptConfig, Trainer, TrainerConfig


def preset_config(name: str):
    base = get_config("smollm_360m")
    if name == "demo":
        return base.replace(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                            d_ff=1024, vocab_size=8192, head_dim=64,
                            remat="none"), 128, 8
    if name == "100m":
        return base.replace(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                            d_ff=2048, vocab_size=32768, head_dim=64,
                            remat="none"), 256, 8
    raise ValueError(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=("demo", "100m"))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg, seq_len, batch = preset_config(args.preset)
    model = build_model(cfg)
    pipe = FilteredTokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=batch,
        n_pool=16384, seed=0))
    print(f"MDRQ sample filter admitted {pipe.admitted.size}/{16384} samples "
          f"via {pipe.filter_stats.method!r} "
          f"(est sel {pipe.filter_stats.est_selectivity:.2%})")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    tr = Trainer(model, pipe, OptConfig(peak_lr=3e-3, warmup_steps=20,
                                        decay_steps=args.steps),
                 ckpt_dir, TrainerConfig(num_steps=args.steps,
                                         ckpt_every=max(50, args.steps // 4),
                                         log_every=max(10, args.steps // 20)))
    if not tr.try_resume():
        tr.init_state()
        print("fresh start")
    else:
        print(f"resumed from checkpoint at step {tr.step}")
    n_params = count_params(split_tree(tr.params)[0])
    print(f"model: {cfg.name} preset={args.preset} params={n_params:,} "
          f"seq={seq_len} batch={batch}")

    log = tr.run()
    print(f"\n{'step':>6} {'loss':>8} {'grad_norm':>10} {'s/step':>8}")
    for r in log:
        print(f"{r['step']:>6} {r['loss']:>8.4f} {r['grad_norm']:>10.4f} "
              f"{r['sec']:>8.2f}")
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'did NOT decrease'}); "
          f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
