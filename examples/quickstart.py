"""Quickstart: the paper in five minutes.

Builds a 1M x 5 uniform dataset (the paper's Fig. 6 configuration), runs the
same range query through every access path, shows they agree, and asks the
planner where the scan/index break-even sits — the paper's headline ~1%.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("REPRO_KERNEL_BACKEND", "xla")  # fast CPU proxy path

import time

import numpy as np

from repro.core import MDRQEngine, RangeQuery
from repro.data import synthetic


def main() -> None:
    n, m = 300_000, 5
    print(f"building SYNT-UNI {n} x {m} and all access paths ...")
    ds = synthetic.synt_uni(n, m, seed=0)
    eng = MDRQEngine(ds)

    rng = np.random.default_rng(1)
    for target in (0.0001, 0.01, 0.3):
        q = synthetic.selectivity_targeted_query(ds, target, rng)
        sel = ds.selectivity(q)
        print(f"\nquery with measured selectivity {sel:.4%}:")
        results = {}
        for meth in ("scan", "scan_vertical", "kdtree", "rstar", "vafile"):
            t0 = time.perf_counter()
            ids = eng.query(q, meth)
            dt = (time.perf_counter() - t0) * 1e3
            results[meth] = ids
            extra = ""
            if meth in ("kdtree", "rstar"):
                idx = getattr(eng, meth)
                extra = f" (visited {idx.last_visited_blocks}/{idx.n_leaves} blocks)"
            print(f"  {meth:14s} {ids.size:7d} ids in {dt:7.2f} ms{extra}")
        assert all(np.array_equal(v, results["scan"]) for v in results.values())
        plan = eng.planner.explain(q)
        print(f"  planner: est sel {plan.est_selectivity:.4%} -> choose "
              f"{plan.method!r}")

    be = eng.planner.break_even_selectivity()
    print(f"\ncost-model break-even at this scale: {be:.3%}"
          f"  (paper, 1M scale: ~1%; scans win everything below ~1e5 objects)")
    print("memory overhead per structure:", eng.memory_report())


if __name__ == "__main__":
    main()
