"""Batched MDRQ execution: fused query batches + the throughput server.

Runs a GMRQB mixed workload three ways — per-query (the seed regime), as one
``MDRQEngine.query_batch`` call, and through the ``MDRQServer`` batching
window — verifies all three agree, and prints the planner's batched
break-even shift (the cost-model result single-query analysis cannot see).

  PYTHONPATH=src python examples/batched_queries.py [n_objects]
"""
import os
os.environ.setdefault("REPRO_KERNEL_BACKEND", "xla")

import sys
import time

import numpy as np

from repro.core import Agg, Count, MDRQEngine, TopK
from repro.data import gmrqb
from repro.serve.mdrq_server import MDRQServer


def main() -> None:
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 200_000
    print(f"building GMRQB ({n} records, 19 attributes) ...")
    ds = gmrqb.build(n, seed=0)
    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
    queries = [q for _, q in gmrqb.mixed_workload(ds, 64, seed=1)]

    # 1) per-query (warm the jit caches first so we time steady state)
    for q in queries[:8]:
        eng.query(q, "auto")
    t0 = time.perf_counter()
    singles = [eng.query(q, "auto") for q in queries]
    t_single = time.perf_counter() - t0

    # 2) one fused batch (warm once with the same shapes: jit traces are
    # per pow2 bucket size, so the timed pass measures steady state)
    eng.query_batch(queries)
    t0 = time.perf_counter()
    batched = eng.query_batch(queries)
    t_batch = time.perf_counter() - t0
    stats = eng.last_batch_stats
    assert all(np.array_equal(a, b) for a, b in zip(singles, batched))

    # 3) through the serving window (warm the B=32 bucket shapes, then count)
    server = MDRQServer(eng, max_batch=32, max_wait_s=float("inf"))
    server.serve_all(queries)
    server.stats = type(server.stats)()
    served = server.serve_all(queries)
    assert all(np.array_equal(a, b) for a, b in zip(singles, served))

    # 4) reduced result shapes (the ResultSpec layer): counts, top-k by an
    # attribute, and aggregates reduce on device — only the payload crosses
    # to the host, the per-query nonzero never runs
    eng.query_batch(queries, spec=Count())
    t0 = time.perf_counter()
    counts = eng.query_batch(queries, spec=Count())
    t_count = time.perf_counter() - t0
    assert counts == [ids.size for ids in singles]

    top3 = eng.query_batch(queries, spec=TopK(k=3, dim=0))      # oldest 3
    sums = eng.query_batch(queries, spec=Agg("sum", dim=0))     # SUM(age)
    for ids, t3, sm in zip(singles, top3, sums):
        assert set(t3.tolist()) <= set(ids.tolist()) and t3.size <= 3
        assert ids.size == 0 or abs(sm) >= 0.0

    print(f"\nper-query : {len(queries)/t_single:8.1f} qps")
    print(f"one batch  : {len(queries)/t_batch:8.1f} qps  "
          f"(buckets: {stats.method_counts})")
    print(f"count mode : {len(queries)/t_count:8.1f} qps  "
          f"(ints only, {sum(counts)} total matches)")
    k = next(i for i, ids in enumerate(singles) if ids.size)
    print(f"top-3 by age (query {k}): ids {top3[k].tolist()}, "
          f"sum(age) = {sums[k]:.1f}")
    print(f"server B=32: {server.stats.qps:8.1f} qps  "
          f"({server.stats.n_batches} batches, "
          f"mean size {server.stats.mean_batch_size:.1f})")

    print("\nscan-vs-index break-even selectivity vs batch size "
          "(cost model, paper-like n=10M, m=5):")
    from repro.core.planner import CostModel, Planner
    p = Planner(eng.hist, CostModel(n=10_000_000, m=5))
    for b in (1, 8, 32, 128):
        print(f"  batch {b:>3}: {p.break_even_selectivity(batch_size=b):.4%}")
    from repro.core import Ids
    print("result-shape shift at batch 128: "
          f"Ids {p.break_even_selectivity(batch_size=128, spec=Ids()):.4%} "
          f"vs Count {p.break_even_selectivity(batch_size=128, spec=Count()):.4%}")


if __name__ == "__main__":
    main()
