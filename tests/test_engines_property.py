"""Property-based tests (hypothesis): every access path returns exactly the
oracle's result set for arbitrary data distributions and query boxes — the
system's core invariant (paper §2.1: result = ids of all matching objects)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Dataset, MDRQEngine, RangeQuery, build_columnar_scan,
                        build_kdtree, build_rstar, build_vafile, match_ids_np)


@st.composite
def dataset_and_query(draw):
    m = draw(st.integers(1, 12))
    n = draw(st.integers(10, 3000))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    dist = draw(st.sampled_from(["uniform", "clustered", "skewed", "discrete"]))
    if dist == "uniform":
        cols = rng.random((m, n))
    elif dist == "clustered":
        k = draw(st.integers(1, 5))
        centers = rng.random((k, m))
        a = rng.integers(0, k, n)
        cols = (centers[a] + rng.normal(0, 0.05, (n, m))).T
    elif dist == "skewed":
        cols = rng.beta(0.3, 3.0, (m, n))
    else:
        cols = rng.integers(0, 7, (m, n)).astype(np.float32)
    ds = Dataset(cols.astype(np.float32))
    # query: random box, sometimes partial-match, sometimes degenerate
    partial = draw(st.booleans())
    i, j = rng.integers(n), rng.integers(n)
    lo = np.minimum(ds.cols[:, i], ds.cols[:, j])
    hi = np.maximum(ds.cols[:, i], ds.cols[:, j])
    if partial and m > 1:
        keep = rng.random(m) < 0.5
        lo = np.where(keep, lo, -np.inf).astype(np.float32)
        hi = np.where(keep, hi, np.inf).astype(np.float32)
    q = RangeQuery(lo, hi)
    return ds, q


@settings(max_examples=25, deadline=None)
@given(dataset_and_query())
def test_all_paths_equal_oracle(dq):
    ds, q = dq
    oracle = match_ids_np(ds.cols, q)
    tile = 256  # small tiles so indexes have multiple blocks even at small n
    scan = build_columnar_scan(ds, tile_n=tile)
    np.testing.assert_array_equal(scan.query(q), oracle)
    np.testing.assert_array_equal(scan.query_partial(q), oracle)
    np.testing.assert_array_equal(build_kdtree(ds, tile_n=tile).query(q), oracle)
    np.testing.assert_array_equal(build_rstar(ds, tile_n=tile).query(q), oracle)
    np.testing.assert_array_equal(build_vafile(ds, tile_n=tile).query(q), oracle)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8))
def test_structure_invariants(seed, m):
    """kd-tree/STR perms are permutations; leaf MBRs contain their objects;
    VA codes quantize consistently with the boundaries."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(100, 4000))
    ds = Dataset(rng.random((m, n)).astype(np.float32))
    for build in (build_kdtree, build_rstar):
        idx = build(ds, tile_n=256)
        assert np.array_equal(np.sort(idx.perm), np.arange(n))
        leaf_lo = np.asarray(idx.levels_lo[-1])
        leaf_hi = np.asarray(idx.levels_hi[-1])
        perm_cols = ds.cols[:, idx.perm]
        for b in range(idx.n_leaves):
            blk = perm_cols[:, b * 256 : (b + 1) * 256]
            if blk.size == 0:
                continue
            assert (blk >= leaf_lo[:, b : b + 1] - 1e-6).all()
            assert (blk <= leaf_hi[:, b : b + 1] + 1e-6).all()
    va = build_vafile(ds, tile_n=256)
    assert va.boundaries.shape == (m, 3)
    assert (np.diff(va.boundaries, axis=1) >= -1e-6).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_engine_auto_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    ds = Dataset(rng.random((6, 5000)).astype(np.float32))
    eng = MDRQEngine(ds, tile_n=512)
    for _ in range(3):
        i, j = rng.integers(5000), rng.integers(5000)
        q = RangeQuery(np.minimum(ds.cols[:, i], ds.cols[:, j]),
                       np.maximum(ds.cols[:, i], ds.cols[:, j]))
        np.testing.assert_array_equal(eng.query(q, "auto"),
                                      match_ids_np(ds.cols, q))


def test_empty_and_full_results(uni5):
    eng = MDRQEngine(uni5, tile_n=1024)
    q_none = RangeQuery.complete([2.0] * 5, [3.0] * 5)
    q_all = RangeQuery.complete([-1.0] * 5, [2.0] * 5)
    for meth in ("scan", "kdtree", "rstar", "vafile"):
        assert eng.query(q_none, meth).size == 0
        assert eng.query(q_all, meth).size == uni5.n
