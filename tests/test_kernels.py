"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(ref.py) and the numpy ground truth. Outputs are discrete masks, so equality
is exact — assert_array_equal, not allclose."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import types as T
from repro.kernels import ops, ref
from repro.kernels.va_filter import pack_codes


def _mk(m, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    cols = rng.random((m, n)).astype(np.float32)
    a, b = cols[:, rng.integers(n)], cols[:, rng.integers(n)]
    q = T.RangeQuery.complete(np.minimum(a, b), np.maximum(a, b))
    return cols, q, rng


@pytest.mark.parametrize("m", [1, 3, 5, 8, 19, 64, 100])
@pytest.mark.parametrize("n", [1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_range_scan_sweep(m, n, dtype):
    cols, q, _ = _mk(m, n, dtype, seed=m * 1000 + n)
    padded, m0, n0 = ops.prepare_columnar(cols)
    data = jnp.asarray(padded, dtype)
    lo, up = ops.query_bounds_device(q, padded.shape[0], dtype)
    out = np.asarray(ops.range_scan(data, lo, up))[:n0]
    oracle = np.asarray(ref.range_scan_ref(data, lo[:, 0], up[:, 0]))[:n0]
    np.testing.assert_array_equal(out, oracle)
    if dtype == jnp.float32:  # numpy ground truth only exact in f32
        np.testing.assert_array_equal(out.astype(bool), T.match_mask_np(cols, q))


@pytest.mark.parametrize("m", [2, 19])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_range_scan_visit_sweep(m, dtype):
    cols, q, rng = _mk(m, 8192, dtype, seed=m)
    padded, _, n0 = ops.prepare_columnar(cols)
    data = jnp.asarray(padded, dtype)
    lo, up = ops.query_bounds_device(q, padded.shape[0], dtype)
    n_blocks = padded.shape[1] // 1024
    ids = np.concatenate([rng.permutation(n_blocks)[: n_blocks // 2],
                          [-1, -1]]).astype(np.int32)
    out = np.asarray(ops.range_scan_visit(data, jnp.asarray(ids), lo, up))
    blocks = data.reshape(data.shape[0], n_blocks, 1024).transpose(1, 0, 2)
    oracle = np.asarray(ref.range_scan_blocks_ref(blocks, jnp.asarray(ids),
                                                  lo[:, 0], up[:, 0]))
    np.testing.assert_array_equal(out, oracle)


@pytest.mark.parametrize("m,n_q", [(5, 2), (19, 7), (64, 30)])
def test_range_scan_vertical_sweep(m, n_q):
    cols, _, rng = _mk(m, 5000, jnp.float32, seed=m + n_q)
    dims = np.sort(rng.choice(m, size=n_q, replace=False))
    preds = {int(d): tuple(sorted(rng.random(2).tolist())) for d in dims}
    q = T.RangeQuery.partial(m, preds)
    padded, _, n0 = ops.prepare_columnar(cols)
    data = jnp.asarray(padded)
    lo, up = ops.query_bounds_device(q, padded.shape[0], jnp.float32)
    out = np.asarray(ops.range_scan_vertical(
        data, jnp.asarray(dims.astype(np.int32)), lo, up))[:n0]
    np.testing.assert_array_equal(out.astype(bool), T.match_mask_np(cols, q))


@pytest.mark.parametrize("m", [3, 19])
def test_range_scan_rows(m):
    cols, q, _ = _mk(m, 3000, jnp.float32, seed=m)
    rows = T.pad_axis(T.pad_axis(cols.T, 1, 8, 0.0), 0, 512, np.inf)
    lo, up = ops.query_bounds_device(q, rows.shape[1], jnp.float32)
    out = np.asarray(ops.range_scan_rows(jnp.asarray(rows), lo.T, up.T))[:3000]
    np.testing.assert_array_equal(out.astype(bool), T.match_mask_np(cols, q))


@pytest.mark.parametrize("m", [1, 16, 19, 33, 48])
def test_va_filter_sweep(m):
    rng = np.random.default_rng(m)
    n = 6144
    codes = rng.integers(0, 4, size=(m, n)).astype(np.uint8)
    qlo = rng.integers(0, 4, size=m).astype(np.int32)
    qhi = np.minimum(3, qlo + rng.integers(0, 4, size=m)).astype(np.int32)
    packed = T.pad_axis(pack_codes(codes), 1, 2048, 0)
    m_s = -(-m // 8) * 8
    qlo_p = np.zeros((m_s, 1), np.int32)
    qhi_p = np.full((m_s, 1), 3, np.int32)
    qlo_p[:m, 0], qhi_p[:m, 0] = qlo, qhi
    out = np.asarray(ops.va_filter(jnp.asarray(packed), jnp.asarray(qlo_p),
                                   jnp.asarray(qhi_p), m))[:n]
    oracle = np.asarray(ref.va_filter_ref(jnp.asarray(codes), jnp.asarray(qlo),
                                          jnp.asarray(qhi)))
    packed_oracle = np.asarray(ref.va_filter_packed_ref(
        jnp.asarray(pack_codes(codes)), jnp.asarray(qlo), jnp.asarray(qhi), m))
    np.testing.assert_array_equal(out, oracle)
    np.testing.assert_array_equal(oracle, packed_oracle)


def test_match_all_and_match_none():
    cols = np.random.default_rng(0).random((4, 2048)).astype(np.float32)
    padded, _, n0 = ops.prepare_columnar(cols)
    data = jnp.asarray(padded)
    q_all = T.RangeQuery.partial(4, {})
    lo, up = ops.query_bounds_device(q_all, padded.shape[0], jnp.float32)
    assert np.asarray(ops.range_scan(data, lo, up))[:n0].all()
    q_none = T.RangeQuery.partial(4, {0: (2.0, 3.0)})
    lo, up = ops.query_bounds_device(q_none, padded.shape[0], jnp.float32)
    assert not np.asarray(ops.range_scan(data, lo, up))[:n0].any()


def test_padding_objects_never_match():
    """+inf sentinel objects must not match even match-all queries' bounds."""
    cols = np.zeros((3, 100), np.float32)
    padded, _, n0 = ops.prepare_columnar(cols)
    q = T.RangeQuery.complete([-1e30] * 3, [1e30] * 3)
    lo, up = ops.query_bounds_device(q, padded.shape[0], jnp.float32)
    out = np.asarray(ops.range_scan(jnp.asarray(padded), lo, up))
    assert out[:n0].all() and not out[n0:].any()


def test_finite_bounds_wider_dtype_stays_finite():
    """A wider comparison dtype (f64 under jax x64) must not overflow the
    float32 carrier arrays back to +-inf — extrema clamp to f32's range."""
    inf = np.full((4, 1), np.inf, np.float32)
    lo, up = T.finite_query_bounds(-inf, inf, dtype=np.float64)
    assert np.isfinite(lo).all() and np.isfinite(up).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_finite_bounds_respect_device_dtype(dtype):
    """Match-all bounds must stay finite *in the comparison dtype*: float32
    extrema round to +-inf under a bfloat16 cast, so the +inf object-padding
    sentinels would match and every padded-axis count reduction (mask_counts,
    visit segment counts, distributed psum) would overcount."""
    inf = np.full((8, 1), np.inf, np.float32)
    lo, up = T.finite_query_bounds(-inf, inf, dtype=dtype)
    assert np.isfinite(np.asarray(jnp.asarray(lo, dtype), np.float32)).all()
    assert np.isfinite(np.asarray(jnp.asarray(up, dtype), np.float32)).all()

    cols = np.random.default_rng(5).random((3, 100)).astype(np.float32)
    padded, _, n0 = ops.prepare_columnar(cols)
    data = jnp.asarray(padded, dtype)
    q_all = T.RangeQuery.partial(3, {})
    qlo, qhi = ops.query_bounds_device(q_all, padded.shape[0], dtype)
    mask = ops.range_scan(data, qlo, qhi)
    # on-device count sees exactly the real objects, never the sentinels
    assert int(np.asarray(ops.mask_counts(mask))) == n0
    assert not np.asarray(mask)[n0:].any()
