"""Mamba-2 SSD chunked algorithm vs the naive recurrence oracle.

The SSD identity (Dao & Gu 2024): the chunked block decomposition must equal
the sequential state-space recurrence
    h_t = exp(dt_t * a) h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t . h_t
exactly (up to dtype). This is the kernel-level correctness property for the
ssm family, independent of any model wiring.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.models.ssm import ssd_chunked


def naive_ssd(x, dt, a, b, c):
    """Sequential recurrence oracle (f64). Shapes as ssd_chunked."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    y = np.zeros((bsz, l, h, p))
    state = np.zeros((bsz, h, p, n))
    for t in range(l):
        da = np.exp(dt[:, t] * a[None, :])                    # (B, H)
        xb = x[:, t] * dt[:, t][..., None]                    # (B, H, P)
        state = state * da[..., None, None] + np.einsum(
            "bhp,bhn->bhpn", xb, b[:, t])
        y[:, t] = np.einsum("bhn,bhpn->bhp", c[:, t], state)
    return y, state


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("seed", [0, 1])
def test_ssd_chunked_equals_recurrence(chunk, seed):
    rng = np.random.default_rng(seed)
    bsz, l, h, p, n = 2, 32, 3, 4, 8
    x = rng.normal(size=(bsz, l, h, p)).astype(np.float32)
    dt = (0.1 + rng.random((bsz, l, h))).astype(np.float32)
    a = (-rng.random(h)).astype(np.float32)
    b = rng.normal(size=(bsz, l, h, n)).astype(np.float32)
    c = rng.normal(size=(bsz, l, h, n)).astype(np.float32)

    y, state = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                           jnp.asarray(b), jnp.asarray(c), chunk)
    y_ref, state_ref = naive_ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y, np.float32), y_ref,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state, np.float32), state_ref,
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_invariance():
    """Different chunk sizes must give identical outputs."""
    rng = np.random.default_rng(2)
    bsz, l, h, p, n = 1, 64, 2, 4, 4
    x = rng.normal(size=(bsz, l, h, p)).astype(np.float32)
    dt = (0.1 + rng.random((bsz, l, h))).astype(np.float32)
    a = (-rng.random(h)).astype(np.float32)
    b = rng.normal(size=(bsz, l, h, n)).astype(np.float32)
    c = rng.normal(size=(bsz, l, h, n)).astype(np.float32)
    outs = [np.asarray(ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                                   jnp.asarray(a), jnp.asarray(b),
                                   jnp.asarray(c), ch)[0], np.float32)
            for ch in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-3, atol=2e-3)
