"""Data pipeline determinism/filtering + serving batcher + GMRQB bands."""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core import RangeQuery, match_ids_np
from repro.data import DataConfig, FilteredTokenPipeline
from repro.data import gmrqb
from repro.models.registry import build_model
from repro.serve import BatchServer, Request, admission_query


def test_pipeline_determinism_and_resume():
    cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=4, n_pool=2048, seed=9)
    p1, p2 = FilteredTokenPipeline(cfg), FilteredTokenPipeline(cfg)
    for step in (0, 5, 1000):
        b1, b2 = p1.batch(step), p2.batch(step)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
    it = p1.iterate(start_step=7)
    nb = next(it)
    np.testing.assert_array_equal(nb["tokens"], p2.batch(7)["tokens"])
    p1.close()


def test_pipeline_filter_is_mdrq():
    cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=4, n_pool=4096,
                     seed=2, filter_query={0: (0.9, 1.0)})
    p = FilteredTokenPipeline(cfg)
    # admitted = exactly the oracle result of the partial-match query
    q = RangeQuery.partial(8, {0: (0.9, 1.0)})
    np.testing.assert_array_equal(p.admitted, match_ids_np(p.features.cols, q))
    # all sampled ids come from the admitted set
    b = p.batch(3)
    assert np.isin(b["sample_ids"], p.admitted).all()


def test_gmrqb_template_selectivity_bands():
    """Measured template selectivities must fall in the paper's Table 1 order
    of magnitude (shape-faithful synthetic stand-in; see gmrqb.py)."""
    ds, rows = gmrqb.measure_table1(n=100_000, n_inst=25, seed=0)
    sels = {r.template: r.avg_selectivity for r in rows}
    assert 0.03 < sels[1] < 0.30          # paper: 10.76%
    assert 0.005 < sels[2] < 0.08         # paper: 2.19%
    assert 0.01 < sels[3] < 0.15          # paper: 5.36%
    for k in (4, 5, 6, 7):
        assert 1e-4 < sels[k] < 1e-2      # paper: 0.05%..0.22%
    assert sels[8] < 1e-3                 # paper: ~1e-7 (n-limited here)
    dims = {r.template: r.avg_dims for r in rows}
    assert dims[1] == 2 and dims[8] == 19


def test_gmrqb_engine_equality():
    from repro.core import MDRQEngine
    ds = gmrqb.build(30_000, seed=1)
    eng = MDRQEngine(ds, tile_n=1024)
    rng = np.random.default_rng(0)
    for k in (1, 4, 8):
        q = gmrqb.template(k, rng, ds)
        oracle = match_ids_np(ds.cols, q)
        for meth in ("scan", "scan_vertical", "kdtree", "vafile", "auto"):
            np.testing.assert_array_equal(eng.query(q, meth), oracle)
            assert eng.query(q, meth, mode="count") == oracle.size


def test_stats_qps_zero_on_empty_paths():
    """Both rate reports return 0.0 — never inf — when nothing was measured:
    ``flush()`` on empty pending and ``query_batch([])``."""
    from repro.core import BatchStats, Dataset, MDRQEngine
    from repro.serve.mdrq_server import MDRQServer, ServerStats

    assert ServerStats().qps == 0.0
    assert BatchStats(5, 0.0, {}, 0).qps == 0.0  # zero seconds, nonzero work

    rng = np.random.default_rng(4)
    eng = MDRQEngine(Dataset(rng.random((3, 2048), dtype=np.float32)),
                     structures=("scan",), tile_n=512)
    assert eng.query_batch([]) == []
    assert eng.last_batch_stats.qps == 0.0
    assert eng.last_batch_stats.n_queries == 0

    srv = MDRQServer(eng, max_batch=8, max_wait_s=float("inf"))
    assert srv.flush() == 0  # empty flush: no batch recorded, rate stays 0.0
    assert srv.stats.n_batches == 0
    assert srv.stats.qps == 0.0


def test_server_survives_engine_failure_and_rejects_bad_dims():
    """A failing flush must not lose co-batched queries, and dim-mismatched
    queries are rejected at submit (before they can poison a window)."""
    from repro.core import Dataset, MDRQEngine, RangeQuery
    from repro.serve.mdrq_server import MDRQServer

    rng = np.random.default_rng(8)
    eng = MDRQEngine(Dataset(rng.random((3, 2048), dtype=np.float32)),
                     structures=("scan",), tile_n=512)
    srv = MDRQServer(eng, max_batch=8, max_wait_s=float("inf"))
    with pytest.raises(ValueError):
        srv.submit(RangeQuery.partial(5, {0: (0.0, 1.0)}))  # wrong dims
    assert srv.n_pending == 0

    q = RangeQuery.partial(3, {0: (0.2, 0.8)})
    ticket = srv.submit(q)
    # make the engine fail once mid-flush; pending must be restored
    real = eng.query_batch
    eng.query_batch = lambda *a, **k: (_ for _ in ()).throw(RuntimeError("boom"))
    with pytest.raises(RuntimeError):
        srv.flush()
    assert srv.n_pending == 1
    eng.query_batch = real
    np.testing.assert_array_equal(ticket.result(),
                                  match_ids_np(eng.dataset.cols, q))


def test_server_poll_flushes_idle_stream():
    """An idle stream must have a flush path once the latency bound passes:
    ``poll()`` flushes iff the oldest pending query exceeded ``max_wait_s``
    (the seed's bound only fired on the *next* submit)."""
    from repro.core import Dataset, MDRQEngine, RangeQuery
    from repro.serve.mdrq_server import MDRQServer

    rng = np.random.default_rng(6)
    ds = Dataset(rng.random((3, 2048), dtype=np.float32))
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    srv = MDRQServer(eng, max_batch=64, max_wait_s=60.0)
    assert srv.poll() == 0  # nothing pending: no-op

    q = RangeQuery.partial(3, {0: (0.2, 0.8)})
    ticket = srv.submit(q)
    assert srv.poll() == 0 and srv.n_pending == 1  # deadline far away
    assert not ticket._done

    srv.max_wait_s = 0.0  # deadline has now passed for the idle window
    assert srv.poll() == 1  # flushed without a submit or result() call
    assert srv.n_pending == 0 and ticket._done
    np.testing.assert_array_equal(ticket.result(),
                                  match_ids_np(ds.cols, q))
    assert srv.stats.n_batches == 1


def test_server_count_mode():
    """A count-mode serving window resolves tickets to device-reduced ints."""
    from repro.core import Dataset, MDRQEngine, RangeQuery
    from repro.serve.mdrq_server import MDRQServer

    rng = np.random.default_rng(12)
    ds = Dataset(rng.random((4, 4096), dtype=np.float32))
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    queries = [RangeQuery.partial(4, {0: (0.0, 0.3), 2: (0.1, 0.9)}),
               RangeQuery.partial(4, {1: (0.5, 0.5)}),  # point predicate
               RangeQuery.partial(4, {})]
    srv = MDRQServer(eng, max_batch=2, max_wait_s=float("inf"), mode="count")
    results = srv.serve_all(queries)
    for q, c in zip(queries, results):
        assert isinstance(c, int)
        assert c == match_ids_np(ds.cols, q).size
    assert srv.stats.n_results == sum(results)
    with pytest.raises(ValueError):
        MDRQServer(eng, mode="nope")


def test_batch_server_completes_all_admitted():
    cfg = get_config("smollm_360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new=3,
                    features=np.array([0.9, 4, 100, 0.1], np.float32))
            for i in range(5)]
    srv = BatchServer(model, params, slots=2, max_len=24)
    done = srv.serve(reqs)
    assert len(done) == 5  # all pass the default admission filter
    assert all(r.output is not None and len(r.output) == 3 for r in done)


def test_admission_filters_low_priority():
    reqs = [Request(rid=0, prompt=np.zeros(2, np.int32), max_new=1,
                    features=np.array([0.05, 2, 100, 0.1], np.float32)),
            Request(rid=1, prompt=np.zeros(2, np.int32), max_new=1,
                    features=np.array([0.9, 2, 100, 0.1], np.float32))]
    admitted = BatchServer.admit(reqs, admission_query(min_priority=0.2))
    assert [r.rid for r in admitted] == [1]
