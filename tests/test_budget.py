"""BUDGET.json: the static launch/sync certificate vs (a) a fresh derivation
from the source and (b) the runtime ``mdrq_launches_total`` counters.

This is the contract that makes the certificate trustworthy in both
directions: ``analysis.budget`` derives the numbers by abstract
interpretation over the project call graph (stdlib ast, no jax), and this
file re-asserts them against what the engine actually does — for every
certified path, frozen and under a live delta, under ``Ids()`` and
``Count()``, through both the synchronous ``query_batch`` and the split
``launch_batch``/``finalize`` protocol (whose device-stage/finalize split
the certificate states explicitly). If either side drifts, exactly one of
the two halves fails and names the path.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import budget
from repro.analysis.engine import build_project, iter_py_files
from repro.core import Count, Ids, MDRQEngine, RangeQuery
from repro.kernels import ops

REPO = Path(__file__).resolve().parents[1]
CERT_PATH = REPO / "BUDGET.json"

SPECS = (Ids(), Count())


@pytest.fixture(scope="module")
def cert():
    return json.loads(CERT_PATH.read_text())


@pytest.fixture(scope="module")
def graph():
    project, errors = build_project(iter_py_files([REPO / "src"]))
    assert errors == []
    return project.graph


# -- the certificate is fresh and internally consistent -----------------------

def test_checked_in_certificate_matches_source(graph):
    """``make budget-cert`` would be a no-op: re-deriving the certificate
    from the current source produces the checked-in file byte-for-byte."""
    assert budget.check(graph, CERT_PATH) == []


def test_certificate_covers_every_registered_path(cert):
    """Every plannable fused path the engine can build is certified."""
    assert set(cert["paths"]) == {"scan", "scan_vertical", "kdtree",
                                  "rstar", "vafile"}
    for name, ctx in cert["paths"].items():
        assert set(ctx) == {"frozen", "delta"}, name


def test_certificate_internal_consistency(cert):
    """finalize = total - device_stage, launches all happen in the device
    stage, and the engine/serve layers add zero cost of their own."""
    for name, ctx in cert["paths"].items():
        for key in ("frozen", "delta"):
            e = ctx[key]
            assert e["finalize_host_syncs"] == (
                e["total"]["host_syncs"]
                - e["device_stage"]["host_syncs"]), (name, key)
            assert e["total"]["launches"] == e["device_stage"]["launches"], \
                (name, key)
            assert e["finalize_host_syncs"] >= 1, (name, key)
    zero = {"host_syncs": 0, "launches": {}}
    assert cert["engine"]["MDRQEngine.launch_batch"] == zero
    assert cert["engine"]["MDRQEngine.query_batch"] == zero
    assert cert["engine"]["PendingBatch.finalize"]["per_bucket"] == \
        {"host_syncs": 1, "launches": {}}
    assert cert["serve"]["PipelinedMDRQServer.flush"] == zero
    assert cert["serve"]["PipelinedMDRQServer._finalize_loop"] == zero


# -- the certificate matches the runtime counters ------------------------------

def _mixed_queries(cols, rng, n_q=6):
    m = cols.shape[0]
    out = []
    for k in range(n_q):
        if k % 2 == 0:
            a = cols[:, rng.integers(cols.shape[1])]
            b = cols[:, rng.integers(cols.shape[1])]
            out.append(RangeQuery.complete(np.minimum(a, b),
                                           np.maximum(a, b)))
        else:
            dims = rng.choice(m, size=int(rng.integers(1, m + 1)),
                              replace=False)
            preds = {int(d): tuple(sorted(rng.random(2).tolist()))
                     for d in dims}
            out.append(RangeQuery.partial(m, preds))
    return out


@pytest.fixture(scope="module")
def eng_frozen(uni5):
    return MDRQEngine(uni5, tile_n=512)


@pytest.fixture(scope="module")
def eng_delta(uni5):
    eng = MDRQEngine(uni5, tile_n=512)
    rng = np.random.default_rng(177)
    new_ids = eng.append(rng.random((200, uni5.m)).astype(np.float32))
    eng.delete(np.concatenate([rng.choice(uni5.n, 120, replace=False),
                               new_ids[:10]]))
    return eng


def _expected(entry) -> dict:
    """Certificate entry -> the exact ``ops.counters()`` dict (nonzero only,
    host syncs under the ``host_sync`` pseudo-op of the same family)."""
    exp = dict(entry["launches"])
    if entry["host_syncs"]:
        exp["host_sync"] = entry["host_syncs"]
    return exp


@pytest.mark.parametrize("context", ["frozen", "delta"])
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.kind)
def test_query_batch_counters_equal_certificate(cert, eng_frozen, eng_delta,
                                                uni5, context, spec):
    """Warm path x spec x frozen/delta: one synchronous ``query_batch``
    bumps exactly the certified mdrq_launches_total deltas."""
    eng = eng_frozen if context == "frozen" else eng_delta
    rng = np.random.default_rng(7)
    queries = _mixed_queries(uni5.cols, rng)
    for name, ctx in cert["paths"].items():
        eng.query_batch(queries, method=name, spec=spec)  # warm / trace
        ops.reset_counters()
        eng.query_batch(queries, method=name, spec=spec)
        assert ops.counters() == _expected(ctx[context]["total"]), \
            (name, context, spec.kind)


@pytest.mark.parametrize("context", ["frozen", "delta"])
def test_split_protocol_stage_split_equals_certificate(cert, eng_frozen,
                                                       eng_delta, uni5,
                                                       context):
    """``launch_batch`` spends exactly the certified device-stage budget;
    ``finalize`` adds exactly the certified finalize syncs (one bucket)."""
    eng = eng_frozen if context == "frozen" else eng_delta
    rng = np.random.default_rng(19)
    queries = _mixed_queries(uni5.cols, rng)
    for name, ctx in cert["paths"].items():
        e = ctx[context]
        eng.query_batch(queries, method=name)  # warm / trace
        ops.reset_counters()
        pending = eng.launch_batch(queries, method=name)
        assert ops.counters() == _expected(e["device_stage"]), \
            (name, context, "device stage")
        pending.finalize()
        assert ops.counters() == _expected(e["total"]), \
            (name, context, "after finalize")
        assert ops.counter("host_sync") - e["device_stage"]["host_syncs"] \
            == e["finalize_host_syncs"], (name, context)


def test_certificate_drift_is_detected(graph, tmp_path):
    """A tampered certificate fails ``budget.check`` with a leaf-level diff
    naming the changed key — the CI failure mode for an uncommitted budget
    change."""
    cert = budget.certify(graph)
    cert["paths"]["scan"]["frozen"]["total"]["host_syncs"] += 1
    stale = tmp_path / "BUDGET.json"
    stale.write_text(budget.render(cert))
    drift = budget.check(graph, stale)
    assert len(drift) == 1
    assert "paths.scan.frozen.total.host_syncs" in drift[0]


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
