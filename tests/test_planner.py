"""Planner: selectivity estimation, cost-model structure, break-even bands."""
import numpy as np
import pytest

from repro.core import Dataset, MDRQEngine, RangeQuery
from repro.core.planner import BINS, CostModel, Histograms, Planner


def test_histogram_estimates(uni5):
    hist = Histograms.build(uni5)
    # uniform data: sel of [0.2, 0.5] on one dim ~ 0.3
    q = RangeQuery.partial(5, {2: (0.2, 0.5)})
    est = hist.selectivity(q)
    true = uni5.selectivity(q)
    assert abs(est - true) < 0.03
    # complete match multiplies per-dim estimates (independence, §2.1)
    q2 = RangeQuery.complete([0.1] * 5, [0.6] * 5)
    est2 = hist.selectivity(q2)
    assert abs(est2 - 0.5 ** 5) < 0.02


def test_histogram_edge_cases(uni5):
    hist = Histograms.build(uni5)
    assert hist.selectivity(RangeQuery.partial(5, {})) == 1.0
    assert hist.selectivity(RangeQuery.partial(5, {0: (5.0, 6.0)})) == 0.0
    assert hist.selectivity(RangeQuery.partial(5, {0: (-5.0, 5.0)})) == 1.0
    # empty range (lb > ub) estimates zero
    assert hist.dim_selectivity(0, 0.7, 0.3) == 0.0


def test_point_predicate_selectivity_floor(uni5):
    """Point predicates (lb == ub, GMRQB-style) must estimate >= 1/n, not 0 —
    a 0.0 estimate mis-ranks every access path for the query."""
    hist = Histograms.build(uni5)
    v = float(uni5.cols[2, 123])
    assert hist.dim_selectivity(2, v, v) >= 1.0 / uni5.n
    # in-domain boundary points too
    e0 = float(hist.edges[2][0])
    assert hist.dim_selectivity(2, e0, e0) >= 1.0 / uni5.n
    # out-of-domain points stay zero
    assert hist.dim_selectivity(2, 7.0, 7.0) == 0.0
    # a full point query plans with selectivity >= 1/n and a usable plan
    rec = uni5.cols[:, 123]
    q = RangeQuery.complete(rec, rec)
    p = Planner(hist, CostModel(n=uni5.n, m=5))
    plan = p.explain(q)
    assert plan.est_selectivity >= 1.0 / uni5.n
    assert plan.method in plan.costs


def test_all_built_structures_plannable(uni5):
    """Every structure the engine builds must be in the planner's available
    tuple (the seed engine built the R*-tree but never planned it)."""
    eng = MDRQEngine(uni5, tile_n=512)
    for name in ("kdtree", "rstar", "vafile"):
        assert getattr(eng, name) is not None
        assert name in eng.planner.available
    q = RangeQuery.complete([0.4] * 5, [0.6] * 5)
    assert "rstar" in eng.planner.explain(q).costs
    # engines built with a subset stay consistent
    eng2 = MDRQEngine(uni5, structures=("scan", "rstar"), tile_n=512)
    assert "rstar" in eng2.planner.available
    assert "kdtree" not in eng2.planner.available


def test_vafile_cost_amortizes_with_batch(uni5):
    """Batched phase 1: the VA-file's filter bytes and both sync halves now
    divide by the batch size."""
    hist = Histograms.build(uni5)
    model = CostModel(n=1_000_000, m=5)
    q = RangeQuery.complete([0.0] * 5, [0.1] * 5)
    c1 = model.cost_vafile(q, hist, batch=1)
    c128 = model.cost_vafile(q, hist, batch=128)
    assert c128 < c1
    # the amortized part includes the approximation stream, not just taxes:
    # the gap must exceed the full fixed-tax amortization alone
    fixed = 2.0 * model.dispatch_overhead + model.host_sync_overhead
    assert (c1 - c128) > fixed * (1 - 1 / 128) * 0.99
    p = Planner(hist, model)
    be = p.break_even_selectivity(index_path="vafile", batch_size=8)
    assert 0.0 <= be <= 1.0



def test_break_even_band_paper_scale(uni5):
    """At the paper's 1M x 5 scale the model's break-even must sit in the
    'around 1%' band the paper reports (we accept 0.05%..5%)."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=1_000_000, m=5))
    be = p.break_even_selectivity()
    assert 0.0005 < be < 0.05, be


def test_small_datasets_prefer_scan(uni5):
    """Paper Fig. 7: scans win outright for n <= 1e5."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=50_000, m=5))
    assert p.break_even_selectivity() == 0.0
    q = RangeQuery.complete([0.0] * 5, [0.01] * 5)  # extremely selective
    assert p.choose(q) in ("scan", "scan_vertical")


def test_partial_match_prefers_vertical(uni19):
    """Paper §8: partial-match over few dims -> vertically partitioned scan."""
    hist = Histograms.build(uni19)
    p = Planner(hist, CostModel(n=uni19.n, m=19))
    q = RangeQuery.partial(19, {3: (0.4, 0.6), 7: (0.1, 0.9)})
    plan = p.explain(q)
    assert plan.costs["scan_vertical"] < plan.costs["scan"]


def test_cost_monotone_in_selectivity():
    model = CostModel(n=1_000_000, m=5)
    sels = [1e-5, 1e-4, 1e-3, 1e-2, 1e-1]
    qs = [RangeQuery.complete([0.0] * 5, [s ** 0.2] * 5) for s in sels]
    costs = [model.cost_tree(q, s) for q, s in zip(qs, sels)]
    assert all(a <= b + 1e-12 for a, b in zip(costs, costs[1:]))


def test_calibration_refits_constants(uni5):
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=uni5.n, m=5))
    # synthetic measurements: 2x slower byte rate than the default
    b = uni5.n * 5 * 4
    samples = [("scan", b, b * 2 * p.model.sec_per_byte + 5e-6)] * 3
    old = p.model.sec_per_byte
    rep = p.calibrate(samples)
    assert p.model.sec_per_byte > old * 1.5
    assert rep.n_samples == 3 and rep.methods == ("scan",)
    assert rep.accepted["sec_per_byte"]


def test_calibration_reports_rejected_fit(uni5):
    """A failed fit must be distinguishable from a successful one: rejected
    constants keep their previous value and the report says so (the seed
    silently kept stale constants)."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=uni5.n, m=5))
    old_rate = p.model.sec_per_byte
    # decreasing time with increasing bytes -> negative sec_per_byte fit
    # (the positive intercept still fits dispatch_overhead — partial accept)
    samples = [("scan", 1e6, 2e-3), ("vafile", 2e6, 1e-3)]
    rep = p.calibrate(samples)
    assert not rep.accepted["sec_per_byte"]
    assert rep.accepted["dispatch_overhead"]
    assert not rep.ok
    assert p.model.sec_per_byte == old_rate          # stale value kept, visibly
    assert rep.methods == ("scan", "vafile")         # who backed the fit
    fit = {f.constant: f for f in rep.fits}["sec_per_byte"]
    assert fit.fitted < 0 and "keeping" in fit.reason
    # empty calibration is a no-op with an empty report
    before = (p.model.sec_per_byte, p.model.dispatch_overhead)
    rep0 = p.calibrate([])
    assert rep0.n_samples == 0 and not rep0.ok
    assert (p.model.sec_per_byte, p.model.dispatch_overhead) == before


def test_break_even_drops_with_devices(uni5):
    """Sharding the scan over d devices divides its streamed bytes while the
    indexes stay single-device, so the break-even selectivity must fall
    monotonically with d — the device axis of the paper's §8 conclusion."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=10_000_000, m=5))
    bes = [p.break_even_selectivity(n_devices=d) for d in (1, 2, 4, 8)]
    assert bes[0] > 0
    assert all(a > b for a, b in zip(bes, bes[1:])), bes
    # n_devices=1 is exactly the legacy result
    assert bes[0] == p.break_even_selectivity()
    # the model default picks up an engine-provided device count
    pd = Planner(hist, CostModel(n=10_000_000, m=5, n_devices=8))
    q = RangeQuery.complete([0.0] * 5, [0.5] * 5)
    assert pd.model.cost_scan(q) < p.model.cost_scan(q)
    # ... and the collective tax keeps multi-device scans from being a free
    # lunch at batch=1: d=2 costs more than half of d=1
    assert p.model.cost_scan(q, n_devices=2) > p.model.cost_scan(q) / 2
