"""Planner: selectivity estimation, cost-model structure, break-even bands."""
import numpy as np
import pytest

from repro.core import Dataset, RangeQuery
from repro.core.planner import BINS, CostModel, Histograms, Planner


def test_histogram_estimates(uni5):
    hist = Histograms.build(uni5)
    # uniform data: sel of [0.2, 0.5] on one dim ~ 0.3
    q = RangeQuery.partial(5, {2: (0.2, 0.5)})
    est = hist.selectivity(q)
    true = uni5.selectivity(q)
    assert abs(est - true) < 0.03
    # complete match multiplies per-dim estimates (independence, §2.1)
    q2 = RangeQuery.complete([0.1] * 5, [0.6] * 5)
    est2 = hist.selectivity(q2)
    assert abs(est2 - 0.5 ** 5) < 0.02


def test_histogram_edge_cases(uni5):
    hist = Histograms.build(uni5)
    assert hist.selectivity(RangeQuery.partial(5, {})) == 1.0
    assert hist.selectivity(RangeQuery.partial(5, {0: (5.0, 6.0)})) == 0.0
    assert hist.selectivity(RangeQuery.partial(5, {0: (-5.0, 5.0)})) == 1.0


def test_break_even_band_paper_scale(uni5):
    """At the paper's 1M x 5 scale the model's break-even must sit in the
    'around 1%' band the paper reports (we accept 0.05%..5%)."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=1_000_000, m=5))
    be = p.break_even_selectivity()
    assert 0.0005 < be < 0.05, be


def test_small_datasets_prefer_scan(uni5):
    """Paper Fig. 7: scans win outright for n <= 1e5."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=50_000, m=5))
    assert p.break_even_selectivity() == 0.0
    q = RangeQuery.complete([0.0] * 5, [0.01] * 5)  # extremely selective
    assert p.choose(q) in ("scan", "scan_vertical")


def test_partial_match_prefers_vertical(uni19):
    """Paper §8: partial-match over few dims -> vertically partitioned scan."""
    hist = Histograms.build(uni19)
    p = Planner(hist, CostModel(n=uni19.n, m=19))
    q = RangeQuery.partial(19, {3: (0.4, 0.6), 7: (0.1, 0.9)})
    plan = p.explain(q)
    assert plan.costs["scan_vertical"] < plan.costs["scan"]


def test_cost_monotone_in_selectivity():
    model = CostModel(n=1_000_000, m=5)
    sels = [1e-5, 1e-4, 1e-3, 1e-2, 1e-1]
    qs = [RangeQuery.complete([0.0] * 5, [s ** 0.2] * 5) for s in sels]
    costs = [model.cost_tree(q, s) for q, s in zip(qs, sels)]
    assert all(a <= b + 1e-12 for a, b in zip(costs, costs[1:]))


def test_calibration_refits_constants(uni5):
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=uni5.n, m=5))
    # synthetic measurements: 2x slower byte rate than the default
    b = uni5.n * 5 * 4
    samples = [("scan", b, b * 2 * p.model.sec_per_byte + 5e-6)] * 3
    old = p.model.sec_per_byte
    p.calibrate(samples)
    assert p.model.sec_per_byte > old * 1.5
