"""Per-architecture smoke tests: REDUCED config of each assigned arch runs one
forward/train step on CPU — output shapes correct, no NaNs (deliverable (f))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import (attn_policy, build_model, sharding_rules,
                                   shape_applicable)
from repro.models.params import count_params, split_tree
from repro.models.transformer import vocab_padded
from repro.train import OptConfig, init_opt_state, make_train_step


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.family == "audio" and cfg.encoder_layers:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, max(8, S // 4), cfg.d_model)), jnp.float32)
        text = S
    else:
        text = S - cfg.n_prefix_embeds
        if cfg.n_prefix_embeds:
            batch["prefix_embeds"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_prefix_embeds, cfg.d_model)), jnp.float32)
    batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, text)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, text)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape[0] == B and logits.shape[-1] == vocab_padded(cfg)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN/inf in logits"
    # one full train step
    step = jax.jit(make_train_step(model, OptConfig(warmup_steps=1, decay_steps=10)))
    opt = init_opt_state(params)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l1 = jax.tree.leaves(split_tree(params)[0])
    l2 = jax.tree.leaves(split_tree(p2)[0])
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(l1, l2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 16, jnp.dtype(cfg.param_dtype))
    toks = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, toks, jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, 1, vocab_padded(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache2["pos"][0]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_full_config_sanity(arch):
    """FULL configs: param counts near the advertised sizes; policies valid."""
    cfg = get_config(arch)
    counts = cfg.param_counts()
    expected = {
        "smollm_360m": 0.36e9, "h2o_danube_1_8b": 1.8e9,
        "phi3_medium_14b": 14e9, "qwen3_8b": 8e9, "arctic_480b": 480e9,
        "deepseek_moe_16b": 16e9, "mamba2_780m": 0.78e9,
        "seamless_m4t_large_v2": 2.3e9, "llava_next_34b": 34e9,
        "recurrentgemma_2b": 2.7e9,
    }[arch]
    assert 0.5 * expected < counts["total"] < 2.0 * expected, counts
    assert counts["active"] <= counts["total"]
    pol = attn_policy(cfg)
    rules = sharding_rules(cfg)
    if pol == "A" and cfg.family != "ssm":
        assert rules["heads"] == "model" and rules["kv_heads"] == "model"
    if pol == "C":
        assert rules["heads"] is None
    # d_ff / d_model / padded vocab always divide the 16-wide model axis
    if cfg.d_ff:
        assert cfg.d_ff % 16 == 0
    assert cfg.d_model % 16 == 0
    assert vocab_padded(cfg) % 16 == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shape_applicability_matrix(arch):
    cfg = get_config(arch)
    ok_train, _ = shape_applicable(cfg, "train_4k")
    ok_long, why = shape_applicable(cfg, "long_500k")
    assert ok_train
    if arch in ("mamba2_780m", "recurrentgemma_2b", "h2o_danube_1_8b"):
        assert ok_long, f"{arch} has bounded state; long_500k must run"
    else:
        assert not ok_long and "full attention" in why
