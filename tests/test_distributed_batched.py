"""Cross-device batched scan: the distributed equivalence suite.

``DistributedScan.query_batch`` / ``count_batch`` must return exactly what
single-device ``ColumnarScan`` returns — ids and count modes — while issuing
one fused collective launch and one host sync per batch (counter-asserted;
wall-clock on CPU cannot see launch budgets).

In-process tests run on whatever devices the session has (1 under the tier-1
suite; 8 under ``make test-dist``, which forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``). True multi-device
equivalence additionally runs in a subprocess with a forced 8-device CPU
platform so the main test process keeps its own device view (XLA locks the
device count at first init)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Count, Dataset, DistributedScan, MDRQEngine,
                        QueryBatch, RangeQuery, match_ids_np)
from repro.core.distributed import make_data_mesh
from repro.core.scan import build_columnar_scan
from repro.kernels import ops


def _mixed_queries(ds, rng, n_q):
    """Record-anchored complete matches + partial + point + match-all."""
    out = []
    for _ in range(n_q):
        a = ds.cols[:, rng.integers(ds.n)]
        b = ds.cols[:, rng.integers(ds.n)]
        out.append(RangeQuery.complete(np.minimum(a, b), np.maximum(a, b)))
    out.append(RangeQuery.partial(ds.m, {1: (0.2, 0.6)}))
    rec = ds.cols[:, rng.integers(ds.n)]
    out.append(RangeQuery.complete(rec, rec))     # point query
    out.append(RangeQuery.partial(ds.m, {}))      # match-all
    return out


@pytest.fixture(scope="module")
def dist_pair(uni5):
    return (DistributedScan(uni5, mesh=make_data_mesh()),
            build_columnar_scan(uni5))


def test_distributed_batch_matches_columnar(dist_pair, uni5):
    """Batched ids and counts equal ColumnarScan, one launch + one sync."""
    dsc, cs = dist_pair
    rng = np.random.default_rng(3)
    batch = QueryBatch.from_queries(_mixed_queries(uni5, rng, 5))
    want = cs.query_batch(batch)

    ops.reset_counters()
    got = dsc.query_batch(batch)
    assert ops.counter("distributed_multi_reduce") == 1
    assert ops.counter("host_sync") == 1
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)

    ops.reset_counters()
    counts = dsc.query_batch(batch, spec=Count())
    assert ops.counter("distributed_multi_reduce") == 1
    assert ops.counter("host_sync") == 1
    assert counts == [w.size for w in want]
    assert all(isinstance(c, int) for c in counts)


def test_distributed_batch_accepts_query_list(dist_pair, uni5):
    dsc, cs = dist_pair
    rng = np.random.default_rng(11)
    queries = _mixed_queries(uni5, rng, 2)
    got = dsc.query_batch(queries)  # plain sequence, not a QueryBatch
    for q, ids in zip(queries, got):
        np.testing.assert_array_equal(ids, match_ids_np(uni5.cols, q))
    with pytest.raises(ValueError):
        dsc.query_batch(queries, spec="top_k")


def test_distributed_single_query_is_counted(dist_pair, uni5):
    """The pre-existing single-query entry points are in the launch/host-sync
    accounting too (the seed's raw ``np.asarray`` escaped it)."""
    dsc, _ = dist_pair
    q = RangeQuery.partial(uni5.m, {0: (0.1, 0.4)})
    ops.reset_counters()
    ids = dsc.query(q)
    assert ops.counter("distributed_mask") == 1
    assert ops.counter("host_sync") == 1
    ops.reset_counters()
    cnt = dsc.count(q)
    assert ops.counter("distributed_count") == 1
    assert ops.counter("host_sync") == 1
    assert cnt == ids.size == match_ids_np(uni5.cols, q).size


def test_meshed_engine_routes_scan_buckets(uni5):
    """``MDRQEngine(mesh=...)`` sends scan buckets through the distributed
    path (counter-asserted) and returns identical results to a plain engine;
    the cost model picks up the mesh's device count."""
    mesh = make_data_mesh()
    eng_d = MDRQEngine(uni5, structures=("scan",), tile_n=512, mesh=mesh)
    eng_s = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    assert eng_d.planner.model.n_devices == mesh.shape["data"]
    assert eng_s.planner.model.n_devices == 1

    rng = np.random.default_rng(23)
    queries = _mixed_queries(uni5, rng, 4)
    ops.reset_counters()
    got = eng_d.query_batch(queries, method="scan")
    assert ops.counter("distributed_multi_reduce") == 1
    assert ops.counter("multi_scan_reduce") == 0  # not the single-device path
    for a, b in zip(got, eng_s.query_batch(queries, method="scan")):
        np.testing.assert_array_equal(a, b)

    counts = eng_d.query_batch(queries, method="scan", spec=Count())
    assert counts == [match_ids_np(uni5.cols, q).size for q in queries]
    # single-query dispatch routes through the mesh as well
    q = queries[0]
    np.testing.assert_array_equal(eng_d.query(q, "scan"),
                                  match_ids_np(uni5.cols, q))
    assert eng_d.query(q, "scan", mode="count") == match_ids_np(uni5.cols, q).size


def test_meshed_engine_never_auto_builds_columnar_copy(uni5):
    """On a meshed engine "auto" must not plan paths that execute on the
    single-device columnar copy: the lazy build would re-place the whole
    dataset on one device next to the sharded copy. Partial-match queries
    plan through the distributed scan instead; scan_vertical stays an
    explicit opt-in."""
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512,
                     mesh=make_data_mesh())
    assert eng.planner.available == ("scan",)
    assert eng._columnar is None
    q = RangeQuery.partial(uni5.m, {1: (0.2, 0.6)})
    res = eng.query_batch([q], method="auto")
    np.testing.assert_array_equal(res[0], match_ids_np(uni5.cols, q))
    assert eng._columnar is None  # no single-device copy materialized
    # the explicit opt-in still works (and only then builds the copy)
    np.testing.assert_array_equal(
        eng.query(q, method="scan_vertical"), match_ids_np(uni5.cols, q))
    assert eng._columnar is not None


def test_server_unchanged_on_meshed_engine(uni5):
    """The serving front end needs no change for a meshed engine: same API,
    same results, scan batches counted on the distributed path."""
    from repro.serve.mdrq_server import MDRQServer

    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512,
                     mesh=make_data_mesh())
    rng = np.random.default_rng(31)
    queries = _mixed_queries(uni5, rng, 6)
    server = MDRQServer(eng, max_batch=4, max_wait_s=float("inf"),
                        method="scan")
    ops.reset_counters()
    results = server.serve_all(queries)
    # 9 queries at window 4 -> 3 flushes -> 3 fused collective launches
    assert ops.counter("distributed_multi_reduce") == server.stats.n_batches == 3
    for q, ids in zip(queries, results):
        np.testing.assert_array_equal(ids, match_ids_np(uni5.cols, q))

    counts = MDRQServer(eng, max_batch=8, max_wait_s=float("inf"),
                        method="scan", spec=Count()).serve_all(queries)
    assert counts == [match_ids_np(uni5.cols, q).size for q in queries]


# -- forced 8-device subprocess equivalence -----------------------------------

DIST_BATCH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.core import (Agg, Count, Dataset, DistributedScan, MDRQEngine,
                            QueryBatch, RangeQuery, TopK, match_ids_np)
    from repro.core.distributed import make_data_mesh
    from repro.core.scan import build_columnar_scan
    from repro.kernels import ops
    from repro.serve.mdrq_server import MDRQServer
    from repro.data import gmrqb

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(7)

    def check_batch(ds, queries, mesh):
        dsc = DistributedScan(ds, mesh=mesh)
        cs = build_columnar_scan(ds)
        batch = QueryBatch.from_queries(queries)
        want = cs.query_batch(batch)
        ops.reset_counters()
        got = dsc.query_batch(batch)
        assert ops.counter("distributed_multi_reduce") == 1, ops.counters()
        assert ops.counter("host_sync") == 1, ops.counters()
        for k, (a, b) in enumerate(zip(got, want)):
            assert np.array_equal(a, b), k
        ops.reset_counters()
        counts = dsc.query_batch(batch, spec=Count())
        assert ops.counter("distributed_multi_reduce") == 1, ops.counters()
        assert ops.counter("host_sync") == 1, ops.counters()
        assert counts == [w.size for w in want]
        # reduced shapes: shard-local partials + one small collective merge,
        # still one launch + one host sync, oracle-checked against the ids
        for spec in (TopK(k=5, dim=1), Agg("sum", 0), Agg("min", 2)):
            ops.reset_counters()
            red = dsc.query_batch(batch, spec=spec)
            assert ops.counter("distributed_multi_reduce") == 1, ops.counters()
            assert ops.counter("host_sync") == 1, ops.counters()
            for k, ids in enumerate(want):
                vals = ds.cols[spec.dim, ids]
                if spec.kind == "topk":
                    assert set(red[k]) <= set(ids)
                    exp = ids[np.argsort(-vals, kind="stable")[: spec.k]]
                    assert np.allclose(ds.cols[spec.dim, red[k]],
                                       ds.cols[spec.dim, exp]), k
                elif spec.op == "sum":
                    assert np.isclose(red[k], vals.sum(dtype=np.float64),
                                      rtol=1e-4), k
                elif ids.size:
                    assert np.isclose(red[k], vals.min()), k
                else:
                    assert np.isnan(red[k]), k
        return want

    # random 5-dim dataset, record-anchored + partial + match-all queries
    ds = Dataset(rng.random((5, 40000), dtype=np.float32))
    queries = []
    for _ in range(6):
        a = ds.cols[:, rng.integers(ds.n)]; b = ds.cols[:, rng.integers(ds.n)]
        queries.append(RangeQuery.complete(np.minimum(a, b), np.maximum(a, b)))
    queries += [RangeQuery.partial(5, {1: (0.2, 0.6)}), RangeQuery.partial(5, {})]
    mesh = make_data_mesh(8)
    want = check_batch(ds, queries, mesh)

    # GMRQB template batches (19 dims, point predicates)
    gds = gmrqb.build(20000, seed=3)
    grng = np.random.default_rng(9)
    gqueries = [gmrqb.template(k, grng, gds) for k in (1, 4, 5, 7, 8)]
    check_batch(gds, gqueries, mesh)

    # meshed engine + unchanged server on top
    eng = MDRQEngine(ds, structures=("scan",), mesh=mesh)
    assert eng.planner.model.n_devices == 8
    srv = MDRQServer(eng, max_batch=4, max_wait_s=float("inf"), method="scan")
    res = srv.serve_all(queries)
    for a, b in zip(res, want):
        assert np.array_equal(a, b)
    print("DIST_BATCH_OK")
""")


def test_multi_device_batched_subprocess():
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", DIST_BATCH_SCRIPT],
                       capture_output=True, text=True, timeout=600, env=env,
                       cwd=root)
    assert "DIST_BATCH_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
