"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
import numpy as np
import pytest

from repro import obs
from repro.core import Dataset
from repro.kernels import ops


@pytest.fixture(autouse=True)
def reset_metrics():
    """Zero the launch/host-sync counters and every other registry metric
    before each test — launch-budget assertions and exporter tests never see
    another test's traffic. (``registry().reset()`` keeps the metric objects,
    so references cached in ``kernels.ops`` stay live.)"""
    ops.reset_counters()
    obs.registry().reset()
    yield


@pytest.fixture(scope="session")
def uni5():
    rng = np.random.default_rng(42)
    return Dataset(rng.random((5, 20_000), dtype=np.float32))


@pytest.fixture(scope="session")
def uni19():
    rng = np.random.default_rng(43)
    return Dataset(rng.random((19, 8_192), dtype=np.float32))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
