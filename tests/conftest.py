"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
import numpy as np
import pytest

from repro.core import Dataset


@pytest.fixture(scope="session")
def uni5():
    rng = np.random.default_rng(42)
    return Dataset(rng.random((5, 20_000), dtype=np.float32))


@pytest.fixture(scope="session")
def uni19():
    rng = np.random.default_rng(43)
    return Dataset(rng.random((19, 8_192), dtype=np.float32))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
