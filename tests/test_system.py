"""End-to-end system tests: the paper's headline experiment in miniature, the
framework integration path (MDRQ filter -> train -> checkpoint -> serve), and
the dry-run machinery on a small subprocess mesh (compile AND execute)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import Dataset, MDRQEngine, RangeQuery, match_ids_np
from repro.data import synthetic


def test_paper_headline_selectivity_ordering(uni5):
    """Fig. 6 in miniature: at high selectivity the tree index must visit far
    fewer blocks than a scan touches; at low selectivity nearly all blocks.
    (Timing claims are benchmarks' business; block-visit counts are exact.)"""
    eng = MDRQEngine(uni5, tile_n=512)
    n_blocks = -(-uni5.n // 512)
    rng = np.random.default_rng(0)
    q_hi = synthetic.selectivity_targeted_query(uni5, 0.0005, rng)
    eng.query(q_hi, "kdtree")
    visited_hi = eng.kdtree.last_visited_blocks
    q_lo = synthetic.selectivity_targeted_query(uni5, 0.5, rng)
    eng.query(q_lo, "kdtree")
    visited_lo = eng.kdtree.last_visited_blocks
    assert visited_hi <= n_blocks * 0.35, (visited_hi, n_blocks)
    assert visited_lo >= n_blocks * 0.5, (visited_lo, n_blocks)


def test_vafile_prunes_exact_compares(uni19):
    eng = MDRQEngine(uni19, tile_n=512)
    rng = np.random.default_rng(1)
    q = synthetic.selectivity_targeted_query(uni19, 1e-4, rng)
    ids = eng.query(q, "vafile")
    np.testing.assert_array_equal(ids, match_ids_np(uni19.cols, q))
    assert eng.vafile.last_candidate_frac < 0.05  # 19-dim prefilter bites


def test_cross_dataset_workloads():
    """Paper Table 2 datasets: engines agree with the oracle on all of them."""
    for ds in (synthetic.synt_uni(5000, 5, 0),
               synthetic.synt_clust(5000, 5, 10, 0),
               synthetic.power(5000, 0)):
        eng = MDRQEngine(ds, tile_n=512)
        for q in synthetic.workload(ds, 5, seed=3):
            oracle = match_ids_np(ds.cols, q)
            for meth in ("scan", "kdtree", "rstar", "vafile"):
                np.testing.assert_array_equal(eng.query(q, meth), oracle)


DRYRUN_MINI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models.registry import build_model, sharding_rules
    from repro.models.params import sharding_tree
    from repro.train import OptConfig, init_opt_state, make_train_step
    from repro.train.optimizer import opt_state_pspecs

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("smollm_360m").reduced().replace(
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512)
    model = build_model(cfg)
    rules = dict(sharding_rules(cfg, tp=4))
    rules.update(heads="model", kv_heads="model")
    params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_sh = sharding_tree(params_abs, mesh, rules)
    opt_abs = jax.eval_shape(init_opt_state, params_abs)
    opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          opt_state_pspecs(params_abs, rules, data_size=2),
                          is_leaf=lambda x: isinstance(x, P))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    bs = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    step = make_train_step(model, OptConfig())
    low = jax.jit(step, in_shardings=(param_sh, opt_sh, bs),
                  donate_argnums=(0, 1)).lower(params_abs, opt_abs, batch)
    comp = low.compile()
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older JAX returns one dict per device
        ca = ca[0]
    assert ca.get("flops", 0) > 0
    # ALSO execute it for real on the 8-device mesh (not just compile)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)), param_sh)
    opt = jax.device_put(init_opt_state(params), opt_sh)
    rngn = np.random.default_rng(0)
    real = {k: jax.device_put(jnp.asarray(rngn.integers(0, 512, (8, 64)),
            jnp.int32), bs[k]) for k in batch}
    p2, o2, metrics = comp(params, opt, real)
    assert np.isfinite(float(metrics["loss"]))
    print("DRYRUN_MINI_OK", float(metrics["loss"]))
""")


def test_dryrun_machinery_small_mesh():
    """The dry-run path (shardings, lower, compile, cost analysis) on a 2x4
    subprocess mesh — and the compiled step actually EXECUTES multi-device."""
    import os
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", DRYRUN_MINI], capture_output=True,
                       text=True, timeout=900, env=env, cwd=root)
    assert "DRYRUN_MINI_OK" in r.stdout, f"{r.stdout}\n{r.stderr[-3000:]}"
