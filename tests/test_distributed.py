"""Distributed MDRQ + gradient compression.

Single-device shard_map equality runs in-process; true multi-device behaviour
(8 host devices) runs in a subprocess so the main test process keeps its
1-device view (XLA locks the device count at first init)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import Dataset, DistributedScan, RangeQuery, match_ids_np


def test_distributed_scan_single_device(uni5):
    dsc = DistributedScan(uni5)
    rng = np.random.default_rng(0)
    for _ in range(3):
        i, j = rng.integers(uni5.n), rng.integers(uni5.n)
        q = RangeQuery(np.minimum(uni5.cols[:, i], uni5.cols[:, j]),
                       np.maximum(uni5.cols[:, i], uni5.cols[:, j]))
        oracle = match_ids_np(uni5.cols, q)
        np.testing.assert_array_equal(dsc.query(q), oracle)
        assert dsc.count(q) == oracle.size


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import Dataset, DistributedScan, RangeQuery, match_ids_np
    from repro.core.distributed import make_data_mesh

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(7)
    ds = Dataset(rng.random((5, 40000), dtype=np.float32))
    dsc = DistributedScan(ds, mesh=make_data_mesh(8))
    for t in range(5):
        i, j = rng.integers(ds.n), rng.integers(ds.n)
        q = RangeQuery(np.minimum(ds.cols[:, i], ds.cols[:, j]),
                       np.maximum(ds.cols[:, i], ds.cols[:, j]))
        oracle = match_ids_np(ds.cols, q)
        assert np.array_equal(dsc.query(q), oracle), t
        assert dsc.count(q) == oracle.size
    print("MULTI_DEVICE_OK")
""")

COMPRESSION_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.distributed import shard_map_compat
    from repro.train import compressed_psum

    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    rng = np.random.default_rng(0)
    g_local = rng.normal(size=(8, 256, 64)).astype(np.float32)

    def body(g):
        return compressed_psum({"w": g[0]}, "data")["w"]

    out = jax.jit(shard_map_compat(body, mesh=mesh, in_specs=P("data"),
                                   out_specs=P()))(jnp.asarray(g_local))
    exact = g_local.mean(axis=0)
    rel = np.abs(np.asarray(out) - exact).max() / np.abs(exact).max()
    assert rel < 0.02, rel   # int8 quantization error bound
    print("COMPRESSION_OK", rel)
""")


@pytest.mark.parametrize("script,marker", [
    (MULTI_DEVICE_SCRIPT, "MULTI_DEVICE_OK"),
    (COMPRESSION_SCRIPT, "COMPRESSION_OK"),
])
def test_multi_device_subprocess(script, marker):
    import os
    from pathlib import Path
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=env, cwd=root)
    assert marker in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
