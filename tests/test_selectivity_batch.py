"""Property test: vectorized selectivity estimation == scalar, exactly.

``Histograms.dim_selectivity_batch`` / ``selectivity_batch`` are the
foundation of the vectorized batch planner — any drift from the scalar
estimators would silently re-rank access paths between single-query and
batched planning. The sweep covers data distributions and every predicate
shape (finite boxes, point predicates at real records, half-open bounds,
unconstrained dims, empty ranges, out-of-domain boxes) and requires *exact*
equality per query and per (query, dim).

A deterministic seeded sweep always runs; with hypothesis installed the same
generator is additionally driven as a property test over drawn seeds/shapes.
"""
import numpy as np
import pytest

from repro.core import Dataset, QueryBatch, RangeQuery
from repro.core.planner import Histograms

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _make_dataset(m: int, n: int, dist: str, scale: float,
                  rng: np.random.Generator) -> Dataset:
    if dist == "uniform":
        cols = rng.random((m, n)) * scale
    elif dist == "skewed":
        cols = rng.beta(0.3, 3.0, (m, n)) * scale
    else:  # discrete (repeated values, zero-width histogram corners)
        cols = rng.integers(0, 5, (m, n)).astype(np.float64) * scale
    return Dataset(cols.astype(np.float32))


def _make_batch(ds: Dataset, q_n: int, scale: float,
                rng: np.random.Generator) -> QueryBatch:
    queries = []
    for _ in range(q_n):
        lo = rng.uniform(-0.5 * scale, 1.5 * scale, ds.m).astype(np.float32)
        up = (lo + rng.uniform(-0.3 * scale, scale, ds.m)).astype(np.float32)
        kind = rng.integers(6)
        if kind == 1:     # point predicate at a real record (GMRQB-style)
            rec = ds.cols[:, rng.integers(ds.n)]
            lo, up = rec.copy(), rec.copy()
        elif kind == 2:   # half-open bounds
            lo = np.where(rng.random(ds.m) < 0.5, -np.inf, lo).astype(np.float32)
            up = np.where(rng.random(ds.m) < 0.5, np.inf, up).astype(np.float32)
        elif kind == 3:   # fully unconstrained (match-all)
            lo[:], up[:] = -np.inf, np.inf
        elif kind == 4:   # out-of-domain box
            lo = lo + 10.0 * scale
            up = up + 10.0 * scale
        queries.append(RangeQuery(lo, up))
    return QueryBatch.from_queries(queries)


def _check_batch_equals_scalar(ds: Dataset, batch: QueryBatch) -> None:
    hist = Histograms.build(ds)
    dim_b = hist.dim_selectivity_batch(batch.lower, batch.upper)
    sel_b = hist.selectivity_batch(batch.lower, batch.upper)
    assert dim_b.shape == (len(batch), ds.m)
    assert sel_b.shape == (len(batch),)
    for k, q in enumerate(batch.queries):
        for d in range(ds.m):
            scalar = hist.dim_selectivity(d, float(q.lower[d]),
                                          float(q.upper[d]))
            assert dim_b[k, d] == scalar, (k, d)
        assert sel_b[k] == hist.selectivity(q), k
    # reusing a precomputed dim_sels array must not change anything
    np.testing.assert_array_equal(
        hist.selectivity_batch(batch.lower, batch.upper, dim_sels=dim_b),
        sel_b)


def test_selectivity_batch_matches_scalar_seeded_sweep():
    rng = np.random.default_rng(0)
    for trial in range(60):
        m = int(rng.integers(1, 9))
        n = int(rng.integers(10, 1500))
        dist = ("uniform", "skewed", "discrete")[trial % 3]
        scale = (1.0, 4.0, 0.01)[trial % 3]
        ds = _make_dataset(m, n, dist, scale, rng)
        batch = _make_batch(ds, int(rng.integers(1, 11)), scale, rng)
        _check_batch_equals_scalar(ds, batch)


if HAVE_HYPOTHESIS:

    @st.composite
    def dataset_and_batch(draw):
        m = draw(st.integers(1, 9))
        n = draw(st.integers(10, 1500))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        scale = draw(st.sampled_from([1.0, 4.0, 0.01]))
        dist = draw(st.sampled_from(["uniform", "skewed", "discrete"]))
        ds = _make_dataset(m, n, dist, scale, rng)
        batch = _make_batch(ds, draw(st.integers(1, 10)), scale, rng)
        return ds, batch

    @settings(max_examples=40, deadline=None)
    @given(dataset_and_batch())
    def test_selectivity_batch_matches_scalar_property(db):
        ds, batch = db
        _check_batch_equals_scalar(ds, batch)
