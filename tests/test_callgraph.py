"""Call-graph edge cases: aliased imports, decorator-registered counted
launches, ``__init__.py`` re-export chains, import cycles, and PEP 420
namespace-level module naming (``src/repro/`` has no ``__init__.py``).

Fixture projects are written to tmp dirs with real ``__init__.py`` files so
``module_name`` derives the same dotted names the rules match against.
"""
from __future__ import annotations

import ast
import textwrap
from pathlib import Path

import pytest

from repro.analysis import engine
from repro.analysis.callgraph import CallGraph, module_name
from repro.analysis.rules import HostSyncRule

REPO = Path(__file__).resolve().parents[1]


def build_graph(tmp_path: Path, files: dict[str, str]) -> CallGraph:
    pairs = []
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
        # every package level needs an __init__.py for module_name to walk
        d = p.parent
        while d != tmp_path:
            (d / "__init__.py").touch()
            d = d.parent
        pairs.append((p, ast.parse(p.read_text())))
    # re-parse __init__ files that were only touched above but also listed
    seen = {p for p, _ in pairs}
    for rel in files:
        d = (tmp_path / rel).parent
        while d != tmp_path:
            init = d / "__init__.py"
            if init not in seen:
                pairs.append((init, ast.parse(init.read_text())))
                seen.add(init)
            d = d.parent
    return CallGraph.build(pairs)


def test_aliased_import_resolves_counted_op(tmp_path):
    g = build_graph(tmp_path, {
        "repro/kernels/myops.py": """\
            def counted(op, reg=None):
                def wrap(fn):
                    return fn
                return wrap
            def _impl(batch):
                return batch
            big_scan = counted("big_scan_op")(_impl)
            """,
        "repro/serve/user.py": """\
            from repro.kernels import myops as o
            def drive(batch):
                return o.big_scan(batch)
            """,
    })
    assert g.counted_op("repro.serve.user", "o.big_scan") == "big_scan_op"
    # the wrapped impl is registered under the same op
    assert g.counted_op("repro.kernels.myops", "_impl") == "big_scan_op"


def test_decorator_registered_counted_launch(tmp_path):
    g = build_graph(tmp_path, {
        "repro/kernels/deco.py": """\
            from repro.kernels.myops import counted

            @counted("deco_op")
            def fused(batch):
                return batch
            """,
        "repro/kernels/myops.py": """\
            def counted(op):
                def wrap(fn):
                    return fn
                return wrap
            """,
        "repro/core/user.py": """\
            from repro.kernels.deco import fused
            def drive(batch):
                return fused(batch)
            """,
    })
    assert g.counted_op("repro.kernels.deco", "fused") == "deco_op"
    assert g.counted_op("repro.core.user", "fused") == "deco_op"


def test_reexport_through_init_resolves(tmp_path):
    g = build_graph(tmp_path, {
        "repro/kernels/myops.py": """\
            def counted(op):
                def wrap(fn):
                    return fn
                return wrap

            @counted("exported_op")
            def big_scan(batch):
                return batch
            """,
        "repro/kernels/__init__.py": """\
            from repro.kernels.myops import big_scan
            """,
        "repro/serve/user.py": """\
            from repro.kernels import big_scan
            def drive(batch):
                return big_scan(batch)
            """,
    })
    # canonicalize follows the __init__ re-export to the real definition
    assert g.resolve("repro.serve.user", "big_scan") \
        == "repro.kernels.myops.big_scan"
    assert g.counted_op("repro.serve.user", "big_scan") == "exported_op"


def test_import_cycle_is_cycle_safe(tmp_path):
    g = build_graph(tmp_path, {
        "repro/core/a.py": """\
            from repro.core.b import thing
            """,
        "repro/core/b.py": """\
            from repro.core.a import thing
            """,
        "repro/core/user.py": """\
            from repro.core.a import thing
            def drive():
                return thing()
            """,
    })
    # neither module defines `thing`; the chain a -> b -> a terminates
    assert g.resolve("repro.core.user", "thing") is None


def test_method_resolution_walks_bases(tmp_path):
    g = build_graph(tmp_path, {
        "repro/core/base.py": """\
            class BasePath:
                def query_batch(self, batch):
                    return batch
            """,
        "repro/core/paths.py": """\
            from repro.core.base import BasePath
            class FancyPath(BasePath):
                def launch_batch(self, batch):
                    return batch, None
            """,
    })
    hit = g.lookup_method("repro.core.paths.FancyPath", "query_batch")
    assert hit is not None
    assert hit.qual == "repro.core.base.BasePath.query_batch"


def test_attr_types_from_init_construction_and_annotation(tmp_path):
    g = build_graph(tmp_path, {
        "repro/core/scan.py": """\
            class ColumnarScan:
                def query_batch(self, batch):
                    return batch
            """,
        "repro/core/paths.py": """\
            from repro.core.scan import ColumnarScan
            class DirectPath:
                def __init__(self):
                    self._scan = ColumnarScan()
            class AnnotatedPath:
                def __init__(self, scan: ColumnarScan):
                    self._scan = scan
            """,
    })
    assert g.classes["repro.core.paths.DirectPath"].attr_types["_scan"] \
        == "repro.core.scan.ColumnarScan"
    assert g.classes["repro.core.paths.AnnotatedPath"].attr_types["_scan"] \
        == "repro.core.scan.ColumnarScan"


def test_cross_module_host_sync_rides_aliased_import(tmp_path):
    """A raw np.asarray() around an aliased counted launch in another
    module is a host-sync finding — the taint crosses files."""
    files = {
        "repro/kernels/myops.py": """\
            def counted(op):
                def wrap(fn):
                    return fn
                return wrap

            @counted("big_scan_op")
            def big_scan(batch):
                return batch
            """,
        "repro/serve/user.py": """\
            import numpy as np
            from repro.kernels import myops as o

            def drive(batch):
                return np.asarray(o.big_scan(batch))
            """,
    }
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
        d = p.parent
        while d != tmp_path:
            (d / "__init__.py").touch()
            d = d.parent
    rep = engine.run([tmp_path / rel for rel in files], [HostSyncRule()])
    assert [f.rule for f in rep.active] == ["host-sync"]
    assert "serve/user.py" in rep.active[0].file


def test_namespace_module_name_absorbs_src_level():
    """``src/repro/`` ships without ``__init__.py`` (PEP 420); module names
    must still come out rooted at ``repro``."""
    assert module_name(REPO / "src/repro/core/paths.py") == "repro.core.paths"
    assert module_name(REPO / "src/repro/numerics.py") == "repro.numerics"
    assert module_name(
        REPO / "src/repro/kernels/__init__.py") == "repro.kernels"


def test_namespace_module_name_in_tmp_src_layout(tmp_path):
    p = tmp_path / "src" / "mypkg" / "sub" / "mod.py"
    p.parent.mkdir(parents=True)
    (p.parent / "__init__.py").touch()
    p.write_text("X = 1\n")
    # sub/ has __init__.py, mypkg/ is a namespace level under src/
    assert module_name(p) == "mypkg.sub.mod"


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
