"""Training substrate: AdamW math, grad accumulation, ZeRO-1 specs,
checkpoint atomicity/integrity, trainer fault tolerance, loss descent."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data import DataConfig, FilteredTokenPipeline
from repro.models.params import Param, split_tree
from repro.models.registry import build_model
from repro.train import (CheckpointManager, OptConfig, SimulatedPreemption,
                         Trainer, TrainerConfig, adamw_update, init_opt_state,
                         make_train_step, opt_state_pspecs)
from repro.train.optimizer import lr_at
from repro.train.train_step import quantize_int8


# ---------------------------------------------------------------------------
# optimizer math vs a numpy reference
# ---------------------------------------------------------------------------
def _np_adamw(w, g, m, v, step, cfg):
    lr = float(lr_at(cfg, jnp.asarray(step)))
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    upd = (m2 / (1 - cfg.b1 ** step)) / (np.sqrt(v2 / (1 - cfg.b2 ** step)) + cfg.eps)
    return w - lr * (upd + cfg.weight_decay * w), m2, v2


def test_adamw_matches_numpy_reference():
    cfg = OptConfig(grad_clip=1e9)  # disable clipping for exact compare
    rng = np.random.default_rng(0)
    w = rng.normal(size=(32, 16)).astype(np.float32)
    g = (rng.normal(size=(32, 16)) * 0.01).astype(np.float32)
    params = {"w": Param(jnp.asarray(w), (None, None))}
    opt = init_opt_state(params)
    m = v = np.zeros_like(w)
    w_ref = w.copy()
    for step in range(1, 4):
        params, opt, _ = adamw_update(params, {"w": jnp.asarray(g)}, opt, cfg)
        w_ref, m, v = _np_adamw(w_ref, g, m, v, step, cfg)
    np.testing.assert_allclose(np.asarray(opt["master"]["w"]), w_ref,
                               rtol=1e-5, atol=1e-7)


def test_grad_clip_bounds_update():
    cfg = OptConfig(grad_clip=0.5)
    params = {"w": Param(jnp.ones((8,), jnp.float32), (None,))}
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(params, {"w": jnp.full((8,), 100.0)}, opt, cfg)
    assert float(metrics["grad_norm"]) > 0.5  # reported norm is pre-clip


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] < 0.2 and max(lrs) <= 1.0 + 1e-6
    assert abs(lrs[-1] - 0.1) < 0.02  # decays to min_lr_frac


# ---------------------------------------------------------------------------
# grad accumulation & compression
# ---------------------------------------------------------------------------
def test_grad_accum_equivalence():
    cfg = get_config("smollm_360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = FilteredTokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=32, global_batch=8,
                                            n_pool=1024, seed=1))
    batch = pipe.batch(0)
    s1 = jax.jit(make_train_step(model, OptConfig(), grad_accum=1))
    s2 = jax.jit(make_train_step(model, OptConfig(), grad_accum=4))
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    v1 = jax.tree.leaves(split_tree(p1)[0])
    v2 = jax.tree.leaves(split_tree(p2)[0])
    for a, b in zip(v1, v2):
        d = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
        assert d < 5e-2, d  # bf16 accumulation-order tolerance


def test_int8_quantization_error():
    rng = np.random.default_rng(0)
    g = rng.normal(size=(1000,)).astype(np.float32) * 0.01
    q, s = quantize_int8(jnp.asarray(g))
    rel = np.abs(np.asarray(q, np.float32) * float(s) - g).max() / np.abs(g).max()
    assert rel < 0.01


# ---------------------------------------------------------------------------
# ZeRO-1 pspecs
# ---------------------------------------------------------------------------
def test_zero1_pspecs_shard_free_dims():
    cfg = get_config("qwen3_8b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = opt_state_pspecs(params, data_size=16)
    flat = jax.tree.leaves(specs["m"], is_leaf=lambda x: isinstance(x, P))
    n_data_sharded = sum(1 for s in flat
                        if any(ax == "data" or (isinstance(ax, tuple) and "data" in ax)
                               for ax in s if ax))
    assert n_data_sharded >= len(flat) * 0.8, "ZeRO-1 should shard most leaves"
    assert specs["step"] == P()


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = {"a": jnp.arange(10, dtype=jnp.bfloat16),
                 "b": {"c": jnp.ones((3, 3), jnp.float32)},
                 "step": np.asarray(7)}
        for s in (1, 2, 3):
            mgr.save(s, state)
        assert mgr.all_steps() == [2, 3]  # gc keeps 2
        out = mgr.restore(3, state)
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.arange(10, dtype=np.float32))
        assert out["a"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        state = {"a": jnp.ones((5,), jnp.float32)}
        path = mgr.save(1, state)
        with open(os.path.join(path, "arrays.npz"), "r+b") as f:
            f.seek(60)
            f.write(b"\xde\xad")
        with pytest.raises(IOError, match="crc"):
            mgr.restore(1, state)


def test_checkpoint_tmp_dirs_ignored():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        os.makedirs(os.path.join(d, "step_00000009.tmp"))  # crashed write
        mgr.save(1, {"a": jnp.zeros((2,))})
        assert mgr.latest_step() == 1


def test_async_save():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save_async(5, {"a": jnp.ones((100, 100))})
        mgr.wait()
        assert mgr.latest_step() == 5


# ---------------------------------------------------------------------------
# trainer: descent + preemption recovery
# ---------------------------------------------------------------------------
def test_training_loss_decreases_and_preemption_resume():
    cfg = get_config("smollm_360m").reduced()
    model = build_model(cfg)
    pipe = FilteredTokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=48, global_batch=8,
                                            n_pool=2048, seed=0))
    opt = OptConfig(peak_lr=1e-3, warmup_steps=5, decay_steps=100)
    with tempfile.TemporaryDirectory() as d:
        fail = {"n": 0}

        def hook(step):
            if step == 25 and fail["n"] == 0:
                fail["n"] += 1
                raise SimulatedPreemption()

        tr = Trainer(model, pipe, opt, d, TrainerConfig(
            num_steps=35, ckpt_every=10, log_every=1), failure_hook=hook)
        tr.init_state()
        log = tr.run()
        assert fail["n"] == 1
        losses = {r["step"]: r["loss"] for r in log}
        assert losses[35] < losses[1], "loss must decrease"

        ref = Trainer(model, pipe, opt, d + "/ref", TrainerConfig(
            num_steps=35, ckpt_every=100, log_every=1))
        ref.init_state()
        ref_log = ref.run()
        ref_losses = {r["step"]: r["loss"] for r in ref_log}
        # recovery replays the exact stream: final losses bit-identical
        assert losses[35] == ref_losses[35]
