"""Elastic restart: a checkpoint written under one mesh restores onto a
DIFFERENT mesh (different device count / sharding) and training continues
bit-correctly — the multi-pod fleet's node-failure story."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models.registry import build_model, sharding_rules
    from repro.models.params import sharding_tree
    from repro.train import (CheckpointManager, OptConfig, init_opt_state,
                             make_train_step)
    from repro.train.optimizer import opt_state_pspecs
    from repro.data import DataConfig, FilteredTokenPipeline

    cfg = get_config("smollm_360m").reduced().replace(
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512)
    model = build_model(cfg)
    pipe = FilteredTokenPipeline(DataConfig(vocab_size=512, seq_len=32,
                                            global_batch=8, n_pool=1024, seed=0))
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)
    step_fn = make_train_step(model, opt_cfg)

    def shardings(mesh, dp, tp):
        rules = dict(sharding_rules(cfg, tp=tp)); rules.update(heads="model", kv_heads="model")
        ps = sharding_tree(jax.eval_shape(model.init, jax.random.PRNGKey(0)), mesh, rules)
        os_ = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           opt_state_pspecs(jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                                            rules, data_size=dp),
                           is_leaf=lambda x: isinstance(x, P))
        return ps, os_

    # --- train 3 steps on a 4x2 mesh, checkpoint --------------------------
    mesh_a = jax.make_mesh((4, 2), ("data", "model"), devices=jax.devices()[:8])
    ps_a, os_a = shardings(mesh_a, 4, 2)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)), ps_a)
    opt = jax.device_put(init_opt_state(params), os_a)
    jstep = jax.jit(step_fn)
    for s in range(3):
        params, opt, m = jstep(params, opt, pipe.batch(s))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, {"params": params, "opt": opt})

        # --- restore onto a DIFFERENT mesh (2x4: half DP, double TP) ------
        mesh_b = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices()[:8])
        ps_b, os_b = shardings(mesh_b, 2, 4)
        like = {"params": jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                "opt": jax.eval_shape(init_opt_state, jax.eval_shape(model.init, jax.random.PRNGKey(0)))}
        restored = mgr.restore(3, like, shardings={"params": ps_b, "opt": os_b})

        # continue training on mesh B; compare against mesh-A continuation
        pb, ob, mb = jstep(restored["params"], restored["opt"], pipe.batch(3))
        pa, oa, ma = jstep(params, opt, pipe.batch(3))
        la, lb = float(ma["loss"]), float(mb["loss"])
        # bf16 reduction order differs between TP widths: small tolerance
        assert abs(la - lb) / la < 1e-3, (la, lb)
        print("ELASTIC_OK", la, lb)
""")


def test_elastic_remesh_restore():
    import os
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=900, env=env, cwd=root)
    assert "ELASTIC_OK" in r.stdout, f"{r.stdout}\n{r.stderr[-3000:]}"
