"""mdrqlint: per-rule positive/negative fixtures, suppression + baseline
round-trips, and the standing assertion that the shipped tree lints clean.

Fixtures are written to tmp dirs whose layout mimics ``repro/...`` because
rules scope themselves by posix-path substring (e.g. uncounted-launch only
fires inside ``repro/kernels/`` and ``repro/core/``).
"""
from __future__ import annotations

import textwrap
from pathlib import Path

import pytest

from repro.analysis import engine
from repro.analysis.__main__ import main as lint_main
from repro.analysis.contracts import (KernelDtypeRule, KernelTileRule,
                                      NoteTraceRule)
from repro.analysis.rules import (ALL_RULES, HostSyncRule, LockDisciplineRule,
                                  RawShardMapRule, RegistryHygieneRule,
                                  SentinelRule, ThreadBoundaryRule,
                                  UncountedLaunchRule)

REPO = Path(__file__).resolve().parents[1]


def lint_one(tmp_path: Path, rel: str, source: str, rule) -> engine.Report:
    """Write ``source`` at tmp/<rel> and run a single rule over it."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return engine.run([path], [rule])


# -- rule 1: host-sync --------------------------------------------------------

def test_host_sync_flags_raw_coercions(tmp_path):
    rep = lint_one(tmp_path, "repro/core/bad_sync.py", """\
        import jax
        import numpy as np
        from repro.kernels import ops

        def leaky(q):
            out = ops.multi_scan_reduce(q)        # device-value source
            jax.device_get(out)                   # raw sync API
            return float(out)                     # raw coercion sink
        """, HostSyncRule())
    rules = [f.rule for f in rep.active]
    assert rules == ["host-sync", "host-sync"]
    assert "jax.device_get" in rep.active[0].message
    assert "float()" in rep.active[1].message


def test_host_sync_accepts_counted_device_get(tmp_path):
    rep = lint_one(tmp_path, "repro/core/good_sync.py", """\
        from repro.kernels import ops

        def clean(q):
            out = ops.multi_scan_reduce(q)
            host = ops.device_get(out)            # the counted sync
            return float(host)                    # host value: not a sync
        """, HostSyncRule())
    assert rep.active == []


def test_host_sync_tracks_taint_through_helpers(tmp_path):
    # _launch returns a device value; the caller's np.asarray is the sync
    rep = lint_one(tmp_path, "repro/core/chained.py", """\
        import numpy as np
        from repro.kernels import ops

        def _launch(q):
            return ops.multi_scan_reduce(q)

        def caller(q):
            return np.asarray(_launch(q))
        """, HostSyncRule())
    assert [f.rule for f in rep.active] == ["host-sync"]
    assert "asarray" in rep.active[0].message


# -- rule 2: uncounted-launch -------------------------------------------------

def test_uncounted_launch_flags_bare_jit(tmp_path):
    rep = lint_one(tmp_path, "repro/kernels/bad_jit.py", """\
        import jax

        @jax.jit
        def fast(x):
            return x + 1

        faster = jax.jit(fast)
        """, UncountedLaunchRule())
    msgs = [f.message for f in rep.active]
    assert len(msgs) == 2
    assert any("'fast'" in m for m in msgs)
    assert any("'faster'" in m for m in msgs)


def test_uncounted_launch_accepts_registered(tmp_path):
    rep = lint_one(tmp_path, "repro/kernels/good_jit.py", """\
        import jax
        from repro.kernels import ops

        @jax.jit
        def _fast_jit(x):
            return x + 1

        fast = ops.counted("fast", "Example counted entry point.")(_fast_jit)
        """, UncountedLaunchRule())
    assert rep.active == []


def test_uncounted_launch_scoped_to_kernels_and_core(tmp_path):
    # a jit in obs/ is not an engine entry point; the rule stays quiet
    rep = lint_one(tmp_path, "repro/obs/free_jit.py", """\
        import jax

        @jax.jit
        def helper(x):
            return x * 2
        """, UncountedLaunchRule())
    assert rep.active == []


# -- rule 3: raw-shard-map ----------------------------------------------------

def test_raw_shard_map_flagged(tmp_path):
    rep = lint_one(tmp_path, "repro/core/bad_dist.py", """\
        from jax.experimental.shard_map import shard_map

        def spread(f, mesh):
            return shard_map(f, mesh=mesh)
        """, RawShardMapRule())
    assert [f.rule for f in rep.active] == ["raw-shard-map"]
    assert "shard_map_compat" in rep.active[0].message


def test_shard_map_compat_accepted(tmp_path):
    rep = lint_one(tmp_path, "repro/core/good_dist.py", """\
        from repro.core.distributed import shard_map_compat

        def spread(f, mesh):
            return shard_map_compat(f, mesh=mesh)
        """, RawShardMapRule())
    assert rep.active == []


# -- rule 4: sentinel ---------------------------------------------------------

def test_sentinel_flags_f32_scale_literals_and_blind_inf_casts(tmp_path):
    rep = lint_one(tmp_path, "repro/models/bad_mask.py", """\
        import jax.numpy as jnp
        import numpy as np

        NEG = -3.0e38                       # rounds to -inf under bf16
        pad = jnp.full((4,), np.inf)        # inf into an unknown dtype
        """, SentinelRule())
    rules = [f.rule for f in rep.active]
    assert rules == ["sentinel", "sentinel"]
    assert "bf16" in rep.active[0].message


def test_sentinel_accepts_numerics_and_explicit_wide_dtypes(tmp_path):
    rep = lint_one(tmp_path, "repro/models/good_mask.py", """\
        import jax.numpy as jnp
        import numpy as np
        from repro import numerics

        NEG = numerics.mask_fill(jnp.bfloat16)
        cost = np.full((4,), np.inf, np.float64)   # f64 inf is exact
        """, SentinelRule())
    assert rep.active == []


# -- rule 5: lock-discipline --------------------------------------------------

def test_lock_discipline_flags_off_lock_write(tmp_path):
    rep = lint_one(tmp_path, "repro/core/bad_lock.py", """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0              # __init__ is exempt

            def add(self):
                with self._lock:
                    self.count += 1

            def reset(self):
                self.count = 0              # off-lock write to guarded attr
        """, LockDisciplineRule())
    assert [f.rule for f in rep.active] == ["lock-discipline"]
    assert "Counter.count" in rep.active[0].message


def test_lock_discipline_accepts_guarded_writes(tmp_path):
    rep = lint_one(tmp_path, "repro/core/good_lock.py", """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0

            def add(self):
                with self._lock:
                    self.count += 1

            def reset(self):
                with self._lock:
                    self.count = 0
        """, LockDisciplineRule())
    assert rep.active == []


def test_lock_discipline_flags_state_mutation_and_off_lock_swap(tmp_path):
    rep = lint_one(tmp_path, "repro/core/bad_state.py", """\
        class Engine:
            def patch(self, cols):
                self._state.cols = cols     # in-place mutation

            def swap(self, new):
                self._state = new           # swap outside the ingest lock
        """, LockDisciplineRule())
    msgs = [f.message for f in rep.active]
    assert len(msgs) == 2
    assert any("in-place" in m for m in msgs)
    assert any("ingest lock" in m for m in msgs)


def test_lock_discipline_accepts_single_swap_under_ingest_lock(tmp_path):
    rep = lint_one(tmp_path, "repro/core/good_state.py", """\
        class Engine:
            def swap(self, new):
                with self._ingest_lock:
                    self._state = new
        """, LockDisciplineRule())
    assert rep.active == []


# -- rule 6: registry-hygiene -------------------------------------------------

def test_registry_hygiene_flags_non_frozen_and_mutable_default(tmp_path):
    rep = lint_one(tmp_path, "repro/core/bad_spec.py", """\
        from repro.core.types import register_result_spec

        @register_result_spec
        class Sloppy:
            cache = []
        """, RegistryHygieneRule())
    msgs = [f.message for f in rep.active]
    assert len(msgs) == 2
    assert any("frozen dataclass" in m for m in msgs)
    assert any("mutable class-level default" in m for m in msgs)


def test_registry_hygiene_accepts_frozen_dataclass(tmp_path):
    rep = lint_one(tmp_path, "repro/core/good_spec.py", """\
        import dataclasses

        from repro.core.types import register_result_spec

        @register_result_spec
        @dataclasses.dataclass(frozen=True)
        class Tidy:
            k: int = 4
            dims: tuple = ()
        """, RegistryHygieneRule())
    assert rep.active == []


# -- suppressions and baseline ------------------------------------------------

def test_inline_suppression_moves_finding_out_of_active(tmp_path):
    rep = lint_one(tmp_path, "repro/models/sup.py", """\
        NEG = -3.0e38  # mdrqlint: disable=sentinel
        POS = 3.0e38   # mdrqlint: disable=all
        """, SentinelRule())
    assert rep.active == []
    assert [f.rule for f in rep.suppressed] == ["sentinel", "sentinel"]
    assert rep.exit_code == 0


def test_baseline_round_trip(tmp_path):
    path = tmp_path / "repro" / "models" / "legacy.py"
    path.parent.mkdir(parents=True)
    path.write_text("OLD = -3.0e38\n")

    first = engine.run([path], [SentinelRule()])
    assert first.exit_code == 1 and len(first.active) == 1

    bl = tmp_path / "baseline.json"
    engine.write_baseline(first, bl)
    accepted = engine.load_baseline(bl)
    assert accepted == {first.active[0].baseline_key()}

    second = engine.run([path], [SentinelRule()], baseline=accepted)
    assert second.exit_code == 0
    assert second.active == [] and len(second.baselined) == 1

    # baseline keys carry no line numbers, so entries survive line drift
    path.write_text("# a new leading comment\nOLD = -3.0e38\n")
    third = engine.run([path], [SentinelRule()], baseline=accepted)
    assert third.exit_code == 0 and len(third.baselined) == 1


def test_cli_baseline_flags_round_trip(tmp_path, capsys):
    path = tmp_path / "repro" / "models" / "legacy.py"
    path.parent.mkdir(parents=True)
    path.write_text("OLD = -3.0e38\n")
    bl = tmp_path / "bl.json"

    assert lint_main([str(path), "--baseline", str(bl)]) == 1
    assert lint_main([str(path), "--baseline", str(bl),
                      "--write-baseline"]) == 0
    assert lint_main([str(path), "--baseline", str(bl)]) == 0
    out = capsys.readouterr().out
    assert "1 baselined" in out


def test_report_format_and_json(tmp_path):
    path = tmp_path / "repro" / "models" / "m.py"
    path.parent.mkdir(parents=True)
    path.write_text("NEG = -3.0e38\n")
    rep = engine.run([path], [SentinelRule()])
    line = rep.active[0].format()
    assert line.startswith(path.as_posix() + ":1 sentinel ")
    data = rep.to_json()
    assert data["n_files"] == 1
    assert data["findings"][0]["rule"] == "sentinel"


def test_parse_error_exits_2_not_1(tmp_path):
    """Broken tree != dirty tree: parse errors get their own exit code."""
    path = tmp_path / "repro" / "core" / "broken.py"
    path.parent.mkdir(parents=True)
    path.write_text("def oops(:\n")
    rep = engine.run([path], ALL_RULES)
    assert rep.exit_code == 2
    assert rep.active == []
    assert rep.errors[0].rule == "parse-error"


def test_multi_rule_suppression_comma_separated(tmp_path):
    src = """\
        import numpy as np
        from repro.kernels import ops

        def peek(x):
            return np.asarray(ops.range_scan(x)), -3.0e38  # mdrqlint: disable=host-sync,sentinel
        """
    path = tmp_path / "repro" / "core" / "multi.py"
    path.parent.mkdir(parents=True)
    path.write_text(textwrap.dedent(src))
    rep = engine.run([path], ALL_RULES)
    assert rep.active == []
    assert sorted({f.rule for f in rep.suppressed}) == ["host-sync",
                                                        "sentinel"]


def test_stale_baseline_fails_and_prune_drops_it(tmp_path, capsys):
    path = tmp_path / "repro" / "models" / "legacy.py"
    path.parent.mkdir(parents=True)
    path.write_text("OLD = -3.0e38\n")
    bl = tmp_path / "bl.json"
    assert lint_main([str(path), "--baseline", str(bl),
                      "--write-baseline"]) == 0

    # debt paid: the finding is gone, but its waiver lingers -> exit 1
    path.write_text("OLD = 0.0\n")
    assert lint_main([str(path), "--baseline", str(bl)]) == 1
    assert "stale baseline entry" in capsys.readouterr().out

    assert lint_main([str(path), "--baseline", str(bl),
                      "--prune-baseline"]) == 0
    assert engine.load_baseline(bl) == set()
    assert lint_main([str(path), "--baseline", str(bl)]) == 0


# -- rule 7: thread-boundary --------------------------------------------------

def test_thread_boundary_flags_sync_and_parked_payloads(tmp_path):
    rep = lint_one(tmp_path, "repro/serve/bad_pipeline.py", """\
        from repro.kernels import ops
        from repro.serve.pipeline import device_stage

        class BadServer:
            @device_stage
            def flush(self):
                pb = self.engine.launch_batch(self._queries)
                host = ops.device_get(pb)       # sync on the wrong thread
                self._inflight = pb             # parked device value
                return host
        """, ThreadBoundaryRule())
    rules = [f.rule for f in rep.active]
    assert rules == ["thread-boundary", "thread-boundary"]
    assert "device_get" in rep.active[0].message
    assert "_inflight" in rep.active[1].message


def test_thread_boundary_taint_rides_wrappers(tmp_path):
    """A device payload wrapped in a window object is still a device value —
    parking the wrapper on self is the same cross-thread leak."""
    rep = lint_one(tmp_path, "repro/serve/bad_window.py", """\
        from repro.serve.pipeline import device_stage

        class BadServer:
            @device_stage
            def flush(self):
                pb = self.engine.launch_batch(self._queries)
                win = _Window(batch=pb, reason="size")
                self._last_window = win
        """, ThreadBoundaryRule())
    assert [f.rule for f in rep.active] == ["thread-boundary"]
    assert "_last_window" in rep.active[0].message


def test_thread_boundary_accepts_queue_handoff(tmp_path):
    """The sanctioned shape: the payload crosses via the backlog queue, and
    the finalizer stage owns the counted sync."""
    rep = lint_one(tmp_path, "repro/serve/good_pipeline.py", """\
        from repro.kernels import ops
        from repro.serve.pipeline import device_stage, finalizer_stage

        class GoodServer:
            @device_stage
            def flush(self):
                pb = self.engine.launch_batch(self._queries)
                win = _Window(batch=pb, reason="size")
                self._backlog.put(win)          # the one sanctioned crossing
                self.stats.n_flushes = self.stats.n_flushes + 1  # host data

            @finalizer_stage
            def _finalize_loop(self):
                win = self._backlog.get()
                host = ops.device_get(win.batch)  # finalizer owns the sync
                return host
        """, ThreadBoundaryRule())
    assert rep.active == []


# -- rules 8-10: Pallas kernel contracts --------------------------------------

def test_kernel_tile_flags_unasserted_grid_division(tmp_path):
    rep = lint_one(tmp_path, "repro/kernels/tiles.py", """\
        import jax.experimental.pallas as pl

        def launch(x, tile):
            return pl.pallas_call(kern, grid=(x.shape[0] // tile,))(x)
        """, KernelTileRule())
    assert [f.rule for f in rep.active] == ["kernel-tile"]
    assert "x.shape[0] // tile" in rep.active[0].message


def test_kernel_tile_accepts_asserted_grid_and_local_assign(tmp_path):
    rep = lint_one(tmp_path, "repro/kernels/tiles.py", """\
        import jax.experimental.pallas as pl

        def launch(x, tile):
            assert x.shape[0] % tile == 0, "pad first"
            grid = (x.shape[0] // tile,)
            return pl.pallas_call(kern, grid=grid)(x)
        """, KernelTileRule())
    assert rep.active == []


def test_kernel_dtype_flags_defaulted_creator_and_inf_fill(tmp_path):
    rep = lint_one(tmp_path, "repro/kernels/accum.py", """\
        import jax.numpy as jnp
        import jax.experimental.pallas as pl

        def kern(x_ref, o_ref):
            acc = jnp.zeros((8, 8))
            pad = jnp.full((8,), -jnp.inf, dtype=jnp.bfloat16)
            o_ref[...] = acc + pad

        def launch(x):
            return pl.pallas_call(kern, grid=(1,))(x)
        """, KernelDtypeRule())
    assert sorted(f.rule for f in rep.active) == ["kernel-dtype",
                                                  "kernel-dtype"]


def test_kernel_dtype_accepts_explicit_and_outside_kernel(tmp_path):
    rep = lint_one(tmp_path, "repro/kernels/accum.py", """\
        import functools
        import jax.numpy as jnp
        import jax.experimental.pallas as pl

        def kern(x_ref, o_ref, *, tile):
            acc = jnp.zeros((8, 8), jnp.float32)
            pad = jnp.full((8,), -jnp.inf, dtype=jnp.float32)
            o_ref[...] = acc + pad

        def launch(x):
            host_side = jnp.zeros((4,))  # not a kernel body: exempt
            return pl.pallas_call(
                functools.partial(kern, tile=8), grid=(1,))(x)
        """, KernelDtypeRule())
    assert rep.active == []


def test_note_trace_flags_jit_without_probe(tmp_path):
    rep = lint_one(tmp_path, "repro/core/jitted.py", """\
        import jax
        from repro.kernels import ops

        @jax.jit
        def silent(x):
            return x + 1

        def _loud(x):
            ops.note_trace("loud")
            return x + 1

        loud = jax.jit(_loud)
        """, NoteTraceRule())
    assert [f.rule for f in rep.active] == ["note-trace"]
    assert "silent" in rep.active[0].message


def test_note_trace_accepts_probe_after_docstring(tmp_path):
    rep = lint_one(tmp_path, "repro/core/jitted.py", """\
        import jax
        from repro.kernels import ops

        @jax.jit
        def fine(x):
            '''Docstrings don't count as the first statement.'''
            ops.note_trace("fine")
            return x + 1
        """, NoteTraceRule())
    assert rep.active == []


# -- the shipped tree lints clean ---------------------------------------------

def test_shipped_tree_is_clean():
    """src/, tests/, benchmarks/ and examples/ carry no active findings
    under the checked-in baseline — the same invocation CI runs via
    ``make lint-mdrq``."""
    rc = lint_main([str(REPO / p)
                    for p in ("src", "tests", "benchmarks", "examples")])
    assert rc == 0


def test_all_rules_have_ids_and_docs():
    ids = [r.rule_id for r in ALL_RULES]
    assert len(ids) == len(set(ids)) == 10
    assert all(r.doc for r in ALL_RULES)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
