"""Pipelined MDRQ serving: AOT warmup discipline, double-buffered execution,
admission control, fault isolation, and stats accounting under overlap.

(``test_pipeline_serve.py`` covers the *data* pipeline; this file covers
``repro.serve.pipeline`` — the MDRQ serving pipeline of DESIGN.md §13.)
"""
import os
import time

import numpy as np
import pytest

from repro.core import (Count, Dataset, MDRQEngine, TopK, match_ids_np)
from repro.core import engine as engine_mod
from repro.data import synthetic
from repro.kernels import ops
from repro.serve import MDRQServer, Overloaded, serve_pipelined


@pytest.fixture(autouse=True)
def clean_aot():
    """conftest's ``reset_metrics`` zeroes counters/registry but deliberately
    leaves the AOT cache and trace log alone — warmup/retrace assertions here
    need both pristine per test."""
    ops.clear_aot_cache()
    ops.reset_trace_log()
    yield
    ops.clear_aot_cache()
    ops.reset_trace_log()


@pytest.fixture(scope="module")
def ds():
    rng = np.random.default_rng(7)
    return Dataset(rng.random((4, 6_000), dtype=np.float32))


def _queries(ds, n, seed=0):
    return synthetic.workload(ds, n, seed=seed)


# -- equivalence ------------------------------------------------------------

@pytest.mark.parametrize("spec", [None, Count(), TopK(k=3, dim=1)],
                         ids=["ids", "count", "topk"])
def test_pipelined_matches_sync_and_oracle(ds, spec):
    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"), tile_n=512)
    qs = _queries(ds, 30, seed=1)
    sync = MDRQServer(eng, max_batch=8, max_wait_s=float("inf"), spec=spec)
    expected = sync.serve_all(qs)
    with serve_pipelined(eng, max_batch=8, max_wait_s=float("inf"),
                         spec=spec, warmup=False,
                         latency_budget_s=1e9) as srv:
        got = srv.serve_all(qs)
        srv.drain()
    assert len(got) == len(expected)
    for g, e, q in zip(got, expected, qs):
        if spec is None:
            np.testing.assert_array_equal(g, match_ids_np(ds.cols, q))
        if isinstance(e, np.ndarray):
            np.testing.assert_array_equal(g, e)
        else:
            assert g == e


def test_pipelined_explicit_paths_match_oracle(ds):
    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"), tile_n=512)
    qs = _queries(ds, 12, seed=2)
    for method in ("scan", "scan_vertical", "kdtree", "vafile"):
        with serve_pipelined(eng, max_batch=4, max_wait_s=float("inf"),
                             method=method, warmup=False,
                         latency_budget_s=1e9) as srv:
            got = srv.serve_all(qs)
            srv.drain()
        for g, q in zip(got, qs):
            np.testing.assert_array_equal(g, match_ids_np(ds.cols, q))


# -- AOT warmup discipline --------------------------------------------------

def test_warmup_compiles_exactly_the_advertised_set(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    with serve_pipelined(eng, max_batch=8, max_wait_s=float("inf"),
                         method="scan", warmup=True,
                         latency_budget_s=1e9) as srv:
        rep = srv.last_warmup
        assert rep is not None
        assert rep.paths == ("scan",)
        assert rep.bucket_sizes == (1, 2, 4, 8)
        # the cache was empty before construction (clean_aot fixture): the
        # advertised key set IS the cache
        assert set(rep.keys) == set(ops.aot_cache_keys())
        assert rep.n_compiled == len(rep.keys) == ops.aot_cache_size() > 0
        # idempotent: a second pass advertises the same set, compiles nothing
        rep2 = srv.warmup()
        assert rep2.n_compiled == 0
        assert rep2.bucket_sizes == rep.bucket_sizes


def test_zero_retraces_after_warmup(ds):
    """The tentpole guarantee: post-warmup steady state never (re)traces —
    every jitted-op trace probe stays silent and no AOT lookup misses."""
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    with serve_pipelined(eng, max_batch=8, max_wait_s=float("inf"),
                         method="scan", warmup=True,
                         latency_budget_s=1e9) as srv:
        ops.reset_trace_log()
        srv.serve_all(_queries(ds, 25, seed=3))  # windows of 8, 8, 8, 1
        srv.drain()
        assert ops.trace_log() == ()
        aot = ops.aot_counters()
        assert aot.get("miss", 0) == 0
        assert aot.get("hit", 0) > 0


def test_set_backend_invalidates_aot_cache(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    with serve_pipelined(eng, max_batch=2, max_wait_s=float("inf"),
                         method="scan", warmup=True,
                         latency_budget_s=1e9):
        assert ops.aot_cache_size() > 0
        target = "xla" if not ops.use_xla() else "auto"
        prev = ops.set_backend(target)
        try:
            # stale executables would silently serve the old backend
            assert ops.aot_cache_size() == 0
        finally:
            ops.set_backend(prev)


# -- launch / host-sync budgets under the split -----------------------------

def test_pipelined_budget_one_launch_one_sync_per_window(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    qs = _queries(ds, 24, seed=4)
    with serve_pipelined(eng, max_batch=8, max_wait_s=float("inf"),
                         method="scan", warmup=True,
                         latency_budget_s=1e9) as srv:
        ops.reset_counters()  # drop warmup traffic; count serving only
        srv.serve_all(qs)     # three full windows of 8
        srv.drain()
        assert ops.counters() == {"multi_scan_reduce": 3, "host_sync": 3}
        assert srv.stats.n_batches == 3


# -- admission control ------------------------------------------------------

def test_overloaded_shed_and_recovery(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    qs = _queries(ds, 8, seed=5)
    with serve_pipelined(eng, max_batch=4, max_wait_s=float("inf"),
                         method="scan", warmup=False,
                         latency_budget_s=100.0) as srv:
        # cold start never sheds (EWMA unknown), even with a zero budget
        srv.latency_budget_s = 0.0
        t = srv.submit(qs[0])
        assert not t.shed
        srv.latency_budget_s = 100.0
        for q in qs[1:4]:
            srv.submit(q)          # window of 4 flushes (reason="size")
        srv.drain()                # EWMA now primed
        # backlog drain estimate now exceeds a zero budget -> shed
        srv.latency_budget_s = 0.0
        shed = srv.submit(qs[4])
        assert shed.shed
        assert srv.n_pending == 0  # shed queries never enter the window
        with pytest.raises(Overloaded):
            shed.result()
        assert srv.stats.shed_counts == {"overloaded": 1}
        # recovery: a sane budget admits again and serves correctly
        srv.latency_budget_s = 100.0
        ok = srv.submit(qs[5])
        srv.flush()
        np.testing.assert_array_equal(ok.result(),
                                      match_ids_np(ds.cols, qs[5]))


# -- fault isolation --------------------------------------------------------

def test_finalizer_fault_poisons_only_its_window(ds, monkeypatch):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    qs = _queries(ds, 8, seed=6)
    orig = engine_mod.PendingBatch.finalize
    calls = []

    def flaky_finalize(self):
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("injected finalize failure")
        return orig(self)

    monkeypatch.setattr(engine_mod.PendingBatch, "finalize", flaky_finalize)
    with serve_pipelined(eng, max_batch=4, max_wait_s=float("inf"),
                         method="scan", warmup=False,
                         latency_budget_s=1e9) as srv:
        first = [srv.submit(q) for q in qs[:4]]    # window 1: poisoned
        second = [srv.submit(q) for q in qs[4:]]   # window 2: healthy
        srv.drain()
        # every ticket resolves or re-raises — none hangs
        for t in first:
            with pytest.raises(RuntimeError, match="injected finalize"):
                t.result(timeout=5.0)
        for t, q in zip(second, qs[4:]):
            np.testing.assert_array_equal(t.result(timeout=5.0),
                                          match_ids_np(ds.cols, q))
        # the poisoned window contributed no stats; the healthy one did
        assert srv.stats.n_queries == 4
        assert srv.stats.n_batches == 1


def test_launch_failure_requeues_window_in_order(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    qs = _queries(ds, 3, seed=7)
    with serve_pipelined(eng, max_batch=8, max_wait_s=float("inf"),
                         method="scan", warmup=False,
                         latency_budget_s=1e9) as srv:
        tickets = [srv.submit(q) for q in qs]
        orig = eng.launch_batch

        def boom(*a, **k):
            raise RuntimeError("injected launch failure")

        eng.launch_batch = boom
        try:
            with pytest.raises(RuntimeError, match="injected launch"):
                srv.flush()
        finally:
            eng.launch_batch = orig
        # window restored in submission order, deadline clock re-anchored
        assert [t for _, t, _ in srv._pending] == tickets
        assert srv._oldest_t == srv._pending[0][2]
        # tickets stay resolvable once the engine recovers
        srv.flush()
        srv.drain()
        for t, q in zip(tickets, qs):
            np.testing.assert_array_equal(t.result(timeout=5.0),
                                          match_ids_np(ds.cols, q))


# -- stats under overlap ----------------------------------------------------

def test_stats_are_wall_clock_anchored(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    qs = _queries(ds, 20, seed=8)
    with serve_pipelined(eng, max_batch=8, max_wait_s=float("inf"),
                         method="scan", warmup=False,
                         latency_budget_s=1e9) as srv:
        srv.serve_all(qs)
        srv.drain()
        st = srv.stats
        assert st.n_queries == 20 and st.n_batches == 3
        assert st.wall_seconds > 0.0
        assert st.finalize_seconds > 0.0
        assert st.busy_seconds > 0.0
        # qps divides by wall clock, not by the (overlapping) stage sum
        assert st.qps == pytest.approx(st.n_queries / st.wall_seconds)
        pct = st.latency_percentiles("ids")
        assert pct["queue"] and pct["execute"]
        # per-query execute latency is the device-stage wall, bounded by the
        # whole-window busy time (it excludes the finalize stage)
        assert pct["execute"]["p99"] <= st.busy_seconds


# -- serve-while-ingest across the pipeline ---------------------------------

def test_inflight_window_snapshot_survives_ingest_and_compact(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    qs = _queries(ds, 5, seed=9)
    rng = np.random.default_rng(10)
    new_rows = rng.random((64, ds.m), dtype=np.float32)
    with serve_pipelined(eng, max_batch=8, max_wait_s=float("inf"),
                         method="scan", warmup=False,
                         latency_budget_s=1e9) as srv:
        before = [srv.submit(q) for q in qs]
        srv.flush()                 # window launches against the pre-append
        srv.append(new_rows)        # snapshot while (possibly) in flight
        after = [srv.submit(q) for q in qs]
        srv.drain()
        for t, q in zip(before, qs):
            np.testing.assert_array_equal(t.result(timeout=5.0),
                                          match_ids_np(ds.cols, q))
        expected_after = eng.query_batch(qs, method="scan")
        for t, e in zip(after, expected_after):
            np.testing.assert_array_equal(t.result(timeout=5.0), e)
        # compact swaps the engine version; serving stays correct after
        srv.compact()
        got = srv.serve_all(qs)
        srv.drain()
        expected = eng.query_batch(qs, method="scan")
        for g, e in zip(got, expected):
            np.testing.assert_array_equal(g, e)


def test_compact_rewarms_the_aot_cache(ds):
    eng = MDRQEngine(ds, structures=("scan",), tile_n=512)
    with serve_pipelined(eng, max_batch=2, max_wait_s=float("inf"),
                         method="scan", warmup=True,
                         latency_budget_s=1e9) as srv:
        first = srv.last_warmup
        eng.append(np.random.default_rng(11).random(
            (2048, ds.m), dtype=np.float32))  # force a real shape change
        srv.compact()
        assert srv.last_warmup is not first  # warmup re-ran
        # the re-warm covered the new shapes: serving stays retrace-free
        ops.reset_trace_log()
        srv.serve_all(_queries(ds, 4, seed=12))
        srv.drain()
        assert ops.trace_log() == ()


# -- throughput: the point of the exercise ----------------------------------

def test_pipelined_sustains_higher_qps_than_sync(ds):
    """Head-to-head at B=128 on the CPU XLA proxy. With >1 core the overlap
    must win by a real margin; the single-core CI proxy can't overlap, so
    there we only bound the pipeline's overhead (the honest curve lives in
    BENCH_pipeline.json)."""
    prev = ops.set_backend("xla")
    try:
        rng = np.random.default_rng(13)
        big = Dataset(rng.random((4, 40_000), dtype=np.float32))
        eng = MDRQEngine(big, structures=("scan",), tile_n=2048)
        qs = _queries(big, 512, seed=14)

        def run_sync():
            srv = MDRQServer(eng, max_batch=128, max_wait_s=float("inf"),
                             method="scan")
            t0 = time.perf_counter()
            srv.serve_all(qs)
            return time.perf_counter() - t0

        def run_pipelined():
            with serve_pipelined(eng, max_batch=128,
                                 max_wait_s=float("inf"), method="scan",
                                 warmup=True, backlog=4,
                                 latency_budget_s=1e9) as srv:
                t0 = time.perf_counter()
                srv.serve_all(qs)
                srv.drain()
                return time.perf_counter() - t0

        run_sync()  # compile + cache warm for the sync path
        sync_s = min(run_sync(), run_sync())
        pipe_s = min(run_pipelined(), run_pipelined())
        if len(os.sched_getaffinity(0)) > 1:
            assert pipe_s < sync_s / 1.05, (pipe_s, sync_s)
        else:
            # no parallelism to exploit: just bound the pipeline overhead
            assert pipe_s < sync_s * 1.67, (pipe_s, sync_s)
    finally:
        ops.set_backend(prev)
