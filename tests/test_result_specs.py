"""The ResultSpec layer: every spec x every registered access path vs the
numpy oracle, launch/host-sync budgets, the mode-string back-compat shim,
and spec-dependent planning.

Covers the acceptance axes of the redesign: (a) ``Ids``/``Count``/``Mask``/
``TopK``/``Agg`` agree with the oracle on random and GMRQB batches across
*all* registered paths (``Count() == len(Ids())``, top-k ids are a value-
ordered subset of the id set, aggregates match ``np.min/max/sum`` over it);
(b) reduced shapes run as one fused reduce launch + one host sync per batch
(counter-asserted); (c) the legacy ``mode=`` strings map to specs through
``validate_mode`` with one DeprecationWarning and unknown modes keep the one
canonical error; (d) ``Planner.plan_batch`` produces spec-dependent plans —
a query's chosen path differs between ``Ids()`` and ``Count()``/``Agg``.
"""
import warnings

import numpy as np
import pytest

from repro.core import (Agg, Count, Dataset, Ids, Mask, MDRQEngine,
                        QueryBatch, RangeQuery, TopK, match_ids_np,
                        register_result_spec, resolve_spec, validate_mode)
from repro.core.planner import CostModel, Histograms, Planner
from repro.core.types import RESULT_SPEC_KINDS, ResultSpec
from repro.kernels import ops

SPECS = (Ids(), Count(), Mask(), TopK(k=4, dim=2), TopK(k=3, dim=1, largest=False),
         Agg("sum", 3), Agg("min", 0), Agg("max", 4))


def _mixed_queries(cols, rng, n_q):
    """Complete + partial + point + empty-range + match-all queries."""
    m = cols.shape[0]
    out = []
    for k in range(n_q):
        if k % 2 == 0:
            a = cols[:, rng.integers(cols.shape[1])]
            b = cols[:, rng.integers(cols.shape[1])]
            out.append(RangeQuery.complete(np.minimum(a, b), np.maximum(a, b)))
        else:
            dims = rng.choice(m, size=int(rng.integers(1, m + 1)), replace=False)
            preds = {int(d): tuple(sorted(rng.random(2).tolist())) for d in dims}
            out.append(RangeQuery.partial(m, preds))
    out.append(RangeQuery.partial(m, {0: (2.0, 3.0)}))  # empty result set
    out.append(RangeQuery.partial(m, {}))               # match-all
    rec = cols[:, 11]
    out.append(RangeQuery.complete(rec, rec))           # point query
    return out


def _check_spec(spec, res, ids, cols):
    """One query's result under ``spec`` vs the oracle id set."""
    if spec.kind == "ids":
        np.testing.assert_array_equal(res, ids)
    elif spec.kind == "count":
        assert isinstance(res, int) and res == ids.size
    elif spec.kind == "mask":
        assert res.dtype == bool and res.shape == (cols.shape[1],)
        np.testing.assert_array_equal(np.nonzero(res)[0], ids)
    elif spec.kind == "topk":
        # subset of the id set, correct length, and value-ordered; compare
        # value sequences (not raw ids) so attribute ties stay well-defined
        assert set(res.tolist()) <= set(ids.tolist())
        assert res.size == min(spec.k, ids.size)
        got = cols[spec.dim, res]
        vals = cols[spec.dim, ids]
        order = np.argsort(-vals if spec.largest else vals, kind="stable")
        np.testing.assert_allclose(got, vals[order[: spec.k]], rtol=1e-6)
        step = np.diff(got)
        assert np.all(step <= 1e-6) if spec.largest else np.all(step >= -1e-6)
    elif spec.kind == "agg":
        if ids.size == 0:
            assert res == 0.0 if spec.op == "sum" else np.isnan(res)
        else:
            vals = cols[spec.dim, ids]
            exp = {"min": np.min, "max": np.max,
                   "sum": lambda v: np.sum(v, dtype=np.float64)}[spec.op](vals)
            assert np.isclose(res, exp, rtol=1e-4), (res, exp)
    else:
        raise AssertionError(spec.kind)


@pytest.fixture(scope="module")
def eng_all(uni5):
    return MDRQEngine(uni5, tile_n=512, rowscan=True)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: repr(s))
def test_specs_vs_oracle_all_paths_random(spec, eng_all, uni5):
    """Every registered path serves every spec, matching the oracle — the
    registry loop means a future registered path is covered by adding
    nothing here."""
    rng = np.random.default_rng(5)
    queries = _mixed_queries(uni5.cols, rng, 6)
    for name in eng_all.paths:
        res = eng_all.query_batch(queries, method=name, spec=spec)
        for q, r in zip(queries, res):
            _check_spec(spec, r, match_ids_np(uni5.cols, q), uni5.cols)
        # single-query entry agrees with the batch
        r1 = eng_all.query(queries[0], method=name, spec=spec)
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(res[0]))


@pytest.mark.parametrize("spec", [Ids(), Count(), TopK(k=5, dim=2),
                                  Agg("sum", 1), Agg("max", 17)],
                         ids=lambda s: repr(s))
def test_specs_vs_oracle_gmrqb(spec):
    """GMRQB template batches (19 dims, point/categorical predicates — heavy
    attribute ties) through every plannable path and "auto"."""
    from repro.data import gmrqb

    ds = gmrqb.build(8192, seed=5)
    eng = MDRQEngine(ds, tile_n=1024)
    rng = np.random.default_rng(11)
    queries = [gmrqb.template(k, rng, ds) for k in (1, 2, 4, 5, 7, 8)]
    for name in list(eng.paths) + ["auto"]:
        res = eng.query_batch(queries, method=name, spec=spec)
        for q, r in zip(queries, res):
            _check_spec(spec, r, match_ids_np(ds.cols, q), ds.cols)


def test_count_equals_len_ids_everywhere(eng_all, uni5):
    rng = np.random.default_rng(7)
    queries = _mixed_queries(uni5.cols, rng, 4)
    for name in list(eng_all.paths) + ["auto"]:
        ids = eng_all.query_batch(queries, method=name, spec=Ids())
        counts = eng_all.query_batch(queries, method=name, spec=Count())
        assert counts == [i.size for i in ids], name


# -- property sweep (seeded always; hypothesis-driven when installed) ---------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _property_case(seed, k, dim, largest, op, ds, eng):
    """One drawn case: random batch x random spec params, every path."""
    rng = np.random.default_rng(seed)
    queries = _mixed_queries(ds.cols, rng, 3)
    oracle = [match_ids_np(ds.cols, q) for q in queries]
    for spec in (Count(), TopK(k=k, dim=dim, largest=largest), Agg(op, dim)):
        for name in eng.paths:
            res = eng.query_batch(queries, method=name, spec=spec)
            for q, r, ids in zip(queries, res, oracle):
                _check_spec(spec, r, ids, ds.cols)


def test_property_specs_match_oracle_seeded(eng_all, uni5):
    """Deterministic sweep of the property: Count() == len(Ids()), TopK is a
    value-ordered subset, Agg matches the numpy reduction over the id set —
    across all registered paths."""
    rng = np.random.default_rng(99)
    for _ in range(4):
        _property_case(int(rng.integers(2**16)), int(rng.integers(1, 9)),
                       int(rng.integers(5)), bool(rng.integers(2)),
                       ("min", "max", "sum")[int(rng.integers(3))],
                       uni5, eng_all)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2**16), k=st.integers(1, 9),
           dim=st.integers(0, 4), largest=st.booleans(),
           op=st.sampled_from(["min", "max", "sum"]))
    def test_property_specs_match_oracle(seed, k, dim, largest, op, uni5,
                                         eng_all):
        _property_case(seed, k, dim, largest, op, uni5, eng_all)


# -- launch / host-sync budgets ----------------------------------------------

@pytest.mark.parametrize("spec", [TopK(k=4, dim=2), Agg("sum", 1), Count()],
                         ids=lambda s: s.kind)
def test_reduced_specs_one_launch_one_sync_scan_paths(spec, eng_all, uni5):
    """On the scan paths a reduced batch is exactly one device launch (the
    fused kernel + the spec's reducer in one jit) and one host sync — only
    the payload crosses the boundary."""
    rng = np.random.default_rng(13)
    queries = _mixed_queries(uni5.cols, rng, 6)
    ops.reset_counters()
    eng_all.query_batch(queries, method="scan", spec=spec)
    assert ops.counters() == {"multi_scan_reduce": 1, "host_sync": 1}
    ops.reset_counters()
    eng_all.query_batch(queries, method="scan_vertical", spec=spec)
    assert ops.counters() == {"multi_scan_vertical_reduce": 1, "host_sync": 1}


@pytest.mark.parametrize("spec", [TopK(k=4, dim=2), Agg("max", 1)],
                         ids=lambda s: s.kind)
def test_reduced_specs_budget_two_phase_paths(spec, eng_all, uni5):
    """The two-phase paths add exactly one fused visit-reduce launch and one
    payload sync on top of their phase-1 budget: the tree prune is its own
    counted launch + survivor-mask sync, and the VA filter likewise, so both
    land at two launches + two syncs total."""
    rng = np.random.default_rng(17)
    queries = _mixed_queries(uni5.cols, rng, 6)
    ops.reset_counters()
    eng_all.query_batch(queries, method="kdtree", spec=spec)
    assert ops.counters() == {"prune_hierarchy_batch": 1,
                              "multi_visit_reduce": 1, "host_sync": 2}
    ops.reset_counters()
    eng_all.query_batch(queries, method="vafile", spec=spec)
    assert ops.counters() == {"multi_va_filter": 1, "multi_visit_reduce": 1,
                              "host_sync": 2}


def test_ids_budget_unchanged(eng_all, uni5):
    """The identity spec's budget matches the pre-spec protocol: one fused
    launch, one (mask) host sync."""
    rng = np.random.default_rng(19)
    queries = _mixed_queries(uni5.cols, rng, 4)
    ops.reset_counters()
    eng_all.query_batch(queries, method="scan", spec=Ids())
    assert ops.counters() == {"multi_scan_reduce": 1, "host_sync": 1}


# -- back-compat shim ---------------------------------------------------------

def test_mode_strings_map_to_specs_with_one_warning(eng_all, uni5):
    rng = np.random.default_rng(23)
    queries = _mixed_queries(uni5.cols, rng, 4)
    new = eng_all.query_batch(queries, method="scan", spec=Count())
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = eng_all.query_batch(queries, method="scan", mode="count")
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(deps) == 1  # a single warning, at the boundary
    assert old == new
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old_ids = eng_all.query_batch(queries, method="scan", mode="ids")
        assert sum(issubclass(x.category, DeprecationWarning)
                   for x in w) == 1
    for a, b in zip(old_ids, eng_all.query_batch(queries, method="scan")):
        np.testing.assert_array_equal(a, b)
    # single-query spelling too
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert eng_all.query(queries[0], method="scan", mode="count") \
            == new[0]
        assert sum(issubclass(x.category, DeprecationWarning)
                   for x in w) == 1


def test_unknown_modes_keep_canonical_error(eng_all, uni5):
    q = RangeQuery.partial(uni5.m, {0: (0.1, 0.2)})
    for bad in ("top_k", "nope", 17):
        with pytest.raises(ValueError, match="unknown mode"):
            eng_all.query(q, mode=bad)
        with pytest.raises(ValueError, match="unknown mode"):
            validate_mode(bad)
    with pytest.raises(ValueError, match="not both"):
        resolve_spec(spec=Count(), mode="ids")
    # spec parameter validation has its own canonical errors
    with pytest.raises(ValueError, match="out of range"):
        eng_all.query(q, spec=TopK(k=2, dim=99))
    with pytest.raises(ValueError, match="TopK k"):
        TopK(k=0, dim=1)
    with pytest.raises(ValueError, match="unknown agg op"):
        Agg("median", 0)


def test_spec_registry_is_the_extension_point():
    """New result shapes register like access paths: a subclass lands in the
    kind registry and rides the PerQueryPath host-fallback rung with only
    ``from_ids`` defined — no engine, path, or kernel edits."""
    assert set(RESULT_SPEC_KINDS) >= {"ids", "count", "mask", "topk", "agg"}

    import dataclasses

    @register_result_spec
    @dataclasses.dataclass(frozen=True)
    class Median(ResultSpec):
        kind = "test_median"
        dim: int = 0

        @property
        def value_dim(self):
            return self.dim

        def from_ids(self, ids, cols):
            return float(np.median(cols[self.dim, ids])) if ids.size else float("nan")

        def host_bytes(self, touched, n):
            return 8.0 * np.ones_like(np.asarray(touched, np.float64))

        def result_size(self, res):
            return 1

    try:
        assert RESULT_SPEC_KINDS["test_median"] is Median
        rng = np.random.default_rng(3)
        ds = Dataset(rng.random((4, 2048), dtype=np.float32))
        eng = MDRQEngine(ds, structures=("scan",), tile_n=512, rowscan=True)
        q = RangeQuery.partial(4, {1: (0.2, 0.7)})
        got = eng.query(q, method="rowscan", spec=Median(dim=2))
        ids = match_ids_np(ds.cols, q)
        assert np.isclose(got, np.median(ds.cols[2, ids]))
    finally:
        RESULT_SPEC_KINDS.pop("test_median", None)


# -- spec-dependent planning --------------------------------------------------

def test_plan_batch_is_spec_dependent(uni5):
    """The reducer-aware output-bytes term flips a plan: at n=10M a
    moderately selective query reads a 10MB mask back under ``Ids()`` — the
    tree's visited fraction is far cheaper, so kdtree wins — while under
    ``Count()``/``Agg`` every path ships O(1) bytes and the amortized fused
    scan wins (the PR 3/4 cost surface)."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=10_000_000, m=5),
                available=("scan", "kdtree"))
    side = 0.02 ** 0.2  # ~2% selectivity complete-match box
    q = RangeQuery.complete([0.0] * 5, [side] * 5)
    batch = QueryBatch.from_queries([q] * 128)

    ids_plan = p.plan_batch(batch, spec=Ids())
    cnt_plan = p.plan_batch(batch, spec=Count())
    agg_plan = p.plan_batch(batch, spec=Agg("sum", 0))
    assert ids_plan.methods[0] == "kdtree"
    assert cnt_plan.methods[0] == "scan"
    assert agg_plan.methods[0] == "scan"
    # scalar explain agrees with the batch surface
    assert p.explain(q, batch_size=128, spec=Ids()).method == "kdtree"
    assert p.explain(q, batch_size=128, spec=Count()).method == "scan"
    # and the modeled cost orders: Count/Agg batches price cheaper than Ids
    # on the scan path (the mask readback is the whole difference)
    j = ids_plan.path_names.index("scan")
    assert cnt_plan.costs[j, 0] < ids_plan.costs[j, 0]


def test_break_even_shifts_with_spec(uni5):
    """Under ``Ids()`` the scan pays the full mask readback while the index
    reads only its visited fraction, so the index wins a wider selectivity
    band than under the payload-free surface; ``Count()`` sits at the
    kernel-side break-even."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=1_000_000, m=5))
    base = p.break_even_selectivity()                 # spec=None (kernel side)
    be_ids = p.break_even_selectivity(spec=Ids())
    be_cnt = p.break_even_selectivity(spec=Count())
    assert be_ids > base
    assert np.isclose(be_cnt, base, rtol=0.05)


# -- server typing ------------------------------------------------------------

def test_server_typed_by_spec(uni5):
    from repro.serve.mdrq_server import MDRQServer

    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    rng = np.random.default_rng(29)
    queries = _mixed_queries(uni5.cols, rng, 5)
    srv = MDRQServer(eng, max_batch=4, max_wait_s=float("inf"),
                     spec=TopK(k=3, dim=1))
    tickets = [srv.submit(q) for q in queries]
    srv.flush()
    assert all(t.spec == TopK(k=3, dim=1) for t in tickets)
    for q, t in zip(queries, tickets):
        _check_spec(TopK(k=3, dim=1), t.result(), match_ids_np(uni5.cols, q),
                    uni5.cols)
    assert srv.stats.spec_counts == {"topk": len(queries)}

    agg_srv = MDRQServer(eng, max_batch=8, max_wait_s=float("inf"),
                         spec=Agg("max", 2))
    res = agg_srv.serve_all(queries)
    for q, r in zip(queries, res):
        _check_spec(Agg("max", 2), r, match_ids_np(uni5.cols, q), uni5.cols)
    assert agg_srv.stats.spec_counts == {"agg": len(queries)}
    # spec validation happens at construction, before any query is accepted
    with pytest.raises(ValueError, match="out of range"):
        MDRQServer(eng, spec=Agg("sum", 99))
