"""Incremental decode must equal the full forward pass — the serving-path
correctness invariant, across attention variants (full, SWA ring cache),
SSM state recurrence, RG-LRU hybrid, cross-attention, and dropless MoE."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.registry import build_model

CASES = ["smollm_360m", "h2o_danube_1_8b", "qwen3_8b", "mamba2_780m",
         "recurrentgemma_2b", "deepseek_moe_16b"]


def _decode_all(model, params, toks, cache_slots):
    cfg = model.cfg
    B, S = toks.shape
    cache = model.init_cache(B, cache_slots, jnp.dtype(cfg.param_dtype))
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = dec(params, cache, jnp.asarray(toks[:, t:t + 1]),
                        jnp.full((B,), t, jnp.int32))
        outs.append(np.asarray(lg, np.float32)[:, 0])
    return np.stack(outs, axis=1)


@pytest.mark.parametrize("arch", CASES)
def test_decode_equals_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # capacity drops are seq-length dependent; disable
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    B, S = 2, 24
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    full = np.asarray(model.forward(params, batch)[0], np.float32)
    dec = _decode_all(model, params, toks, cache_slots=S + 8)
    rel = np.max(np.abs(full - dec)) / (np.max(np.abs(full)) + 1e-9)
    assert rel < 0.05, rel


def test_swa_ring_cache_matches_window_mask():
    """Decode through a ring cache smaller than the sequence must equal the
    full forward with the same sliding-window mask (cache wraps twice)."""
    cfg = get_config("h2o_danube_1_8b").reduced()  # window 64 in reduced
    cfg = cfg.replace(sliding_window=8, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    B, S = 2, 20  # S > 2*window: ring wraps
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    full = np.asarray(model.forward(params, batch)[0], np.float32)
    dec = _decode_all(model, params, toks, cache_slots=S)
    rel = np.max(np.abs(full - dec)) / (np.max(np.abs(full)) + 1e-9)
    assert rel < 0.05, rel


def test_encdec_decode_with_cross_cache():
    cfg = get_config("seamless_m4t_large_v2").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    B, S, E = 2, 12, 8
    enc = jnp.asarray(rng.normal(size=(B, E, cfg.d_model)), jnp.float32)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"enc_embeds": enc, "tokens": jnp.asarray(toks),
             "labels": jnp.asarray(toks)}
    full = np.asarray(model.forward(params, batch)[0], np.float32)
    cache = model.init_cache(B, S + 4, jnp.dtype(cfg.param_dtype), enc_len=E)
    cache = model.fill_cross_cache(params, cache, enc)
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = dec(params, cache, jnp.asarray(toks[:, t:t + 1]),
                        jnp.full((B,), t, jnp.int32))
        outs.append(np.asarray(lg, np.float32)[:, 0])
    decoded = np.stack(outs, axis=1)
    rel = np.max(np.abs(full - decoded)) / (np.max(np.abs(full)) + 1e-9)
    assert rel < 0.05, rel
