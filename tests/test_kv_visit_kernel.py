"""Block-visit decode attention kernel: shape sweeps vs the jnp oracle, and
equivalence with dense attention when every block is visited."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.kv_visit import kv_visit_attention
from repro.kernels.ref import kv_visit_attention_ref


def _setup(b, kv, g, hd, nb, bs, n_visit, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, kv, g, hd)), dtype)
    kb = jnp.asarray(rng.normal(size=(b, kv, nb, bs, hd)), dtype)
    vb = jnp.asarray(rng.normal(size=(b, kv, nb, bs, hd)), dtype)
    ids = np.full((b, kv, n_visit), -1, np.int32)
    for i in range(b):
        for h in range(kv):
            sel = rng.choice(nb, size=min(n_visit, nb), replace=False)
            ids[i, h, : sel.size] = sel
    pos = jnp.asarray(rng.integers(bs, nb * bs, size=b), jnp.int32)
    return q, kb, vb, jnp.asarray(ids), pos


@pytest.mark.parametrize("b,kv,g,hd,nb,bs,nv", [
    (2, 2, 4, 32, 4, 16, 2),
    (1, 1, 8, 64, 8, 32, 8),
    (2, 4, 2, 128, 4, 128, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kv_visit_matches_oracle(b, kv, g, hd, nb, bs, nv, dtype):
    q, kb, vb, ids, pos = _setup(b, kv, g, hd, nb, bs, nv, dtype=dtype)
    out = kv_visit_attention(q, kb, vb, ids, pos, interpret=True)
    ref = kv_visit_attention_ref(q, kb, vb, ids, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_visit_all_blocks_equals_dense_attention():
    """Visiting every block must reproduce ordinary masked decode attention."""
    b, kv, g, hd, nb, bs = 2, 2, 3, 32, 4, 16
    q, kb, vb, _, pos = _setup(b, kv, g, hd, nb, bs, nb, seed=1)
    ids = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[None, None],
                           (b, kv, nb))
    out = kv_visit_attention(q, kb, vb, ids, pos, interpret=True)
    # dense reference over the flat cache
    k_flat = np.asarray(kb).reshape(b, kv, nb * bs, hd)
    v_flat = np.asarray(vb).reshape(b, kv, nb * bs, hd)
    s = np.einsum("bkgh,bkth->bkgt", np.asarray(q), k_flat) * hd ** -0.5
    valid = (np.arange(nb * bs)[None, :] <= np.asarray(pos)[:, None])
    s = np.where(valid[:, None, None, :], s, -1e38)
    w = np.exp(s - s.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    dense = np.einsum("bkgt,bkth->bkgh", w, v_flat)
    np.testing.assert_allclose(np.asarray(out, np.float32), dense,
                               rtol=2e-4, atol=2e-4)


def test_padding_ids_do_not_contribute():
    """-1-padded visits must not change the result (block 0 is DMA'd but
    masked)."""
    b, kv, g, hd, nb, bs = 1, 1, 2, 32, 4, 16
    q, kb, vb, _, pos = _setup(b, kv, g, hd, nb, bs, 2, seed=2)
    ids = jnp.asarray([[[1, 2]]], jnp.int32)
    ids_padded = jnp.asarray([[[1, 2, -1, -1]]], jnp.int32)
    out1 = kv_visit_attention(q, kb, vb, ids, pos, interpret=True)
    out2 = kv_visit_attention(q, kb, vb, ids_padded, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)
