"""Batched (multi-query) execution: fused kernels vs the numpy oracle, and
``query_batch`` vs the single-query path for every method.

Kernels run in interpret mode on CPU (the oracle-checked reference path), so
sizes stay small; the XLA refs are checked for exact equality with the
kernels in the same sweep. Masks are discrete — equality is exact."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (Count, Dataset, MDRQEngine, QueryBatch, RangeQuery,
                        match_ids_np, match_mask_np)
from repro.core.planner import CostModel, Planner, Histograms
from repro.core.vafile import build_vafile
from repro.kernels import ops, ref
from repro.kernels.va_filter import pack_codes


def _mixed_queries(m, cols, rng, n_q):
    """Alternating complete- and partial-match queries around real records."""
    out = []
    for k in range(n_q):
        if k % 2 == 0:
            a = cols[:, rng.integers(cols.shape[1])]
            b = cols[:, rng.integers(cols.shape[1])]
            out.append(RangeQuery.complete(np.minimum(a, b), np.maximum(a, b)))
        else:
            dims = rng.choice(m, size=int(rng.integers(1, m + 1)), replace=False)
            preds = {int(d): tuple(sorted(rng.random(2).tolist())) for d in dims}
            out.append(RangeQuery.partial(m, preds))
    return out


# -- (a) kernel variants vs the numpy oracle ---------------------------------

@pytest.mark.parametrize("m,n_q", [(3, 1), (5, 4), (19, 6)])
def test_multi_scan_tiles_vs_oracle(m, n_q):
    rng = np.random.default_rng(m * 10 + n_q)
    cols = rng.random((m, 4096)).astype(np.float32)
    batch = QueryBatch.from_queries(_mixed_queries(m, cols, rng, n_q))
    padded, _, n0 = ops.prepare_columnar(cols)
    data = jnp.asarray(padded)
    lo, up = batch.bounds_columnar(padded.shape[0])
    lo, up = jnp.asarray(lo), jnp.asarray(up)
    out = np.asarray(ops.multi_range_scan(data, lo, up))
    np.testing.assert_array_equal(out, np.asarray(ref.multi_scan_ref(data, lo, up)))
    for k in range(n_q):
        np.testing.assert_array_equal(out[k, :n0].astype(bool),
                                      match_mask_np(cols, batch[k]))


@pytest.mark.parametrize("m,n_q", [(5, 3), (19, 5)])
def test_multi_scan_vertical_vs_oracle(m, n_q):
    rng = np.random.default_rng(m + n_q)
    cols = rng.random((m, 4096)).astype(np.float32)
    batch = QueryBatch.from_queries(_mixed_queries(m, cols, rng, n_q))
    padded, _, n0 = ops.prepare_columnar(cols)
    data = jnp.asarray(padded)
    dim_ids = jnp.asarray(batch.padded_dim_ids())
    lo, up = batch.bounds_columnar(padded.shape[0])
    lo, up = jnp.asarray(lo), jnp.asarray(up)
    out = np.asarray(ops.multi_range_scan_vertical(data, dim_ids, lo, up))
    np.testing.assert_array_equal(
        out, np.asarray(ref.multi_scan_vertical_ref(data, dim_ids, lo, up)))
    for k in range(n_q):
        np.testing.assert_array_equal(out[k, :n0].astype(bool),
                                      match_mask_np(cols, batch[k]))


def test_multi_scan_visit_vs_oracle():
    rng = np.random.default_rng(7)
    m, tile_n = 5, 1024
    cols = rng.random((m, 8192)).astype(np.float32)
    batch = QueryBatch.from_queries(_mixed_queries(m, cols, rng, 3))
    padded, _, n0 = ops.prepare_columnar(cols, tile_n=tile_n)
    data = jnp.asarray(padded)
    n_blocks = padded.shape[1] // tile_n
    # every (query, block) pair, shuffled, plus padding entries
    qids = np.repeat(np.arange(3), n_blocks)
    bids = np.tile(np.arange(n_blocks), 3)
    order = rng.permutation(qids.size)
    qids = np.concatenate([qids[order], [0, 0]]).astype(np.int32)
    bids = np.concatenate([bids[order], [-1, -1]]).astype(np.int32)
    lo, up = batch.bounds_columnar(padded.shape[0])
    lo, up = jnp.asarray(lo), jnp.asarray(up)
    out = np.asarray(ops.multi_range_scan_visit(
        data, jnp.asarray(qids), jnp.asarray(bids), lo, up, tile_n=tile_n))
    blocks = data.reshape(data.shape[0], n_blocks, tile_n).transpose(1, 0, 2)
    np.testing.assert_array_equal(out, np.asarray(ref.multi_scan_blocks_ref(
        blocks, jnp.asarray(qids), jnp.asarray(bids), lo, up)))
    for v in range(qids.size - 2):
        k, b = int(qids[v]), int(bids[v])
        full = np.zeros((padded.shape[1],), bool)
        full[:n0] = match_mask_np(cols, batch[k])
        np.testing.assert_array_equal(out[v].astype(bool),
                                      full[b * tile_n:(b + 1) * tile_n])


@pytest.mark.parametrize("m,n_q", [(5, 3), (19, 6), (33, 4)])
def test_multi_va_filter_vs_single_and_oracle(m, n_q):
    """Batched phase 1: one-launch masks == per-query va_filter == ref,
    including point (cell_lo == cell_hi) and match-all queries."""
    rng = np.random.default_rng(m * 7 + n_q)
    n, tile_n = 4096, 1024
    codes = rng.integers(0, 4, size=(m, n)).astype(np.uint8)
    packed = jnp.asarray(pack_codes(codes))
    m_s = -(-m // 8) * 8
    qlo = np.zeros((m_s, n_q), np.int32)
    qhi = np.full((m_s, n_q), 3, np.int32)
    qlo[:m] = rng.integers(0, 4, size=(m, n_q))
    qhi[:m] = np.minimum(3, qlo[:m] + rng.integers(0, 3, size=(m, n_q)))
    qlo[:m, 0] = qhi[:m, 0]          # point query in cell space
    qlo[:m, -1], qhi[:m, -1] = 0, 3  # match-all
    out = np.asarray(ops.multi_va_filter(packed, jnp.asarray(qlo),
                                         jnp.asarray(qhi), m, tile_n=tile_n))
    np.testing.assert_array_equal(out, np.asarray(ref.multi_va_filter_packed_ref(
        packed, jnp.asarray(qlo), jnp.asarray(qhi), m)))
    for k in range(n_q):
        single = np.asarray(ops.va_filter(
            packed, jnp.asarray(qlo[:, k: k + 1]), jnp.asarray(qhi[:, k: k + 1]),
            m, tile_n=tile_n))
        np.testing.assert_array_equal(out[k], single)
    # on-device block reduction == host-side reduction of the full masks
    blocks = np.asarray(ops.multi_va_filter(packed, jnp.asarray(qlo),
                                            jnp.asarray(qhi), m,
                                            tile_n=tile_n, block_n=tile_n))
    np.testing.assert_array_equal(
        blocks, out.reshape(n_q, -1, tile_n).any(axis=2))


def _queries_with_points(cols, rng, n_q):
    """Mixed queries plus point predicates (lb == ub at real records)."""
    m = cols.shape[0]
    out = _mixed_queries(m, cols, rng, n_q)
    rec = cols[:, rng.integers(cols.shape[1])]
    out.append(RangeQuery.complete(rec, rec))                # full point query
    out.append(RangeQuery.partial(m, {1: (float(rec[1]), float(rec[1]))}))
    return out


def test_vafile_batch_one_launch_one_sync(uni5):
    """Tentpole budget: the batched VA path issues exactly one phase-1 launch
    and one phase-1 host sync per batch (plus one fused visit-reduce launch +
    payload readback), never the per-query va_filter — results bit-identical
    to the single-query path."""
    vf = build_vafile(uni5, tile_n=512)
    rng = np.random.default_rng(17)
    queries = _queries_with_points(uni5.cols, rng, 6)
    singles = [vf.query(q) for q in queries]
    batch = QueryBatch.from_queries(queries)

    ops.reset_counters()
    batched = vf.query_batch(batch)
    assert ops.counter("multi_va_filter") == 1   # one phase-1 launch
    assert ops.counter("va_filter") == 0         # never per-query
    assert ops.counter("multi_visit_reduce") == 1
    assert ops.counter("host_sync") == 2         # survivor bits + visit masks
    for s, b in zip(singles, batched):
        np.testing.assert_array_equal(s, b)

    ops.reset_counters()
    counts = vf.query_batch(batch, spec=Count())
    assert ops.counter("multi_va_filter") == 1
    assert ops.counter("host_sync") == 2
    assert counts == [s.size for s in singles]
    assert all(isinstance(c, int) for c in counts)


def test_vafile_batch_gmrqb_templates():
    """GMRQB-style batches (templates with point predicates) through the
    batched VA path: ids and counts match the single-query path / oracle."""
    from repro.data import gmrqb

    ds = gmrqb.build(8192, seed=3)
    vf = build_vafile(ds, tile_n=1024)
    rng = np.random.default_rng(9)
    queries = [gmrqb.template(k, rng, ds) for k in (1, 4, 5, 7, 8)]
    batch = QueryBatch.from_queries(queries)
    batched = vf.query_batch(batch)
    counts = vf.query_batch(batch, spec=Count())
    for k, q in enumerate(queries):
        oracle = match_ids_np(ds.cols, q)
        np.testing.assert_array_equal(batched[k], oracle)
        np.testing.assert_array_equal(vf.query(q), oracle)
        assert counts[k] == oracle.size
        assert vf.count(q) == oracle.size


# -- (b) query_batch == per-query query for all methods ----------------------

@pytest.mark.parametrize("method", ["scan", "scan_vertical", "kdtree",
                                    "rstar", "vafile", "auto"])
def test_query_batch_equals_single(method, uni5):
    eng = MDRQEngine(uni5, tile_n=512)
    rng = np.random.default_rng(11)
    queries = _mixed_queries(uni5.m, uni5.cols, rng, 6)
    batched = eng.query_batch(queries, method=method)
    assert eng.last_batch_stats.n_queries == 6
    assert sum(eng.last_batch_stats.method_counts.values()) == 6
    for k, q in enumerate(queries):
        np.testing.assert_array_equal(batched[k], eng.query(q, method))
        if method != "auto":
            np.testing.assert_array_equal(batched[k], match_ids_np(uni5.cols, q))


# -- count-only result mode --------------------------------------------------

@pytest.fixture(scope="module")
def eng_all(uni5):
    return MDRQEngine(uni5, tile_n=512, rowscan=True)


@pytest.mark.parametrize("method", ["scan", "scan_vertical", "rowscan",
                                    "kdtree", "rstar", "vafile", "auto"])
def test_count_mode_equals_ids_sizes(method, eng_all, uni5):
    rng = np.random.default_rng(29)
    queries = _queries_with_points(uni5.cols, rng, 5)
    counts = eng_all.query_batch(queries, method=method, mode="count")
    assert all(isinstance(c, int) for c in counts)
    assert eng_all.last_batch_stats.n_results == sum(counts)
    for k, q in enumerate(queries):
        expected = match_ids_np(uni5.cols, q).size
        assert counts[k] == expected, (method, k)
        assert eng_all.query(q, method, mode="count") == expected
        assert eng_all.last_stats.n_results == expected


def test_count_mode_scan_single_launch_no_mask_readback(eng_all, uni5):
    """Count mode sums masks on device: one fused launch, one O(Q) transfer,
    and no (Q, n) mask ever crosses to the host."""
    rng = np.random.default_rng(31)
    queries = _mixed_queries(uni5.m, uni5.cols, rng, 8)
    ops.reset_counters()
    eng_all.query_batch(queries, method="scan", spec=Count())
    assert ops.counter("multi_scan_reduce") == 1
    assert ops.counter("host_sync") == 1


def test_count_mode_rejects_unknown(eng_all, uni5):
    q = RangeQuery.partial(uni5.m, {0: (0.1, 0.2)})
    with pytest.raises(ValueError):
        eng_all.query(q, mode="top_k")
    with pytest.raises(ValueError):
        eng_all.query_batch([q], mode="top_k")


def test_query_batch_accepts_querybatch_object(uni5):
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    rng = np.random.default_rng(3)
    queries = _mixed_queries(uni5.m, uni5.cols, rng, 4)
    res_list = eng.query_batch(queries, method="scan")
    res_qb = eng.query_batch(QueryBatch.from_queries(queries), method="scan")
    for a, b in zip(res_list, res_qb):
        np.testing.assert_array_equal(a, b)


# -- (c) edge cases ----------------------------------------------------------

def test_query_batch_empty_and_single(uni5):
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    assert eng.query_batch([]) == []
    assert eng.last_batch_stats.n_queries == 0
    q = RangeQuery.partial(uni5.m, {0: (0.2, 0.4)})
    res = eng.query_batch([q], method="scan")
    assert len(res) == 1
    np.testing.assert_array_equal(res[0], match_ids_np(uni5.cols, q))


def test_query_batch_match_all_and_match_none(uni5):
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    q_all = RangeQuery.partial(uni5.m, {})
    q_none = RangeQuery.partial(uni5.m, {0: (2.0, 3.0)})
    res = eng.query_batch([q_all, q_none, q_all], method="scan_vertical")
    assert res[0].size == uni5.n and res[2].size == uni5.n
    assert res[1].size == 0


def test_query_batch_dim_mismatch(uni5):
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    with pytest.raises(ValueError):
        eng.query_batch([RangeQuery.partial(3, {0: (0.0, 1.0)})])


def test_querybatch_rejects_mixed_dims():
    with pytest.raises(ValueError):
        QueryBatch.from_queries([RangeQuery.partial(3, {}),
                                 RangeQuery.partial(4, {})])


# -- batched planner costs ---------------------------------------------------

def test_batch_amortizes_fixed_taxes(uni5):
    hist = Histograms.build(uni5)
    model = CostModel(n=1_000_000, m=5)
    q = RangeQuery.complete([0.0] * 5, [0.1] * 5)
    sel = hist.selectivity(q)
    assert model.cost_tree(q, sel, batch=128) < model.cost_tree(q, sel)
    assert model.cost_scan(q, batch=128) < model.cost_scan(q)
    # batch=1 must equal the legacy single-query cost structure
    p = Planner(hist, model)
    assert p.explain(q).costs == p.explain(q, batch_size=1).costs


def test_break_even_shifts_with_batch(uni5):
    """The batched break-even differs from single-query — the subsystem's
    paper-relevant planning result (net of sync amortization helping indexes
    and fused-byte amortization helping scans)."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=10_000_000, m=5))
    be1 = p.break_even_selectivity()
    be128 = p.break_even_selectivity(batch_size=128)
    assert be1 > 0
    assert abs(be128 - be1) / be1 > 0.25, (be1, be128)


# -- the serving front end ---------------------------------------------------

def test_mdrq_server_batches_and_agrees(uni5):
    from repro.serve.mdrq_server import MDRQServer

    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    rng = np.random.default_rng(21)
    queries = _mixed_queries(uni5.m, uni5.cols, rng, 10)
    server = MDRQServer(eng, max_batch=4, max_wait_s=float("inf"), method="scan")
    results = server.serve_all(queries)
    for q, ids in zip(queries, results):
        np.testing.assert_array_equal(ids, match_ids_np(uni5.cols, q))
    # 10 queries at window 4 -> batches of 4, 4, 2
    assert server.stats.n_batches == 3
    assert server.stats.n_queries == 10
    assert server.stats.qps > 0


def test_mdrq_server_ticket_forces_flush(uni5):
    from repro.serve.mdrq_server import MDRQServer

    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    server = MDRQServer(eng, max_batch=64, max_wait_s=float("inf"))
    q = RangeQuery.partial(uni5.m, {1: (0.1, 0.3)})
    ticket = server.submit(q)
    assert server.n_pending == 1  # window not full, nothing executed yet
    np.testing.assert_array_equal(ticket.result(), match_ids_np(uni5.cols, q))
    assert server.n_pending == 0
