"""§Observability: metrics registry, spans, traces, query log, drift audit.

Covers DESIGN.md §10 end to end:
  * histogram percentiles against a numpy oracle (error bounded by one
    bucket ratio),
  * exporter round-trips (JSONL parse-back; Prometheus text lint),
  * span nesting around jitted calls with launch/host-sync attribution
    (spans wrap the jitted call — no trace-time capture),
  * zero overhead when disabled: ``span()`` returns the shared singleton and
    ``query_batch(trace=False)`` allocates no Span objects at all,
  * ``query_batch(trace=True)`` QueryTrace correctness,
  * the drift audit flagging a skewed-histogram selectivity model,
  * the acceptance loop: corrupt a cost constant -> traced queries ->
    ``Planner.calibrate`` on the audit's observations repairs it,
  * server latency percentiles, flush reasons, the bounded reservoir log,
    and the deadline-flush trace event,
  * tracing overhead <= 5% qps at B=128 (perf knob).
"""
import json
import math
import re
import time

import numpy as np
import pytest

from repro import obs
from repro.core import Count, Dataset, MDRQEngine, RangeQuery
from repro.kernels import ops
from repro.obs import metrics, tracing


@pytest.fixture(scope="module")
def engine():
    rng = np.random.default_rng(7)
    return MDRQEngine(Dataset(rng.random((4, 20_000), dtype=np.float32)))


@pytest.fixture
def xla_backend():
    # ops.set_backend drops the jit caches on switch: the backend is read at
    # trace time, so executables another test traced at a colliding padded
    # shape would otherwise be reused under the wrong backend
    prev = ops.set_backend("xla")
    yield
    ops.set_backend(prev)


def _queries(m, n_q, seed=0, width=0.4):
    rng = np.random.default_rng(seed)
    lo = rng.random((n_q, m)).astype(np.float32) * (1 - width)
    return [RangeQuery.complete(lo[k], lo[k] + width) for k in range(n_q)]


# -- metrics registry ---------------------------------------------------------

def test_counter_gauge_labels_and_families():
    reg = obs.registry()
    a = reg.counter("t_total", op="a")
    b = reg.counter("t_total", op="b")
    assert a is reg.counter("t_total", op="a")  # get-or-create
    a.inc(); a.inc(2); b.inc()
    assert a.value == 3 and b.value == 1
    assert reg.family_total("t_total") == 4
    assert reg.counter_values("t_total", "op") == {"a": 3.0, "b": 1.0}
    g = reg.gauge("t_gauge")
    g.set(2.5)
    assert g.value == 2.5
    with pytest.raises(TypeError):
        reg.gauge("t_total", op="a")  # kind mismatch on one family
    reg.reset()
    assert a.value == 0  # reset zeroes values but keeps objects live


def test_histogram_percentiles_vs_numpy_oracle():
    rng = np.random.default_rng(0)
    xs = np.exp(rng.normal(loc=-7.0, scale=2.0, size=4000))  # latency-ish
    h = metrics.Histogram("lat", {})
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert math.isclose(h.sum, float(xs.sum()), rel_tol=1e-9)
    for p in (50, 90, 95, 99):
        exact = float(np.percentile(xs, p))
        est = h.percentile(p)
        # interpolation is exact to one bucket ratio by construction
        assert exact / metrics.LATENCY_BUCKET_RATIO <= est \
            <= exact * metrics.LATENCY_BUCKET_RATIO
    # clamped to observed extremes
    assert h.percentile(100) == pytest.approx(float(xs.max()))
    assert xs.min() <= h.percentile(0.01) <= np.percentile(xs, 1)
    ps = h.percentiles((50, 95, 99))
    assert set(ps) == {"p50", "p95", "p99"}


def test_histogram_empty_and_validation():
    h = metrics.Histogram("lat", {})
    assert math.isnan(h.percentile(50))
    with pytest.raises(ValueError):
        h.percentile(0)
    with pytest.raises(ValueError):
        metrics.Histogram("bad", {}, bounds=(2.0, 1.0))


def test_jsonl_export_round_trips():
    reg = obs.registry()
    reg.counter("rt_total", help="x", op="scan").inc(5)
    reg.gauge("rt_gauge").set(1.25)
    h = reg.histogram("rt_seconds", kind="ids")
    for v in (1e-4, 2e-4, 3e-3):
        h.observe(v)
    rows = [json.loads(line) for line in reg.to_jsonl().splitlines()]
    by_name = {(r["name"], tuple(sorted(r["labels"].items()))): r
               for r in rows}
    c = by_name[("rt_total", (("op", "scan"),))]
    assert c["type"] == "counter" and c["value"] == 5
    g = by_name[("rt_gauge", ())]
    assert g["type"] == "gauge" and g["value"] == 1.25
    hr = by_name[("rt_seconds", (("kind", "ids"),))]
    assert hr["type"] == "histogram" and hr["count"] == 3
    assert hr["sum"] == pytest.approx(3.3e-3)
    # sparse buckets carry (edge, cumulative count); last cum == count
    assert hr["buckets"][-1][1] == 3
    assert "p50" in hr and "p99" in hr


def test_prometheus_text_lints():
    reg = obs.registry()
    reg.counter("pl_total", help="a counter", op="scan").inc(2)
    reg.counter("pl_total", op="tree").inc(1)
    reg.gauge("pl_gauge").set(3)
    h = reg.histogram("pl_seconds", help="a histogram", kind="ids")
    h.observe(1e-4); h.observe(5.0e-1)
    text = reg.to_prometheus()
    assert text.endswith("\n")
    sample_re = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*'                 # metric name
        r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'         # first label
        r'(,[a-zA-Z_][a-zA-Z0-9_]*="[^"]*")*\})?'    # more labels
        r' (\+Inf|-?[0-9.eE+-]+)$')                  # value
    types = []
    for line in text.splitlines():
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            types.append(line.split()[2:4])
            continue
        assert sample_re.match(line), f"malformed sample line: {line!r}"
    # one TYPE per family, correct kinds
    fams = dict((n, k) for n, k in types)
    assert len(types) == len(fams)
    assert fams["pl_total"] == "counter"
    assert fams["pl_gauge"] == "gauge"
    assert fams["pl_seconds"] == "histogram"
    # histogram triplet: +Inf bucket cumulative == _count
    inf = re.search(r'pl_seconds_bucket\{kind="ids",le="\+Inf"\} (\d+)', text)
    cnt = re.search(r'pl_seconds_count\{kind="ids"\} (\d+)', text)
    assert inf and cnt and inf.group(1) == cnt.group(1) == "2"
    assert 'pl_seconds_sum{kind="ids"}' in text


# -- spans & launch attribution ----------------------------------------------

def test_ops_counters_are_registry_backed(engine):
    """The launch/host-sync budget counters and the metrics registry are one
    store — budget tests migrated to the registry backend see identical
    numbers through either API."""
    engine.query_batch(_queries(4, 8), method="scan")
    assert ops.counters()  # something launched
    vals = obs.registry().counter_values(tracing.LAUNCH_FAMILY, "op")
    for name, count in ops.counters().items():
        assert vals[name] == count


def test_span_nesting_around_jitted_calls(engine):
    """Spans wrap the jitted call (never the traced body): nested spans
    record the launches and host syncs that completed under them."""
    qs = _queries(4, 8, seed=1)
    engine.query_batch(qs, method="scan")  # warm the jit cache first
    ops.reset_counters()
    with obs.Tracer() as tr:
        with obs.span("outer") as outer:
            with obs.span("inner", path="scan") as inner:
                engine.query_batch(qs, method="scan")
    assert tr.spans == [outer]
    assert outer.children == [inner]
    assert inner.launches >= 1 and inner.host_syncs >= 1
    # the parent's deltas include the child's (snapshots are cumulative)
    assert outer.launches == inner.launches
    assert outer.host_syncs == inner.host_syncs
    assert inner.seconds > 0
    assert [s.attrs for s in tr.find("inner")] == [{"path": "scan"}]


def test_null_span_when_disabled_and_no_allocation(engine, monkeypatch):
    assert not obs.enabled()
    s = obs.span("anything", a=1)
    assert s is obs.NULL_SPAN  # the shared singleton, no allocation
    with s as got:
        got.set(x=2).block_on(None)  # all no-ops

    # the acceptance knife: with tracing disabled, the engine + path layers
    # must not construct a single Span object on the hot path
    def boom(*a, **kw):
        raise AssertionError("Span allocated with tracing disabled")
    monkeypatch.setattr(tracing, "Span", boom)
    res = engine.query_batch(_queries(4, 8, seed=2), trace=False)
    assert len(res) == 8


# -- engine traces ------------------------------------------------------------

def test_query_batch_trace_records(engine):
    qs = _queries(4, 16, seed=3)
    res = engine.query_batch(qs, trace=True)
    bt = engine.last_trace
    assert bt.n_queries == 16 and len(bt.queries) == 16
    assert bt.n == engine.dataset.n
    assert bt.plan_seconds <= bt.seconds
    assert [t.method for t in bt.queries] == engine.last_batch_stats.methods
    for t in bt.queries:
        assert t.bucket_size == engine.last_batch_stats.method_counts[t.method]
        assert t.spec_kind == "ids"
        assert t.mq == 4
        assert t.result_size == len(res[t.index])
        assert t.obs_selectivity == pytest.approx(
            len(res[t.index]) / engine.dataset.n)
        assert math.isfinite(t.est_cost)      # planned run: costs are real
        assert 0 < t.est_selectivity <= 1
        assert t.seconds >= 0 and t.launches > 0
    # span tree: one plan span, one execute span per realized bucket, each
    # with the path adapter's own span nested under it
    names = [s.name for s in bt.spans]
    assert names.count("plan") == 1
    ex = [s for s in bt.spans if s.name == "execute"]
    assert {s.attrs["path"] for s in ex} == \
        set(engine.last_batch_stats.method_counts)
    assert all(c.name == "path" for s in ex for c in s.children)

    # explicit-method run: estimates exist, planner cost is honestly NaN
    engine.query_batch(qs, method="scan", trace=True)
    t = engine.last_trace.queries[0]
    assert t.method == "scan" and math.isnan(t.est_cost)
    assert 0 < t.est_selectivity <= 1
    # tracing did not leak an active tracer
    assert not obs.enabled()


def test_trace_disabled_leaves_no_trace(engine):
    engine.last_trace = None
    engine.query_batch(_queries(4, 4, seed=4))
    assert engine.last_trace is None


# -- drift audit + calibration repair -----------------------------------------

def test_audit_flags_skewed_histograms():
    """Perfectly correlated dims break the independence assumption: the
    histogram estimate is ~sel^2 while reality is ~sel — the audit must flag
    the (path x decile) cells, and a well-modeled dataset must stay clean."""
    rng = np.random.default_rng(11)
    col = rng.random(8_192, dtype=np.float32)
    skewed = MDRQEngine(Dataset(np.stack([col, col])),
                        structures=("scan",))
    qs = []
    for k in range(24):
        lo = float(rng.random() * 0.6)
        q = RangeQuery.complete([lo, lo], [lo + 0.25, lo + 0.25])
        qs.append(q)
    skewed.query_batch(qs, method="scan", trace=True)
    report = obs.audit(skewed.last_trace, sel_tolerance=2.0)
    assert not report.ok
    assert all(c.method == "scan" for c in report.drifted)
    # obs sel ~0.25 vs est ~0.0625 -> ratio ~4x, well past tolerance
    assert all(c.sel_ratio > 2.0 for c in report.drifted)
    assert "DRIFT" in report.summary()

    # independent uniform dims: the same workload shape audits clean
    ok_eng = MDRQEngine(Dataset(rng.random((2, 8_192), dtype=np.float32)),
                        structures=("scan",))
    ok_eng.query_batch(qs, method="scan", trace=True)
    assert obs.audit(ok_eng.last_trace, sel_tolerance=2.0).ok


def test_audit_cell_bucketing():
    def qt(method, est, obs_sel, cost=float("nan")):
        return tracing.QueryTrace(
            index=0, method=method, bucket_size=4, est_selectivity=est,
            est_cost=cost, spec_kind="ids", mq=2, result_size=0,
            obs_selectivity=obs_sel, seconds=1e-4, launches=0.25,
            host_syncs=0.25)
    report = obs.audit(
        [qt("scan", 0.05, 0.05), qt("scan", 0.55, 0.54),
         qt("kdtree", 0.01, 0.3)], sel_tolerance=4.0)
    cells = {(c.method, c.decile): c for c in report.cells}
    assert set(cells) == {("scan", 0), ("scan", 5), ("kdtree", 0)}
    assert not cells[("scan", 0)].drifted
    assert cells[("kdtree", 0)].drifted  # 30x past a 4x tolerance
    # unobservable traces (reduced specs) are counted but never flagged
    rep2 = obs.audit([qt("scan", 0.05, None)])
    assert rep2.n_unobserved == 1 and rep2.ok


def test_calibration_repairs_corrupted_cost_constant(xla_backend):
    """Acceptance: corrupt a machine constant, run traced queries, and show
    ``Planner.calibrate`` on the audit's observations repairs it through the
    existing CalibrationReport plumbing (trace -> audit -> calibrate)."""
    # XLA backend for honest timings (interpret mode runs the grid as a
    # Python loop); the fixture cleared the jit caches, so every shape
    # below traces fresh under it
    rng = np.random.default_rng(5)
    eng = MDRQEngine(Dataset(rng.random((4, 50_000), dtype=np.float32)),
                     structures=("scan",))
    model = eng.planner.model
    true_spb = model.sec_per_byte
    model.sec_per_byte = corrupted = true_spb * 1e6

    # traced production traffic at several batch sizes — bucket amortization
    # varies modeled bytes/query, which is what the lstsq fit needs
    samples = []
    for b, seed in ((4, 0), (16, 1), (64, 2)):
        qs = _queries(4, b, seed=seed)
        eng.query_batch(qs, method="scan", spec=Count())  # warm the shape
        eng.query_batch(qs, method="scan", spec=Count(), trace=True)
        samples += obs.calibration_samples(eng.last_trace, model)
    assert len(samples) == 84 and all(m == "scan" for m, _, _ in samples)

    # the corrupted model mispredicts wall time by ~3 orders of magnitude
    worst = max(corrupted * nb / max(sec, 1e-12) for _, nb, sec in samples)
    assert worst > 50

    report = eng.planner.calibrate(samples)
    assert isinstance(report, type(eng.planner.calibrate([])))
    assert report.n_samples == 84 and report.methods == ("scan",)
    assert report.accepted["sec_per_byte"]
    # repaired: the corrupted constant moved back toward reality
    assert model.sec_per_byte < corrupted / 50
    # and the fit explains the measurements far better than the corruption
    resid = [abs(model.sec_per_byte * nb + model.dispatch_overhead - sec)
             / max(sec, 1e-12) for _, nb, sec in samples]
    assert np.median(resid) < 1.0 < worst


# -- server observability -----------------------------------------------------

def test_server_latency_flush_reasons_and_query_log(engine):
    from repro.serve.mdrq_server import MDRQServer

    srv = MDRQServer(engine, max_batch=4, max_wait_s=10.0, spec=Count())
    qs = _queries(4, 9, seed=6)
    tickets = [srv.submit(q) for q in qs[:8]]   # two size-triggered flushes
    assert srv.stats.flush_reasons == {"size": 2}

    srv.max_wait_s = 1e-4
    srv.submit(qs[8])
    time.sleep(2e-3)
    with obs.Tracer() as tr:
        flushed = srv.poll()                    # idle-stream deadline flush
    assert flushed == 1
    assert srv.stats.flush_reasons == {"size": 2, "deadline": 1}
    # the flush trace event carries the trigger
    ev = tr.find("flush")
    assert len(ev) == 1 and ev[0].attrs["reason"] == "deadline"
    assert ev[0].attrs["n_queries"] == 1

    # registry mirror of the reasons
    reasons = obs.registry().counter_values("mdrq_server_flushes_total",
                                            "reason")
    assert reasons == {"size": 2.0, "deadline": 1.0}

    # per-spec-kind latency percentiles
    lat = srv.stats.latency_percentiles("count")
    for stage in ("queue", "execute"):
        assert set(lat[stage]) == {"p50", "p95", "p99"}
        assert 0 < lat[stage]["p50"] <= lat[stage]["p99"]
    assert srv.stats.latency_percentiles("ids") == {"queue": {},
                                                    "execute": {}}
    # queue latency of the deadline-flushed query reflects its wait
    assert srv.query_log.by_reason("deadline")[0].queue_seconds >= 2e-3

    # the query log saw everything, with methods and reasons per entry
    assert len(srv.query_log) == 9
    assert {e.flush_reason for e in srv.query_log.entries} \
        == {"size", "deadline"}
    assert all(e.method in engine.paths for e in srv.query_log.entries)
    assert all(e.spec_kind == "count" for e in srv.query_log.entries)
    lo, up = srv.query_log.bounds()
    assert lo.shape == (9, 4) and up.shape == (9, 4)
    assert all(t.result() == e.result_size
               for t, e in zip(tickets, srv.query_log.entries))


def test_query_log_reservoir_bound():
    log = obs.QueryLog(capacity=16, seed=1)
    e = obs.QueryLogEntry(lower=np.zeros(2), upper=np.ones(2),
                          spec_kind="ids", method="scan", result_size=0,
                          queue_seconds=0.0, execute_seconds=0.0,
                          flush_reason="size", batch_size=1)
    for _ in range(1000):
        log.offer(e)
    assert len(log) == 16 and log.n_seen == 1000
    with pytest.raises(ValueError):
        obs.QueryLog(capacity=0)


def test_reservoir_is_uniform():
    """Retention frequency of early vs late offers stays ~capacity/n."""
    hits = np.zeros(200)
    for seed in range(40):
        log = obs.QueryLog(capacity=20, seed=seed)
        for i in range(200):
            log.offer(i)  # duck-typed payload: the log never inspects it
        for kept in log.entries:
            hits[kept] += 1
    # expected retention 20/200 = 0.1 per slot per trial -> 4 of 40 trials;
    # first and second halves must not differ wildly
    assert abs(hits[:100].mean() - hits[100:].mean()) < 2.0


# -- tracing overhead (perf knob) ---------------------------------------------

def test_tracing_overhead_under_5pct_at_B128(xla_backend):
    """Acceptance: tracing may cost at most 5% qps at B=128. Span count per
    batch is O(buckets), not O(queries), so the overhead is a handful of
    perf_counter calls amortized over 128 queries."""
    rng = np.random.default_rng(9)
    eng = MDRQEngine(Dataset(rng.random((4, 33_000), dtype=np.float32)),
                     structures=("scan",))
    qs = _queries(4, 128, seed=10)

    def run(trace):
        t0 = time.perf_counter()
        eng.query_batch(qs, trace=trace)  # the production (planned) path
        return time.perf_counter() - t0

    run(False); run(True)  # warm jit + allocator
    for attempt in range(3):  # perf assertions get retries, not big margins
        plain = min(run(False) for _ in range(5))
        traced = min(run(True) for _ in range(5))
        if traced <= plain * 1.05:
            break
    assert traced <= plain * 1.05, \
        f"tracing overhead {traced / plain - 1:.1%} > 5%"
