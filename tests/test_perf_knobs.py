"""§Perf optimization knobs: semantics preserved under every variant.

Each knob must keep model function (or greedy behaviour) intact:
  * prefill_last_only   — bit-equal last-token logits
  * attn_scores_f32=False — bf16 streaming softmax within tolerance
  * kv_cache_int8       — decode within quantization tolerance
  * kv_block_prune (keep-all) — bit-equal decode
  * kv_prune_groups (keep-all) — bit-equal decode
  * seq_shard_resid / attn_batch_shard — no-ops without a mesh (tests run
    single-device), exercised for real in the dry-run subprocess.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.registry import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32)
    return cfg, params, toks


def _decode_all(cfg, params, toks, slots=48):
    model = build_model(cfg)
    b = toks.shape[0]
    cache = model.init_cache(b, slots, jnp.dtype(cfg.param_dtype))
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(toks.shape[1]):
        lg, cache = dec(params, cache, jnp.asarray(toks[:, t:t + 1]),
                        jnp.full((b,), t, jnp.int32))
        outs.append(np.asarray(lg, np.float32)[:, 0])
    return np.stack(outs, 1)


def test_prefill_last_only_equals_full(setup):
    cfg, params, toks = setup
    batch = {"tokens": jnp.asarray(toks)}
    full, _ = build_model(cfg).prefill(params, batch)
    last, _ = build_model(cfg.replace(prefill_last_only=True)).prefill(params, batch)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(last, np.float32), rtol=0, atol=1e-5)


def test_bf16_scores_close(setup):
    cfg, params, toks = setup
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    l1 = float(build_model(cfg).loss_fn(params, batch)[0])
    l2 = float(build_model(cfg.replace(attn_scores_f32=False)).loss_fn(params, batch)[0])
    assert abs(l1 - l2) / l1 < 1e-2


def test_int8_kv_decode_close(setup):
    cfg, params, toks = setup
    full = _decode_all(cfg, params, toks)
    q8 = _decode_all(cfg.replace(kv_cache_int8=True), params, toks)
    # random-init logits are near-flat; require bounded absolute deviation
    assert np.abs(full - q8).max() < 0.15 * (np.abs(full).max() + 1.0)


@pytest.mark.parametrize("groups", [0, 2])
def test_keepall_prune_is_exact(setup, groups):
    cfg, params, toks = setup
    full = _decode_all(cfg, params, toks)
    pruned = _decode_all(
        cfg.replace(kv_block_prune=4, kv_block_size=16, kv_prune_groups=groups),
        params, toks, slots=64)
    np.testing.assert_allclose(full, pruned, rtol=0, atol=0.05)


def test_zone_map_bound_is_valid():
    """Property: q+.kmax + q-.kmin >= q.k for every key in the block."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        q = rng.normal(size=(8,))
        keys = rng.normal(size=(32, 8))
        kmin, kmax = keys.min(0), keys.max(0)
        ub = np.maximum(q, 0) @ kmax + np.minimum(q, 0) @ kmin
        assert (keys @ q <= ub + 1e-9).all()


def test_seqshard_and_batchshard_noop_without_mesh(setup):
    cfg, params, toks = setup
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    base = float(build_model(cfg).loss_fn(params, batch)[0])
    v1 = float(build_model(cfg.replace(seq_shard_resid=True)).loss_fn(params, batch)[0])
    v2 = float(build_model(cfg.replace(attn_batch_shard=True)).loss_fn(params, batch)[0])
    assert base == v1 == v2
