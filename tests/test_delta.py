"""The versioned dataset: delta segment, tombstones, compaction (DESIGN.md §11).

Covers the acceptance axes of the mutable plane:

  * equivalence — ``query_batch`` over (base + delta − tombstones) matches
    the numpy oracle over the combined live rows for every registered path ×
    every ResultSpec, including the tombstones-only (d=0) corner;
  * budgets — Count/TopK/Agg stay ONE fused launch + ONE host sync per batch
    with a non-empty delta (counter-asserted);
  * compaction — ``compact()`` returns a correct old->new id map, bumps the
    version, empties the delta, and preserves query results through the map;
    the explicit build()/ingest/commit() interleaving folds late writes;
  * planning — ``CostModel.delta_n`` flips a minority index pick to the scan
    as the delta grows, and the engine refreshes it from the snapshot;
  * atomicity — concurrent match-all counts during append/delete/compact only
    ever observe valid cumulative totals (no torn version mix);
  * calibration guards — zero-traffic and all-tombstoned traces produce
    no-op reports, not divide-by-zero.
"""
import threading

import numpy as np
import pytest

from repro.core import (Agg, Compactor, Count, Dataset, Ids, Mask, MDRQEngine,
                        QueryBatch, RangeQuery, TopK)
from repro.core import types as T
from repro.core.planner import CostModel, Histograms, Planner
from repro.kernels import ops
from repro.obs.audit import audit as audit_traces
from repro.obs.audit import calibration_samples

SPECS = (Ids(), Count(), Mask(), TopK(k=4, dim=2),
         TopK(k=3, dim=1, largest=False), Agg("sum", 3), Agg("min", 0),
         Agg("max", 4))


def _mixed_queries(m, rng, n_q):
    """Complete + partial + empty-range + match-all queries over [0, 1)."""
    out = []
    for k in range(n_q):
        if k % 2 == 0:
            a, b = np.sort(rng.random((2, m)).astype(np.float32), axis=0)
            out.append(RangeQuery.complete(a, b))
        else:
            dims = rng.choice(m, size=int(rng.integers(1, m + 1)),
                              replace=False)
            preds = {int(d): tuple(sorted(rng.random(2).tolist()))
                     for d in dims}
            out.append(RangeQuery.partial(m, preds))
    out.append(RangeQuery.partial(m, {0: (2.0, 3.0)}))  # empty result set
    out.append(RangeQuery.partial(m, {}))               # match-all
    return out


class _Oracle:
    """Numpy ground truth over the combined (base + delta − tombstones) rows."""

    def __init__(self, cols, extra_rows, dead_ids):
        self.cols = (np.concatenate([cols, extra_rows.T.astype(np.float32)],
                                    axis=1)
                     if extra_rows is not None and len(extra_rows) else cols)
        self.alive = np.ones((self.cols.shape[1],), bool)
        self.alive[np.asarray(dead_ids, np.int64)] = False

    def ids(self, q):
        return np.nonzero(T.match_mask_np(self.cols, q) & self.alive)[0] \
            .astype(np.int64)

    def check(self, spec, q, res):
        ids = self.ids(q)
        cols = self.cols
        if spec.kind == "ids":
            np.testing.assert_array_equal(res, ids)
        elif spec.kind == "count":
            assert isinstance(res, int) and res == ids.size
        elif spec.kind == "mask":
            assert res.dtype == bool and res.shape == (cols.shape[1],)
            np.testing.assert_array_equal(np.nonzero(res)[0], ids)
        elif spec.kind == "topk":
            vals = cols[spec.dim, ids]
            order = np.lexsort((ids, -vals if spec.largest else vals))
            np.testing.assert_array_equal(res, ids[order[: spec.k]])
        elif spec.kind == "agg":
            if ids.size == 0:
                assert res == 0.0 if spec.op == "sum" else np.isnan(res)
            else:
                vals = cols[spec.dim, ids]
                exp = {"min": np.min, "max": np.max,
                       "sum": lambda v: np.sum(v, dtype=np.float64)}[spec.op](vals)
                assert np.isclose(res, exp, rtol=1e-4), (res, exp)
        else:
            raise AssertionError(spec.kind)


@pytest.fixture(scope="module")
def eng_delta(uni5):
    """All-paths engine over uni5 with a ~1% delta + mixed tombstones."""
    eng = MDRQEngine(uni5, rowscan=True)
    rng = np.random.default_rng(77)
    extra = rng.random((200, uni5.m)).astype(np.float32)   # 1% of n=20k
    new_ids = eng.append(extra)
    dead = np.concatenate([rng.choice(uni5.n, 120, replace=False),
                           new_ids[:10]])
    eng.delete(dead)
    return eng, _Oracle(uni5.cols, extra, dead)


ALL_PATHS = ("scan", "scan_vertical", "kdtree", "rstar", "vafile", "rowscan")


@pytest.mark.parametrize("method", ALL_PATHS)
def test_delta_equivalence_all_paths_all_specs(method, eng_delta):
    """query_batch over (base + delta − tombstones) == the numpy oracle over
    the combined live rows, for every path × Ids/Count/Mask/TopK/Agg."""
    eng, oracle = eng_delta
    rng = np.random.default_rng(5)
    queries = _mixed_queries(eng.dataset.m, rng, 6)
    for spec in SPECS:
        results = eng.query_batch(queries, method=method, spec=spec)
        for q, res in zip(queries, results):
            oracle.check(spec, q, res)


def test_delta_equivalence_auto_and_singles(eng_delta):
    """The planner route and the single-query entry point agree with the
    oracle too (singles ride the delta-aware batch rung at Q=1)."""
    eng, oracle = eng_delta
    rng = np.random.default_rng(6)
    queries = _mixed_queries(eng.dataset.m, rng, 5)
    for spec in (Ids(), Count(), TopK(k=5, dim=0)):
        for q, res in zip(queries, eng.query_batch(queries, spec=spec)):
            oracle.check(spec, q, res)
        for q in queries[:3]:
            oracle.check(spec, q, eng.query(q, spec=spec))


def test_tombstones_only_delta(uni5):
    """Deletes with no appends (d=0) still fold on device — and stay at the
    frozen-path launch budget (no delta block to scan)."""
    eng = MDRQEngine(uni5, structures=("scan", "kdtree"))
    rng = np.random.default_rng(21)
    dead = rng.choice(uni5.n, 500, replace=False)
    eng.delete(dead)
    oracle = _Oracle(uni5.cols, None, dead)
    queries = _mixed_queries(uni5.m, rng, 4)
    for method in ("scan", "kdtree"):
        for spec in (Ids(), Count(), Agg("sum", 1)):
            for q, res in zip(queries,
                              eng.query_batch(queries, method=method,
                                              spec=spec)):
                oracle.check(spec, q, res)
    ops.reset_counters()
    eng.query_batch(queries, method="scan", spec=Count())
    assert ops.counters() == {"multi_scan_reduce": 1, "host_sync": 1}


# -- launch / host-sync budgets under a live delta ----------------------------

@pytest.mark.parametrize("spec", [Count(), TopK(k=4, dim=2), Agg("sum", 1)],
                         ids=lambda s: s.kind)
def test_reduced_specs_budget_unchanged_with_delta(spec, eng_delta):
    """A non-empty delta changes no budget: the delta block scans inside the
    same fused jit and its payload rides the same host sync."""
    eng, _ = eng_delta
    rng = np.random.default_rng(13)
    queries = _mixed_queries(eng.dataset.m, rng, 6)
    ops.reset_counters()
    eng.query_batch(queries, method="scan", spec=spec)
    assert ops.counters() == {"multi_scan_reduce": 1, "host_sync": 1}
    ops.reset_counters()
    eng.query_batch(queries, method="scan_vertical", spec=spec)
    assert ops.counters() == {"multi_scan_vertical_reduce": 1, "host_sync": 1}
    ops.reset_counters()
    eng.query_batch(queries, method="kdtree", spec=spec)
    assert ops.counters() == {"prune_hierarchy_batch": 1,
                              "multi_visit_reduce": 1, "host_sync": 2}
    ops.reset_counters()
    eng.query_batch(queries, method="vafile", spec=spec)
    assert ops.counters() == {"multi_va_filter": 1, "multi_visit_reduce": 1,
                              "host_sync": 2}


def test_memory_report_includes_delta(eng_delta):
    """Satellite: memory_report carries the delta segment + tombstone bytes."""
    eng, _ = eng_delta
    rep = eng.memory_report()
    assert rep["delta"] == eng.delta.nbytes
    # segment rows + delta tombstones + base tombstone vector all counted
    assert rep["delta"] >= 200 * eng.dataset.m * 4 + eng.dataset.n


# -- compaction ---------------------------------------------------------------

def _tiny_engine(seed=11, m=3, n=1024, **kw):
    rng = np.random.default_rng(seed)
    ds = Dataset(rng.random((m, n), dtype=np.float32))
    kw.setdefault("structures", ("scan", "kdtree"))
    return MDRQEngine(ds, tile_n=256, **kw), rng


def test_compact_swaps_version_and_preserves_results():
    eng, rng = _tiny_engine()
    m, n = eng.dataset.m, eng.dataset.n
    extra = rng.random((50, m)).astype(np.float32)
    new_ids = eng.append(extra)
    dead = np.concatenate([rng.choice(n, 30, replace=False), new_ids[:5]])
    eng.delete(dead)
    oracle = _Oracle(eng.dataset.cols, extra, dead)
    queries = _mixed_queries(m, rng, 4)
    before = eng.query_batch(queries, method="scan")

    id_map = eng.compact()
    assert eng.version == 1
    assert eng.delta.d == 0 and eng.delta.n_total == eng.dataset.n
    assert eng.dataset.n == n + 50 - dead.size
    # the map: -1 exactly on tombstoned ids, a bijection onto the rest
    assert id_map.shape == (n + 50,)
    np.testing.assert_array_equal(np.nonzero(id_map < 0)[0], np.sort(dead))
    kept = id_map[id_map >= 0]
    np.testing.assert_array_equal(np.sort(kept), np.arange(eng.dataset.n))
    # every path answers identically, modulo the id renaming
    for method in ("scan", "kdtree"):
        after = eng.query_batch(queries, method=method)
        for res_b, res_a, q in zip(before, after, queries):
            np.testing.assert_array_equal(res_a, np.sort(id_map[res_b]))
            oracle.check(Ids(), q, res_b)
    # rebuilt-from-scratch engine agrees with the compacted one
    fresh = MDRQEngine(Dataset(oracle.cols[:, oracle.alive]), tile_n=256,
                       structures=("scan",))
    for res_a, res_f in zip(eng.query_batch(queries, method="scan"),
                            fresh.query_batch(queries, method="scan")):
        np.testing.assert_array_equal(res_a, res_f)


def test_compactor_folds_ingest_during_build():
    """Writes that land between build() and commit() survive the swap: late
    appends re-enter the new version's delta, late deletes fold through the
    id map (or tombstone the new delta)."""
    eng, rng = _tiny_engine(seed=12, structures=("scan",))
    m, n = eng.dataset.m, eng.dataset.n
    rows0 = rng.random((20, m)).astype(np.float32)
    ids0 = eng.append(rows0)
    eng.delete([0, 1, int(ids0[0])])

    comp = Compactor(eng)
    comp.build()
    # ingest mid-compaction: an append plus deletes hitting (a) a base row
    # kept by the build, (b) a delta row kept by the build, (c) a late row
    rows1 = rng.random((10, m)).astype(np.float32)
    ids1 = eng.append(rows1)
    eng.delete([5, int(ids0[1]), int(ids1[0])])
    id_map = comp.commit()

    assert eng.version == 1
    dead = np.array([0, 1, ids0[0], 5, ids0[1], ids1[0]])
    assert id_map.shape == (n + 30,)
    np.testing.assert_array_equal(np.nonzero(id_map < 0)[0], np.sort(dead))
    # the late rows live in the new version's delta (one already tombstoned)
    assert eng.delta.d == 10
    assert eng.dataset.n == n + 20 - 3  # build snapshot: 2 base + 1 delta dead
    # Oracle in the NEW id space: new base cols + the late rows, with the
    # late tombstones translated into it by hand. Base ids 0/1 died at build,
    # so kept base id 5 -> 5 - 2; ids0[1] is the first surviving snapshot
    # delta row -> n - 2; ids1[0] is the first new-delta row -> n_new.
    dead_new = [5 - 2, n - 2, eng.dataset.n]
    oracle = _Oracle(eng.dataset.cols, rows1, dead_new)
    queries = _mixed_queries(m, rng, 4)
    for q, res in zip(queries, eng.query_batch(queries, method="scan")):
        oracle.check(Ids(), q, res)


def test_compact_rejects_stale_commit():
    eng, rng = _tiny_engine(seed=13, structures=("scan",))
    eng.append(rng.random((4, eng.dataset.m)).astype(np.float32))
    c1, c2 = Compactor(eng), Compactor(eng)
    c1.build(), c2.build()
    c1.commit()
    with pytest.raises(RuntimeError, match="changed during compaction"):
        c2.commit()


def test_non_delta_aware_path_raises_until_compact():
    eng, rng = _tiny_engine(seed=14, structures=("scan",))

    class Frozen:
        nbytes_index = 0

        def query(self, q):
            return np.empty((0,), np.int64)

        def count(self, q):
            return 0

        def query_batch(self, batch, spec=Ids()):
            return [np.empty((0,), np.int64) for _ in range(len(batch))]

    from repro.core.paths import PerQueryPath

    class FrozenPath(PerQueryPath):
        def query_batch(self, batch, spec=Ids()):  # no delta param
            return super(FrozenPath, self).query_batch(batch, spec=spec)

    eng.register_path(FrozenPath("frozen", Frozen()))
    q = RangeQuery.partial(eng.dataset.m, {})
    eng.query_batch([q], method="frozen")  # empty delta: fine
    eng.append(rng.random((2, eng.dataset.m)).astype(np.float32))
    with pytest.raises(ValueError, match="not delta-aware"):
        eng.query_batch([q], method="frozen")


# -- planning -----------------------------------------------------------------

def test_plan_batch_flips_index_pick_as_delta_grows(uni5):
    """The documented flip: a minority-bucket index pick amortizes the delta
    scan over few queries; as delta_n grows its per-query delta share beats
    the index advantage and plan_batch reassigns it to the scan bucket."""
    hist = Histograms.build(uni5)
    model = CostModel(n=4_000_000, m=uni5.m)
    planner = Planner(hist, model, available=("scan", "kdtree"))
    lo = np.full((uni5.m,), 0.4, np.float32)
    tiny = [RangeQuery.complete(lo, lo + 2e-4) for _ in range(8)]
    broad = [RangeQuery.complete(np.zeros(uni5.m, np.float32),
                                 np.full(uni5.m, 0.9, np.float32))
             for _ in range(24)]
    batch = QueryBatch.from_queries(tiny + broad)

    # planned under Count: the Ids spec adds an O(result) host-materialize
    # term that would mask the delta axis for full-scan picks
    model.delta_n = 0
    bp0 = planner.plan_batch(batch, spec=Count())
    assert bp0.methods[:8] == ["kdtree"] * 8
    assert set(bp0.methods[8:]) == {"scan"}

    model.delta_n = 2_000_000
    bp1 = planner.plan_batch(batch, spec=Count())
    assert bp1.methods == ["scan"] * 32


def test_engine_refreshes_delta_cost_axis(uni5):
    eng = MDRQEngine(uni5, structures=("scan",))
    q = RangeQuery.partial(uni5.m, {0: (0.1, 0.2)})
    eng.query_batch([q], method="scan")
    assert eng.planner.model.delta_n == 0
    eng.append(np.random.default_rng(0).random((64, uni5.m))
               .astype(np.float32))
    eng.query_batch([q], method="scan")
    assert eng.planner.model.delta_n == 64


# -- atomicity under concurrent serve traffic ---------------------------------

def test_compact_swap_atomic_under_concurrent_counts():
    """Background match-all counts during append/delete/compact must only
    ever observe valid cumulative totals: a torn swap (new base without its
    delta, double-counted delta, half-applied tombstones) would surface as
    an off-set count."""
    eng, rng = _tiny_engine(seed=15, n=2048)
    n = eng.dataset.n
    q = RangeQuery.partial(eng.dataset.m, {})
    valid = {n}
    observed, errors = [], []
    stop = threading.Event()

    def prober():
        try:
            while not stop.is_set():
                observed.append(
                    eng.query_batch([q], method="scan", spec=Count())[0])
        except Exception as exc:  # pragma: no cover - surfaced by the assert
            errors.append(exc)

    th = threading.Thread(target=prober)
    th.start()
    live = n
    try:
        for _ in range(3):
            ids = eng.append(rng.random((32, eng.dataset.m))
                             .astype(np.float32))
            live += 32
            valid.add(live)
            eng.delete(ids[:8])
            live -= 8
            valid.add(live)
            eng.compact()  # count-invariant: swap must not change totals
    finally:
        stop.set()
        th.join(timeout=60)
    assert not errors, errors
    assert observed and set(observed) <= valid, \
        (sorted(set(observed) - valid), sorted(valid))
    assert eng.version == 3
    assert eng.query_batch([q], method="scan", spec=Count())[0] == live


# -- calibration guards (satellite) -------------------------------------------

def test_calibrate_no_ops_on_empty_samples(uni5):
    eng = MDRQEngine(uni5, structures=("scan",))
    before = eng.planner.model.sec_per_byte
    report = eng.planner.calibrate([])
    assert report.n_samples == 0 and not report.ok
    assert np.isnan(report.rms_rel_err)
    assert eng.planner.model.sec_per_byte == before
    assert calibration_samples([], eng.planner.model) == []


def test_calibration_pipeline_survives_all_tombstoned_traffic():
    """Traces from a fully tombstoned dataset (every query returns nothing)
    still audit and calibrate without dividing by zero."""
    eng, rng = _tiny_engine(seed=16, structures=("scan",))
    eng.delete(np.arange(eng.dataset.n))
    queries = _mixed_queries(eng.dataset.m, rng, 4)
    eng.query_batch(queries, method="scan", trace=True)
    trace = eng.last_trace
    assert all(qt.result_size == 0 for qt in trace.queries)
    rep = audit_traces([trace])
    assert rep is not None
    samples = calibration_samples([trace], eng.planner.model)
    report = eng.planner.calibrate(samples)
    assert report.n_samples == len(samples)
