"""Access-path registry layer + vectorized fixpoint batch planner.

Covers the three acceptance axes of the refactor: (a) the engine routes
everything through the ``AccessPath`` registry and a test-registered toy path
is planned and executed with no engine changes; (b) batched "auto" execution
is element-identical to per-query "auto" in both result modes (random and
GMRQB workloads) while the launch/host-sync budgets hold; (c) ``plan_batch``
is vectorized (>= 10x over Q scalar ``explain`` calls, asserted coarsely) and
its fixpoint amortizes by *realized* bucket sizes, not the whole batch."""
import time

import numpy as np
import pytest

from repro.core import (Dataset, MDRQEngine, PerQueryPath, QueryBatch,
                        RangeQuery, match_ids_np)
from repro.core.planner import BatchPlan, CostModel, Histograms, Planner
from repro.kernels import ops


def _mixed_queries(cols, rng, n_q):
    """Alternating complete- and partial-match queries around real records."""
    m = cols.shape[0]
    out = []
    for k in range(n_q):
        if k % 2 == 0:
            a = cols[:, rng.integers(cols.shape[1])]
            b = cols[:, rng.integers(cols.shape[1])]
            out.append(RangeQuery.complete(np.minimum(a, b), np.maximum(a, b)))
        else:
            dims = rng.choice(m, size=int(rng.integers(1, m + 1)), replace=False)
            preds = {int(d): tuple(sorted(rng.random(2).tolist())) for d in dims}
            out.append(RangeQuery.partial(m, preds))
    return out


# -- (a) the registry ---------------------------------------------------------

class _ToyNumpyPath:
    """A complete third-party access path: numpy oracle + near-zero cost."""

    name = "toy_numpy"
    plannable = True
    owns_storage = True
    nbytes_index = 123

    def __init__(self, dataset):
        self._cols = dataset.cols
        self.batch_calls = 0

    def query(self, q):
        return match_ids_np(self._cols, q)

    def count(self, q):
        return int(match_ids_np(self._cols, q).size)

    def query_batch(self, batch, mode="ids"):
        self.batch_calls += 1
        if mode == "count":
            return [self.count(batch[k]) for k in range(len(batch))]
        return [self.query(batch[k]) for k in range(len(batch))]

    def cost(self, q, sel, batch, model):
        return 1e-12  # always wins "auto"

    def cost_batch(self, pi, bucket, model):
        return np.full((len(pi),), 1e-12)


def test_toy_path_planned_and_executed_without_engine_changes(uni5):
    """Register a path the engine has never heard of: the planner prices it,
    "auto" routes to it (single and batch), explicit dispatch finds it, and
    the memory report carries it — zero engine edits."""
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    toy = _ToyNumpyPath(uni5)
    eng.register_path(toy)
    assert "toy_numpy" in eng.paths
    assert "toy_numpy" in eng.planner.available
    assert eng.memory_report()["toy_numpy"] == 123

    rng = np.random.default_rng(5)
    queries = _mixed_queries(uni5.cols, rng, 6)
    # single-query auto: the planner must pick the near-free toy path
    res = eng.query(queries[0], method="auto")
    assert eng.last_stats.method == "toy_numpy"
    np.testing.assert_array_equal(res, match_ids_np(uni5.cols, queries[0]))
    # batched auto: one bucket, one toy batch call, oracle-equal results
    batched = eng.query_batch(queries, method="auto")
    assert eng.last_batch_stats.method_counts == {"toy_numpy": 6}
    assert toy.batch_calls == 1
    for q, ids in zip(queries, batched):
        np.testing.assert_array_equal(ids, match_ids_np(uni5.cols, q))
    # explicit dispatch + count mode through the same registry entry
    assert eng.query(queries[1], method="toy_numpy", mode="count") == \
        match_ids_np(uni5.cols, queries[1]).size
    counts = eng.query_batch(queries, method="toy_numpy", mode="count")
    assert counts == [match_ids_np(uni5.cols, q).size for q in queries]


def test_register_path_rejects_incomplete_objects(uni5):
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)

    class _NotAPath:
        name = "broken"

    with pytest.raises(TypeError):
        eng.register_path(_NotAPath())


def test_engine_has_no_dispatch_chains():
    """The refactor's structural guarantee: routing is the registry, not
    per-method if/elif chains in the engine."""
    import inspect
    from repro.core import engine as engine_mod

    src = inspect.getsource(engine_mod)
    for needle in ("_dispatch_batch", "_dispatch_count",
                   'method == "scan"', 'method == "kdtree"',
                   'method == "vafile"', 'method == "rowscan"'):
        assert needle not in src, needle


def test_rowscan_rides_the_per_query_fallback(uni5):
    """RowScan has no fused batch kernel: the generic ``PerQueryPath``
    adapter carries it — batch results equal the oracle, and it never enters
    "auto" planning (plannable=False)."""
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512, rowscan=True)
    assert isinstance(eng.paths["rowscan"], PerQueryPath)
    assert "rowscan" not in eng.planner.available
    rng = np.random.default_rng(7)
    queries = _mixed_queries(uni5.cols, rng, 4)
    for q, ids in zip(queries, eng.query_batch(queries, method="rowscan")):
        np.testing.assert_array_equal(ids, match_ids_np(uni5.cols, q))
    counts = eng.query_batch(queries, method="rowscan", mode="count")
    assert counts == [match_ids_np(uni5.cols, q).size for q in queries]


def test_unknown_method_and_unbuilt_structure_raise(uni5):
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    q = RangeQuery.partial(uni5.m, {0: (0.1, 0.2)})
    with pytest.raises(ValueError, match="unknown method"):
        eng.query(q, method="kdtree")  # built structures only
    with pytest.raises(ValueError, match="unknown method"):
        eng.query_batch([q], method="nope")
    with pytest.raises(ValueError, match="unknown mode"):
        eng.query_batch([q], mode="top_k")


# -- (b) batched auto == per-query auto ---------------------------------------

@pytest.mark.parametrize("mode", ["ids", "count"])
def test_batched_auto_equals_per_query_auto_random(uni5, mode):
    eng = MDRQEngine(uni5, tile_n=512)
    rng = np.random.default_rng(13)
    queries = _mixed_queries(uni5.cols, rng, 8)
    rec = uni5.cols[:, 7]
    queries.append(RangeQuery.complete(rec, rec))     # point query
    queries.append(RangeQuery.partial(uni5.m, {}))    # match-all
    batched = eng.query_batch(queries, method="auto", mode=mode)
    for q, res in zip(queries, batched):
        single = eng.query(q, method="auto", mode=mode)
        if mode == "count":
            assert res == single == match_ids_np(uni5.cols, q).size
        else:
            np.testing.assert_array_equal(res, single)
            np.testing.assert_array_equal(res, match_ids_np(uni5.cols, q))


@pytest.mark.parametrize("mode", ["ids", "count"])
def test_batched_auto_equals_per_query_auto_gmrqb(mode):
    from repro.data import gmrqb

    ds = gmrqb.build(8192, seed=5)
    eng = MDRQEngine(ds, tile_n=1024)
    rng = np.random.default_rng(11)
    queries = [gmrqb.template(k, rng, ds) for k in (1, 2, 4, 5, 7, 8)]
    batched = eng.query_batch(queries, method="auto", mode=mode)
    for q, res in zip(queries, batched):
        single = eng.query(q, method="auto", mode=mode)
        if mode == "count":
            assert res == single == match_ids_np(ds.cols, q).size
        else:
            np.testing.assert_array_equal(res, single)
            np.testing.assert_array_equal(res, match_ids_np(ds.cols, q))


def test_auto_batch_launch_budget_one_per_bucket(uni5):
    """The registry didn't change the launch structure: an auto-planned batch
    that buckets to the fused scan still costs one launch + one host sync."""
    eng = MDRQEngine(uni5, structures=("scan",), tile_n=512)
    rng = np.random.default_rng(3)
    queries = _mixed_queries(uni5.cols, rng, 8)
    ops.reset_counters()
    eng.query_batch(queries, method="auto")
    n_buckets = len(eng.last_batch_stats.method_counts)
    launches = (ops.counter("multi_scan_reduce")
                + ops.counter("multi_scan_vertical_reduce"))
    assert launches == n_buckets
    assert ops.counter("host_sync") == n_buckets


# -- (c) vectorized fixpoint planning -----------------------------------------

def test_plan_batch_vectorized_speedup(uni5):
    """Planning a 128-query batch must beat 128 scalar explain calls by >=
    10x (coarse wall-clock bound; bench_throughput reports the precise
    number via BatchStats.plan_seconds)."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=1_000_000, m=5))
    rng = np.random.default_rng(17)
    queries = _mixed_queries(uni5.cols, rng, 128)
    batch = QueryBatch.from_queries(queries)
    p.plan_batch(batch)  # warm any lazy numpy paths

    t_scalar = min(_timed(lambda: [p.explain(q, batch_size=128)
                                   for q in queries]) for _ in range(3))
    t_vec = min(_timed(lambda: p.plan_batch(batch)) for _ in range(3))
    assert t_scalar > 10 * t_vec, (t_scalar, t_vec)


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def test_plan_batch_fixpoint_uses_realized_buckets(uni5):
    """A selective query co-batched with 127 scan-bound queries: under the
    old whole-batch amortization the tree wins it (every fixed tax divided by
    128), but its *realized* tree bucket would hold one query — the fixpoint
    re-prices with that bucket and moves it onto the big scan bucket, whose
    amortization is real. The final plan differs from what ``len(batch)``
    amortization (and from what batch_size=1) would choose.

    Planned under ``Count()`` so the result-payload term is negligible and
    the scenario isolates the amortization effect (under ``Ids()`` the
    scan's n-byte mask readback dominates at n=10M and the tree keeps the
    selective query on output-bytes grounds — that spec-dependent flip is
    covered by test_result_specs.py).
    """
    from repro.core import Count

    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=10_000_000, m=5),
                available=("scan", "kdtree"))
    wide = RangeQuery.complete([0.0] * 5, [0.9] * 5)
    selective = RangeQuery.complete([0.0] * 5, [0.1] * 5)
    batch = QueryBatch.from_queries([wide] * 127 + [selective])

    # whole-batch amortization (the seed's explain_batch semantics): tree
    assert p.explain(selective, batch_size=len(batch),
                     spec=Count()).method == "kdtree"
    assert p.explain_batch(batch.queries, spec=Count())[-1].method == "kdtree"
    # realized-bucket fixpoint: the one-query tree bucket can't pay its own
    # host-sync tax, the 128-query scan bucket amortizes for free -> scan
    bp = p.plan_batch(batch, spec=Count())
    assert isinstance(bp, BatchPlan)
    assert bp.methods[-1] == "scan"
    assert bp.bucket_sizes == {"scan": 128}
    assert bp.converged and 2 <= bp.n_iterations <= 4
    assert bp.est_selectivity.shape == (128,)


def test_plan_batch_matches_engine_buckets(uni5):
    """The buckets the fixpoint priced are the buckets the engine executes,
    and the planning share of the wall time is recorded separately."""
    eng = MDRQEngine(uni5, tile_n=512)
    rng = np.random.default_rng(29)
    queries = _mixed_queries(uni5.cols, rng, 16)
    bp = eng.planner.plan_batch(QueryBatch.from_queries(queries))
    eng.query_batch(queries, method="auto")
    stats = eng.last_batch_stats
    assert stats.method_counts == bp.bucket_sizes
    assert sum(bp.bucket_sizes.values()) == 16
    assert 0.0 < stats.plan_seconds <= stats.seconds


def test_explain_batch_matches_scalar_explain(uni5):
    """The vectorized whole-batch pass must reproduce the scalar cost dicts
    (same paths, same numbers) — the two formulations cannot drift."""
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=1_000_000, m=5))
    rng = np.random.default_rng(23)
    queries = _mixed_queries(uni5.cols, rng, 9)
    queries.append(RangeQuery.partial(uni5.m, {}))  # match-all edge
    for q, pb in zip(queries, p.explain_batch(queries)):
        ps = p.explain(q, batch_size=len(queries))
        assert set(pb.costs) == set(ps.costs)
        for name in pb.costs:
            assert np.isclose(pb.costs[name], ps.costs[name],
                              rtol=1e-9, atol=0.0), name
        assert pb.method == ps.method
        assert np.isclose(pb.est_selectivity, ps.est_selectivity, rtol=0,
                          atol=0)


def test_plan_batch_single_query_and_empty(uni5):
    hist = Histograms.build(uni5)
    p = Planner(hist, CostModel(n=uni5.n, m=5))
    q = RangeQuery.partial(5, {0: (0.1, 0.3)})
    bp = p.plan_batch(QueryBatch.from_queries([q]))
    assert len(bp.methods) == 1 and bp.methods[0] in p.available
    assert p.explain_batch([]) == []
