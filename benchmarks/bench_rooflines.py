"""Deliverable (g): per-cell roofline terms from the dry-run artifacts."""
import glob
import json
import os

from benchmarks.common import emit_row


def run(quick: bool = True) -> None:
    pat = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun",
                       "*.json")
    files = sorted(glob.glob(pat))
    if not files:
        emit_row("roofline/none", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{r.get('tag','')}"
        if r.get("status") != "ok":
            emit_row(name, 0.0, f"skipped:{r.get('reason','?')[:60]}")
            continue
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ratio = r.get("useful_flops_ratio") or 0.0
        emit_row(name, step_s * 1e6,
                 f"dominant={r['dominant']};compute_s={r['compute_s']:.4f};"
                 f"memory_s={r['memory_s']:.4f};collective_s={r['collective_s']:.4f};"
                 f"useful_flops_ratio={ratio:.3f}")
