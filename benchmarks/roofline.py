"""Roofline-term extraction from dry-run compiled artifacts.

Three terms per (arch x shape x mesh) cell — TPU v5e targets:

  compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective = collective_bytes_per_device / link_bw    (~50 GB/s ICI)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition module after
SPMD). Collective bytes are parsed from the post-partitioning HLO text: we sum
the *result* shapes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute instruction (documented convention: result
bytes ~ bytes crossing the link per device per step; all-reduce counted 2x for
the reduce+broadcast halves of a ring).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,4096,960]{2,1,0}" or "f32[128]"  (shape part of an HLO result)
_SHAPE_RE = re.compile(r"(pred|[sucf]\d+|bf16)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective op type from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # instruction lines look like: "%name = TYPE op-name(...)" or fused.
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        rest = m.group(1)
        for op in _COLLECTIVES:
            # match the op as the instruction verb: "... = <shape> all-reduce("
            if re.search(rf"\b{op}(?:-start|-done)?\(", rest):
                # result type precedes the verb
                type_part = rest.split(op)[0]
                if op.endswith("done") or "-done(" in rest:
                    continue
                out[op] += _shape_bytes(type_part)
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collectives: dict[str, int]

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        # all-reduce moves ~2x its payload on a ring (reduce + broadcast)
        ar2 = self.collectives.get("all-reduce", 0)
        return (self.collective_bytes + ar2) / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def summary(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
            "collectives_by_op": self.collectives,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def extrapolate(rl1: "Roofline", rl2: "Roofline", units: int) -> "Roofline":
    """cost(L) = cost(1) + (L-1) * (cost(2) - cost(1)) — exact for homogeneous
    layer stacks (constant terms: embed/unembed/loss; linear terms: layers)."""
    k = units - 1

    def ext(a, b):
        return a + k * (b - a)

    coll = {op: int(ext(rl1.collectives.get(op, 0), rl2.collectives.get(op, 0)))
            for op in set(rl1.collectives) | set(rl2.collectives)}
    coll = {op: max(0, v) for op, v in coll.items()}
    return Roofline(
        max(0.0, ext(rl1.flops_per_device, rl2.flops_per_device)),
        max(0.0, ext(rl1.bytes_per_device, rl2.bytes_per_device)),
        float(sum(coll.values())), coll)


def from_compiled(compiled, hlo_text: Optional[str] = None) -> Roofline:
    """Build roofline terms from a compiled executable."""
    costs = compiled.cost_analysis() or {}
    if isinstance(costs, list):  # older jax returns [dict]
        costs = costs[0] if costs else {}
    flops = float(costs.get("flops", 0.0))
    byts = float(costs.get("bytes accessed", costs.get("bytes_accessed", 0.0)))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return Roofline(flops, byts, float(sum(coll.values())), coll)


def model_flops(cfg, tokens: float, train: bool) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); forward-only = 2*N*D."""
    counts = cfg.param_counts()
    mult = 6.0 if train else 2.0
    return mult * counts["active"] * tokens
