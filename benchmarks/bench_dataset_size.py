"""Fig. 7: throughput vs dataset size (10k..1M, 5 dims, sel ~0.4%)."""
import numpy as np

from benchmarks.common import emit_row, qps
from repro.core import MDRQEngine
from repro.data import synthetic


def run(quick: bool = True) -> None:
    sizes = (10_000, 100_000, 1_000_000) if not quick else (10_000, 100_000, 400_000)
    rng = np.random.default_rng(3)
    for n in sizes:
        ds = synthetic.synt_uni(n, 5, seed=1)
        eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
        queries = [synthetic.selectivity_targeted_query(ds, 0.004, rng)
                   for _ in range(15)]
        for meth in ("scan", "kdtree", "vafile"):
            r = qps(eng, queries, meth)
            emit_row(f"fig7/n{n}/{meth}", 1e6 / r, f"qps={r:.1f}")
