"""Fig. 11: scaling vs #parallel units (1..8 host devices, sharded scan)."""
import os
import subprocess
import sys

from benchmarks.common import emit_row

SCRIPT = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={k}'
os.environ['REPRO_KERNEL_BACKEND'] = 'xla'
import numpy as np, time
from repro.core import DistributedScan
from repro.core.distributed import make_data_mesh
from repro.data import gmrqb
ds = gmrqb.build(200000, seed=0)
d = DistributedScan(ds, mesh=make_data_mesh({k}))
rng = np.random.default_rng(1)
qs = [gmrqb.template(int(rng.integers(1, 8)), rng, ds) for _ in range(20)]
[d.query(q) for q in qs[:3]]
t0 = time.perf_counter()
[d.query(q) for q in qs]
print('RESULT', (time.perf_counter() - t0) / len(qs))
"""


def run(quick: bool = True) -> None:
    for k in (1, 2, 4, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.pop("XLA_FLAGS", None)
        r = subprocess.run([sys.executable, "-c", SCRIPT.format(k=k)],
                           capture_output=True, text=True, timeout=900, env=env)
        for line in r.stdout.splitlines():
            if line.startswith("RESULT"):
                dt = float(line.split()[1])
                emit_row(f"fig11/devices{k}/scan", dt * 1e6, f"qps={1/dt:.1f}")
