"""Batched-execution throughput: queries/sec over GMRQB template mixes.

Sweeps the serving batch size over {1, 8, 32, 128} with the fused multi-query
kernels underneath (``MDRQEngine.query_batch`` via ``MDRQServer``) — the
inter-query analogue of the paper's intra-query scaling figures. Batch 1 is
the seed engine's per-query regime, so the B{128}/B{1} speedup row is the
amortization headline. Like every benchmark here, CPU numbers use the XLA
backend as the honest proxy (see common.py); real kernel numbers are TPU.
"""
import numpy as np

from benchmarks.common import emit_row
from repro.core import MDRQEngine
from repro.data import gmrqb
from repro.serve.mdrq_server import MDRQServer

BATCH_SIZES = (1, 8, 32, 128)


def _throughput(eng, queries, batch: int, method: str = "auto"):
    """(qps, whole-workload method_counts) through a fresh serving window."""
    server = MDRQServer(eng, max_batch=batch, max_wait_s=float("inf"),
                        method=method)
    server.serve_all(queries[: 2 * batch])  # warmup (jit + retrace buckets)
    server.stats = type(server.stats)()
    server.serve_all(queries)
    return server.stats.qps, server.stats.method_counts


def run(quick: bool = True) -> None:
    n = 200_000 if quick else 1_000_000
    ds = gmrqb.build(n, seed=0)
    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
    n_queries = 128 if quick else 256

    # Mixed workload (all 8 templates interleaved) across batch sizes.
    mixed = [q for _, q in gmrqb.mixed_workload(ds, n_queries, seed=2)]
    base = None
    for b in BATCH_SIZES:
        r, _ = _throughput(eng, mixed, b)
        base = base or r
        emit_row(f"throughput/mixed/B{b}", 1e6 / r,
                 f"qps={r:.1f};speedup_vs_B1={r / base:.2f}x")

    # Per-template mixes at the largest batch: which access path carries the
    # throughput for each selectivity band.
    rng = np.random.default_rng(3)
    for k in (1, 4, 8):
        queries = [gmrqb.template(k, rng, ds) for _ in range(n_queries)]
        r, counts = _throughput(eng, queries, BATCH_SIZES[-1])
        emit_row(f"throughput/T{k}/B{BATCH_SIZES[-1]}", 1e6 / r,
                 f"qps={r:.1f};buckets={'+'.join(sorted(counts))}")

    # Fixed-method sweep: isolates the fused-kernel win from planner choices.
    for meth in ("scan", "scan_vertical"):
        r1, _ = _throughput(eng, mixed, 1, method=meth)
        rb, _ = _throughput(eng, mixed, BATCH_SIZES[-1], method=meth)
        emit_row(f"throughput/{meth}/B{BATCH_SIZES[-1]}", 1e6 / rb,
                 f"qps={rb:.1f};speedup_vs_B1={rb / r1:.2f}x")
