"""Batched-execution throughput: queries/sec over GMRQB template mixes.

Sweeps the serving batch size over {1, 8, 32, 128} with the fused multi-query
kernels underneath (``MDRQEngine.query_batch`` via ``MDRQServer``) — the
inter-query analogue of the paper's intra-query scaling figures. Batch 1 is
the seed engine's per-query regime, so the B{128}/B{1} speedup row is the
amortization headline. Like every benchmark here, CPU numbers use the XLA
backend as the honest proxy (see common.py); real kernel numbers are TPU.

Result shapes ride the ResultSpec layer: every row carries a ``result_spec``
column, ``--spec {ids,count,mask,topk,agg}`` selects the shape for the mixed
sweep, and ``run_specs`` (the ``--spec topk`` / ``--spec agg`` CI smoke rows)
compares reduced shapes against ids at the largest batch — the reduced
payload (O(k)/O(1) bytes over the device->host boundary instead of a mask)
is the row-to-row delta. ``run_count`` keeps the PR 2 count-only sweep.
"""
import os
import sys
import time

if __name__ == "__main__":  # direct module run: set the backend before any
    os.environ.setdefault("REPRO_KERNEL_BACKEND", "xla")  # repro import
    if "--devices" in sys.argv:
        # the device count locks at first XLA init, so the CPU proxy for the
        # cross-device sweep must be forced before anything imports jax
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from benchmarks.common import emit_row, write_bench_json
from repro.core import Agg, Count, Ids, Mask, MDRQEngine, TopK
from repro.data import gmrqb
from repro.serve.mdrq_server import MDRQServer

BATCH_SIZES = (1, 8, 32, 128)

# The --spec vocabulary: one representative instance per registered kind
# (GMRQB dim 0 = the age attribute for top-k/aggregates).
SPEC_CHOICES = {
    "ids": Ids(),
    "count": Count(),
    "mask": Mask(),
    "topk": TopK(k=10, dim=0),
    "agg": Agg("sum", 0),
}


def _throughput(eng, queries, batch: int, method: str = "auto",
                spec=Ids()):
    """(qps, whole-workload ServerStats) through a fresh serving window."""
    server = MDRQServer(eng, max_batch=batch, max_wait_s=float("inf"),
                        method=method, spec=spec)
    server.serve_all(queries[: 2 * batch])  # warmup (jit + retrace buckets)
    server.stats = type(server.stats)()
    server.serve_all(queries)
    return server.stats.qps, server.stats


def _plan_us(stats) -> float:
    """Planning microseconds per query (BatchStats.plan_seconds, aggregated
    by the server) — isolates the vectorized fixpoint planner's cost from
    kernel time in every throughput row."""
    return 1e6 * stats.plan_seconds / max(stats.n_queries, 1)


def _workload(quick: bool, smoke: bool = False):
    if smoke:
        n, n_queries = 20_000, 32
    else:
        n, n_queries = (200_000, 128) if quick else (1_000_000, 256)
    ds = gmrqb.build(n, seed=0)
    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
    mixed = [q for _, q in gmrqb.mixed_workload(ds, n_queries, seed=2)]
    return eng, mixed, n_queries


def run(quick: bool = True, spec=Ids()) -> None:
    eng, mixed, n_queries = _workload(quick)
    kind = spec.kind

    # Mixed workload (all 8 templates interleaved) across batch sizes.
    base = None
    for b in BATCH_SIZES:
        r, stats = _throughput(eng, mixed, b, spec=spec)
        base = base or r
        emit_row(f"throughput/mixed/B{b}", 1e6 / r,
                 f"qps={r:.1f};speedup_vs_B1={r / base:.2f}x;"
                 f"plan_us_per_q={_plan_us(stats):.1f}", result_spec=kind)

    # Per-template mixes at the largest batch: which access path carries the
    # throughput for each selectivity band.
    rng = np.random.default_rng(3)
    for k in (1, 4, 8):
        queries = [gmrqb.template(k, rng, eng.dataset) for _ in range(n_queries)]
        r, stats = _throughput(eng, queries, BATCH_SIZES[-1], spec=spec)
        emit_row(f"throughput/T{k}/B{BATCH_SIZES[-1]}", 1e6 / r,
                 f"qps={r:.1f};buckets={'+'.join(sorted(stats.method_counts))};"
                 f"plan_us_per_q={_plan_us(stats):.1f}", result_spec=kind)

    # Fixed-method sweep: isolates the fused-kernel win from planner choices.
    for meth in ("scan", "scan_vertical"):
        r1, _ = _throughput(eng, mixed, 1, method=meth, spec=spec)
        rb, _ = _throughput(eng, mixed, BATCH_SIZES[-1], method=meth,
                            spec=spec)
        emit_row(f"throughput/{meth}/B{BATCH_SIZES[-1]}", 1e6 / rb,
                 f"qps={rb:.1f};speedup_vs_B1={rb / r1:.2f}x",
                 result_spec=kind)


def run_count(quick: bool = True) -> None:
    """Count-only result mode sweep (``--spec count`` / ``make bench-count``)."""
    eng, mixed, _ = _workload(quick)

    base = None
    for b in BATCH_SIZES:
        r, _ = _throughput(eng, mixed, b, spec=Count())
        base = base or r
        emit_row(f"throughput/count/mixed/B{b}", 1e6 / r,
                 f"qps={r:.1f};speedup_vs_B1={r / base:.2f}x",
                 result_spec="count")

    # Count-vs-ids at the largest batch: the id-materialization tax, per path.
    for meth in ("scan", "vafile"):
        r_ids, _ = _throughput(eng, mixed, BATCH_SIZES[-1], method=meth)
        r_cnt, _ = _throughput(eng, mixed, BATCH_SIZES[-1], method=meth,
                               spec=Count())
        emit_row(f"throughput/count/{meth}/B{BATCH_SIZES[-1]}", 1e6 / r_cnt,
                 f"qps={r_cnt:.1f};count_vs_ids={r_cnt / r_ids:.2f}x",
                 result_spec="count")


def run_specs(quick: bool = True, smoke: bool = False,
              kinds=("topk", "agg")) -> None:
    """Reduced-result-shape sweep: one row per spec kind at the largest
    batch, with the spec/ids qps ratio isolating the result-materialization
    tax the on-device reducers remove. ``smoke=True`` runs CI-sized inputs
    so a reducer performance regression surfaces in CI logs (`make
    bench-specs-smoke`)."""
    eng, mixed, _ = _workload(quick, smoke=smoke)
    batch = 32 if smoke else BATCH_SIZES[-1]
    r_ids, _ = _throughput(eng, mixed, batch)
    emit_row(f"throughput/spec/B{batch}", 1e6 / r_ids, f"qps={r_ids:.1f}",
             result_spec="ids")
    for kind in kinds:
        spec = SPEC_CHOICES[kind]
        r, stats = _throughput(eng, mixed, batch, spec=spec)
        emit_row(f"throughput/spec/B{batch}", 1e6 / r,
                 f"qps={r:.1f};vs_ids={r / r_ids:.2f}x;"
                 f"buckets={'+'.join(sorted(stats.method_counts))}",
                 result_spec=kind)


def run_smoke(json_path: str = "BENCH_smoke.json", spec=Ids()) -> None:
    """The CI smoke artifact: per-batch-size qps + p50/p95/p99 queue and
    execute latency over the mixed workload at CI-sized inputs, written to
    ``json_path`` (``make bench-smoke`` -> ``BENCH_smoke.json``).

    ``benchmarks.check_bench`` diffs a fresh run of this against the
    checked-in baseline with a +-30% qps guard band (warn-only), so a
    serving-path throughput regression surfaces in CI logs without making a
    noisy shared runner fail the build.
    """
    eng, mixed, n_queries = _workload(quick=True, smoke=True)
    kind = spec.kind
    batches = []
    for b in BATCH_SIZES:
        server = MDRQServer(eng, max_batch=b, max_wait_s=float("inf"),
                            method="auto", spec=spec)
        server.serve_all(mixed[: 2 * b])  # warmup (jit + retrace buckets)
        server.stats = type(server.stats)()
        server.serve_all(mixed)
        stats = server.stats
        lat = stats.latency_percentiles(kind)
        emit_row(f"smoke/B{b}", 1e6 / stats.qps,
                 f"qps={stats.qps:.1f};"
                 f"p50_exec_us={1e6 * lat['execute'].get('p50', 0):.1f};"
                 f"p99_exec_us={1e6 * lat['execute'].get('p99', 0):.1f}",
                 result_spec=kind)
        batches.append({
            "batch": b,
            "qps": round(stats.qps, 2),
            "mean_batch_size": round(stats.mean_batch_size, 2),
            "plan_us_per_q": round(_plan_us(stats), 2),
            "method_counts": stats.method_counts,
            "flush_reasons": stats.flush_reasons,
            "latency_seconds": lat,
        })
    write_bench_json(
        json_path, "smoke",
        backend=os.environ.get("REPRO_KERNEL_BACKEND", "auto"),
        n=eng.dataset.n, n_queries=n_queries, spec=kind, batches=batches)


def run_ingest(quick: bool = True, smoke: bool = False) -> None:
    """Serve-while-ingest sweep: qps vs delta fraction (``make bench-ingest``).

    Grows the delta segment to {0, 0.5, 1, 2, 5}% of the base dataset (with
    ~10% of each appended slab immediately tombstoned — writes in both
    directions), re-measuring mixed-workload Count qps at the largest batch
    after each step. The ``vs_delta0`` column is the serving tax of the
    un-compacted write path: every batch pays one extra delta-block scan
    inside the same fused launch, so the tax should track the delta's byte
    fraction, not a per-query launch penalty. A final compaction row
    (fresh structures, empty delta) closes the loop — qps recovers to the
    frozen-path rate and the row carries the compact() wall time.

    The ingest ops go through ``MDRQServer.append``/``delete``/``compact``
    so each step also exercises the window-flush interleaving that serving
    traffic sees (flush_reason="ingest").
    """
    eng, mixed, _ = _workload(quick, smoke=smoke)
    batch = 32 if smoke else BATCH_SIZES[-1]
    rng = np.random.default_rng(7)
    n = eng.dataset.n
    ingest = MDRQServer(eng, max_batch=batch, max_wait_s=float("inf"),
                        spec=Count())

    base_qps = None
    for frac in (0.0, 0.005, 0.01, 0.02, 0.05):
        target = int(round(frac * n))
        grow = target - eng.delta.d
        if grow > 0:
            new_ids = ingest.append(
                rng.random((grow, eng.dataset.m)).astype(np.float32))
            if grow >= 10:
                ingest.delete(new_ids[:: 10])
        r, stats = _throughput(eng, mixed, batch, spec=Count())
        base_qps = base_qps or r
        emit_row(f"throughput/ingest/delta{100 * frac:g}pct/B{batch}",
                 1e6 / r,
                 f"qps={r:.1f};vs_delta0={r / base_qps:.2f}x;"
                 f"delta_rows={eng.delta.d};"
                 f"plan_us_per_q={_plan_us(stats):.1f}",
                 result_spec="count")

    t0 = time.perf_counter()
    ingest.compact()
    compact_s = time.perf_counter() - t0
    r, _ = _throughput(eng, mixed, batch, spec=Count())
    emit_row(f"throughput/ingest/compacted/B{batch}", 1e6 / r,
             f"qps={r:.1f};vs_delta0={r / base_qps:.2f}x;"
             f"compact_s={compact_s:.3f};n={eng.dataset.n}",
             result_spec="count")


def _offered_load_pass(srv, queries, offered_qps: float) -> tuple[float, int]:
    """Open-loop driver: Poisson-free fixed-rate arrivals at ``offered_qps``.

    Submits each query at its scheduled arrival instant (polling the server's
    deadline flush while waiting — the real admission-loop shape), then
    drains. Returns (wall seconds, queries shed at admission). Unlike the
    closed-loop ``serve_all``, a saturated server here keeps receiving
    arrivals it cannot absorb — exactly the regime admission control exists
    for."""
    interval = 1.0 / offered_qps
    t0 = time.perf_counter()
    n_shed = 0
    for i, q in enumerate(queries):
        target = t0 + i * interval
        while True:
            now = time.perf_counter()
            if now >= target:
                break
            srv.poll()
            time.sleep(min(target - now, 2e-4))
        if getattr(srv.submit(q), "shed", False):
            n_shed += 1
    srv.drain()
    return time.perf_counter() - t0, n_shed


# Offered load as a fraction of the measured closed-loop pipelined qps —
# machine-independent keys, so check_bench can diff points across runs whose
# absolute qps differ.
OFFERED_FRACS = (0.25, 0.5, 0.75, 1.0, 1.25, 1.5)


def run_pipeline(quick: bool = True, smoke: bool = False,
                 json_path: str = "BENCH_pipeline.json") -> None:
    """Pipelined-serving bench (``--offered-load`` / ``make bench-pipeline-smoke``).

    Two sections, written to ``json_path``:

      * head-to-head: closed-loop qps of the synchronous ``MDRQServer`` vs
        the AOT-warmed ``PipelinedMDRQServer`` at the largest batch — the
        double-buffering win (device stage overlapping host finalize);
      * offered-load sweep: fixed-rate arrivals at fractions of the
        pipelined closed-loop qps, recording achieved qps, shed fraction,
        and p99 queue/execute latency per point. The *saturation knee* is
        the highest offered load the server absorbs (achieved >= 90% of
        offered, sheds < 1%); past it, admission control sheds instead of
        letting queue latency diverge.
    """
    from repro.kernels import ops
    from repro.serve import serve_pipelined

    eng, mixed, n_queries = _workload(quick, smoke=smoke)
    batch = 32 if smoke else BATCH_SIZES[-1]

    sync_qps, _ = _throughput(eng, mixed, batch)
    emit_row(f"pipeline/sync/B{batch}", 1e6 / sync_qps, f"qps={sync_qps:.1f}")

    with serve_pipelined(eng, max_batch=batch, max_wait_s=float("inf"),
                         warmup=True, latency_budget_s=1e9) as srv:
        wrep = srv.last_warmup
        srv.serve_all(mixed[: 2 * batch])   # post-warmup dry pass
        srv.drain()
        srv.reset_stats()
        srv.serve_all(mixed)
        srv.drain()
        pipe_qps = srv.stats.qps
    emit_row(f"pipeline/pipelined/B{batch}", 1e6 / pipe_qps,
             f"qps={pipe_qps:.1f};vs_sync={pipe_qps / sync_qps:.2f}x;"
             f"aot_compiled={wrep.n_compiled};"
             f"warmup_s={wrep.seconds:.2f}")

    # Offered-load sweep on a server with a *real* latency budget (~8
    # windows of drain time) so saturation sheds instead of queueing.
    budget = max(0.05, 8 * batch / pipe_qps)
    points, knee = [], 0.0
    with serve_pipelined(eng, max_batch=batch, max_wait_s=5e-3,
                         warmup=True, backlog=4,
                         latency_budget_s=budget) as srv:
        for frac in OFFERED_FRACS:
            offered = frac * pipe_qps
            srv.reset_stats()
            wall, n_shed = _offered_load_pass(srv, mixed, offered)
            st = srv.stats
            achieved = st.n_queries / wall
            shed_frac = n_shed / len(mixed)
            lat = st.latency_percentiles("ids")
            p99q = lat["queue"].get("p99", 0.0) if lat["queue"] else 0.0
            p99x = lat["execute"].get("p99", 0.0) if lat["execute"] else 0.0
            if shed_frac < 0.01 and achieved >= 0.9 * offered:
                knee = max(knee, offered)
            points.append({
                "frac": frac,
                "offered_qps": round(offered, 2),
                "achieved_qps": round(achieved, 2),
                "shed_frac": round(shed_frac, 4),
                "p99_queue_s": round(p99q, 6),
                "p99_execute_s": round(p99x, 6),
            })
            emit_row(f"pipeline/offered{frac:g}x/B{batch}", 1e6 / achieved,
                     f"qps={achieved:.1f};offered={offered:.1f};"
                     f"shed={100 * shed_frac:.1f}%;"
                     f"p99_queue_us={1e6 * p99q:.0f}")

    write_bench_json(
        json_path, "pipeline",
        backend=os.environ.get("REPRO_KERNEL_BACKEND", "auto"),
        n=eng.dataset.n, n_queries=n_queries, batch=batch,
        head_to_head={"sync_qps": round(sync_qps, 2),
                      "pipelined_qps": round(pipe_qps, 2),
                      "speedup": round(pipe_qps / sync_qps, 3)},
        warmup={"n_runs": wrep.n_runs, "n_compiled": wrep.n_compiled,
                "seconds": round(wrep.seconds, 3),
                "aot_hits": ops.aot_counters().get("hit", 0)},
        latency_budget_s=round(budget, 4),
        knee_qps=round(knee, 2),
        offered=points)


def run_devices(quick: bool = True) -> None:
    """Cross-device batched-scan sweep (``--devices`` / ``make bench-dist``).

    Shards the dataset over 1/2/4/8-device meshes and drives the fixed
    ``scan`` path through ``DistributedScan`` at the largest batch, in both
    result modes. On CPU the devices are ``xla_force_host_platform_device_
    count`` shards of one socket — the honest proxy for *launch structure*
    (one collective per batch), not for bandwidth scaling, which needs a real
    TPU mesh (every CPU "device" shares the same memory bus).
    """
    import jax

    from repro.core.distributed import make_data_mesh

    avail = len(jax.devices())
    if avail < 2:
        print("# run_devices: single-device process; run via "
              "`make bench-dist` (or --devices) for the 8-device CPU proxy",
              flush=True)
    n = 200_000 if quick else 1_000_000
    ds = gmrqb.build(n, seed=0)
    queries = [q for _, q in gmrqb.mixed_workload(ds, 128, seed=2)]
    batch = BATCH_SIZES[-1]
    base: dict = {}
    for d in (1, 2, 4, 8):
        if d > avail:
            continue
        # one engine (one pad + shard placement) per mesh size, both modes
        eng = MDRQEngine(ds, structures=("scan",), mesh=make_data_mesh(d))
        for spec in (Ids(), Count()):
            r, _ = _throughput(eng, queries, batch, method="scan", spec=spec)
            base.setdefault(spec.kind, r)
            emit_row(f"throughput/dist/{spec.kind}/D{d}/B{batch}", 1e6 / r,
                     f"qps={r:.1f};speedup_vs_D1={r / base[spec.kind]:.2f}x",
                     result_spec=spec.kind)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--spec", choices=tuple(SPEC_CHOICES), default="ids",
                    help="result spec to sweep (reduced kinds run the "
                         "spec-vs-ids comparison section)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs (tiny n, one spec row) — the "
                         "reducer-regression smoke")
    ap.add_argument("--ingest", action="store_true",
                    help="serve-while-ingest sweep: qps vs delta fraction, "
                         "plus the post-compaction recovery row")
    ap.add_argument("--offered-load", action="store_true",
                    help="pipelined serving bench: sync-vs-pipelined "
                         "head-to-head plus the qps-vs-offered-load sweep "
                         "(saturation knee, p99 under load, shed fraction) "
                         "-> BENCH_pipeline.json")
    ap.add_argument("--devices", action="store_true",
                    help="cross-device batched scan sweep (forces an "
                         "8-device CPU platform when XLA_FLAGS is unset)")
    ap.add_argument("--json", default="",
                    help="with --spec ids --smoke: write the per-batch-size "
                         "qps/latency artifact here (BENCH_smoke.json)")
    args = ap.parse_args()
    from benchmarks.common import CSV_HEADER
    print(CSV_HEADER, flush=True)
    if args.offered_load:
        run_pipeline(quick=not args.full, smoke=args.smoke,
                     json_path=args.json or "BENCH_pipeline.json")
    elif args.devices:
        run_devices(quick=not args.full)
    elif args.ingest:
        run_ingest(quick=not args.full, smoke=args.smoke)
    elif args.spec == "count":
        run_count(quick=not args.full)
    elif args.spec in ("topk", "agg", "mask"):
        run_specs(quick=not args.full, smoke=args.smoke, kinds=(args.spec,))
    elif args.smoke:
        run_smoke(json_path=args.json or "BENCH_smoke.json")
    else:
        run(quick=not args.full)
