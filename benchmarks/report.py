"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh: str, tag: str = "") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") == mesh and r.get("tag", "") == tag:
            out.append(r)
    return out


def fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b/1e9:.1f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def roofline_table(mesh: str = "16x16", tag: str = "") -> str:
    rows = [
        "| arch | shape | kind | compute (s) | memory (s) | collective (s) | "
        "dominant | useful-flops | peak temp/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh, tag):
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                        f"skipped: {r.get('reason','')[:70]} |")
            continue
        temp = r.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
        u = r.get("useful_flops_ratio")
        note = ""
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{u:.2f} | {fmt_bytes(temp)} | {note} |")
    return "\n".join(rows)


def dryrun_table(mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | status | compile (s) | FLOPs/dev | HLO bytes/dev | "
        "collective bytes/dev (AR/AG/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — | — |")
            continue
        c = r["collectives_by_op"]
        coll = "/".join(fmt_bytes(c.get(k, 0)) for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.1f} | "
            f"{r['flops_per_device']:.3e} | {fmt_bytes(r['bytes_per_device'])} | {coll} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    print(roofline_table(mesh) if which == "roofline" else dryrun_table(mesh))
