"""§7.2 memory comparison: auxiliary bytes of each MDIS vs the raw data
(paper: MDIS need 2.5x-5.4x the scan's space; our blocked structures are
far leaner because nodes are implicit)."""
from benchmarks.common import emit_row
from repro.core import MDRQEngine
from repro.data import gmrqb, synthetic


def run(quick: bool = True) -> None:
    for name, ds in (("synt_1M5" if not quick else "synt_200k5",
                      synthetic.synt_uni(200_000 if quick else 1_000_000, 5, 0)),
                     ("gmrqb", gmrqb.build(200_000 if quick else 10_000_000, 0))):
        eng = MDRQEngine(ds)
        rep = eng.memory_report()
        for k, v in rep.items():
            if k == "data":
                emit_row(f"mem/{name}/data", 0.0, f"bytes={v}")
            else:
                emit_row(f"mem/{name}/{k}", 0.0,
                         f"bytes={v};ratio_vs_data={v / rep['data']:.4f}")
