"""Diff a fresh BENCH_smoke.json against the checked-in baseline.

CI regression guard for the serving path: ``make bench-smoke`` writes a fresh
artifact, and this script compares its per-batch-size qps to the baseline
with a guard band (default +-30%). Outside the band it *warns* — shared CI
runners are too noisy for a hard throughput gate — and exits 0; ``--strict``
turns the warnings into a non-zero exit for dedicated perf machines.

  PYTHONPATH=src python -m benchmarks.check_bench /tmp/BENCH_smoke.json \
      BENCH_smoke.json [--band 0.30] [--strict]
"""
from __future__ import annotations

import argparse
import json
import sys


def _by_batch(doc: dict) -> dict[int, dict]:
    return {int(b["batch"]): b for b in doc.get("batches", [])}


def compare(fresh: dict, baseline: dict, band: float) -> list[str]:
    """Human-readable comparison lines; entries breaching the band are
    prefixed with WARN."""
    out = []
    fb, bb = _by_batch(fresh), _by_batch(baseline)
    for batch in sorted(bb):
        base = bb[batch]["qps"]
        if batch not in fb:
            out.append(f"WARN B{batch}: missing from fresh run "
                       f"(baseline qps={base:.1f})")
            continue
        cur = fb[batch]["qps"]
        ratio = cur / base if base > 0 else float("inf")
        line = (f"B{batch}: qps {cur:.1f} vs baseline {base:.1f} "
                f"(x{ratio:.2f}, band x{1 - band:.2f}..x{1 + band:.2f})")
        if not (1.0 - band) <= ratio <= (1.0 + band):
            line = "WARN " + line
        out.append(line)
    for batch in sorted(set(fb) - set(bb)):
        out.append(f"B{batch}: new (qps={fb[batch]['qps']:.1f}, no baseline)")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="just-produced BENCH_smoke.json")
    ap.add_argument("baseline", help="checked-in BENCH_smoke.json")
    ap.add_argument("--band", type=float, default=0.30,
                    help="relative qps guard band (0.30 = +-30%%)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any WARN (perf-dedicated runners)")
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    lines = compare(fresh, baseline, args.band)
    warned = False
    for line in lines:
        warned = warned or line.startswith("WARN")
        print(line, flush=True)
    if warned:
        print("check_bench: qps outside the guard band (warn-only; "
              "rerun or refresh the baseline via `make bench-smoke`)"
              if not args.strict else
              "check_bench: FAILED (--strict)", flush=True)
    return 1 if (warned and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
