"""Diff a fresh bench JSON artifact against the checked-in baseline.

CI regression guard for the serving path: ``make bench-smoke`` /
``make bench-pipeline-smoke`` write fresh artifacts, and this script compares
their qps points to the baseline with a guard band (default +-30%). Outside
the band it *warns* — shared CI runners are too noisy for a hard throughput
gate — and exits 0; ``--strict`` turns the warnings into a non-zero exit for
dedicated perf machines.

Two artifact shapes are understood, keyed by which point list the doc
carries:

  * ``batches``: per-batch-size points, keyed ``B<batch>``, metric ``qps``
    (BENCH_smoke.json);
  * ``offered``: offered-load sweep points, keyed by the machine-independent
    ladder fraction ``offered<frac>x``, metric ``achieved_qps``
    (BENCH_pipeline.json — absolute offered qps differs across machines, the
    ladder fraction does not). The pipeline doc's ``head_to_head`` qps pair
    is compared too.

  PYTHONPATH=src python -m benchmarks.check_bench /tmp/BENCH_smoke.json \
      BENCH_smoke.json [--band 0.30] [--strict]
"""
from __future__ import annotations

import argparse
import json
import sys


def _points(doc: dict) -> dict[str, float]:
    """label -> qps metric, for whichever point list the artifact carries."""
    out: dict[str, float] = {}
    for b in doc.get("batches", []):
        out[f"B{int(b['batch'])}"] = float(b["qps"])
    for p in doc.get("offered", []):
        out[f"offered{p['frac']:g}x"] = float(p["achieved_qps"])
    hth = doc.get("head_to_head")
    if hth:
        out["sync"] = float(hth["sync_qps"])
        out["pipelined"] = float(hth["pipelined_qps"])
    return out


def compare(fresh: dict, baseline: dict, band: float) -> list[str]:
    """Human-readable comparison lines; entries breaching the band are
    prefixed with WARN."""
    out = []
    fb, bb = _points(fresh), _points(baseline)
    for label, base in bb.items():
        if label not in fb:
            out.append(f"WARN {label}: missing from fresh run "
                       f"(baseline qps={base:.1f})")
            continue
        cur = fb[label]
        ratio = cur / base if base > 0 else float("inf")
        line = (f"{label}: qps {cur:.1f} vs baseline {base:.1f} "
                f"(x{ratio:.2f}, band x{1 - band:.2f}..x{1 + band:.2f})")
        if not (1.0 - band) <= ratio <= (1.0 + band):
            line = "WARN " + line
        out.append(line)
    for label in fb:
        if label not in bb:
            out.append(f"{label}: new (qps={fb[label]:.1f}, no baseline)")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="just-produced bench JSON artifact")
    ap.add_argument("baseline", help="checked-in baseline artifact")
    ap.add_argument("--band", type=float, default=0.30,
                    help="relative qps guard band (0.30 = +-30%%)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any WARN (perf-dedicated runners)")
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    lines = compare(fresh, baseline, args.band)
    warned = False
    for line in lines:
        warned = warned or line.startswith("WARN")
        print(line, flush=True)
    if warned:
        print("check_bench: qps outside the guard band (warn-only; "
              "rerun or refresh the baseline via `make bench-smoke` / "
              "`make bench-pipeline-smoke`)"
              if not args.strict else
              "check_bench: FAILED (--strict)", flush=True)
    return 1 if (warned and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
