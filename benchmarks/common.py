"""Shared benchmark utilities.

All benchmarks execute with REPRO_KERNEL_BACKEND=xla (set by run.py before
any repro import): interpret-mode Pallas runs the grid as a Python loop, so
the XLA path — semantically identical to the kernels, validated in tests —
is the honest CPU throughput proxy. On a TPU the same harness times Mosaic.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Optional

import numpy as np


def time_workload(fn: Callable[[], object], n_warm: int = 2, n_iter: int = 5
                  ) -> float:
    """Median seconds per call of fn()."""
    for _ in range(n_warm):
        fn()
    ts = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run_queries(engine, queries, method: str) -> float:
    """Total seconds to run all queries with the given method (one pass)."""
    t0 = time.perf_counter()
    for q in queries:
        engine.query(q, method)
    return time.perf_counter() - t0


def qps(engine, queries, method: str, n_warm: int = 3) -> float:
    """Queries/second after warmup (the paper's throughput metric, §7.1.2)."""
    for q in queries[:n_warm]:
        engine.query(q, method)
    dt = run_queries(engine, queries, method)
    return len(queries) / dt


CSV_HEADER = "name,us_per_call,result_spec,derived"

# Every emit_row also lands here as a dict, so any bench section can be
# serialized to a BENCH_<name>.json artifact after the fact (run.py
# --json-dir; bench_throughput --json). Cleared only by mark()/rows_since
# bookkeeping — a process runs few enough rows that the list is free.
ROWS: list[dict] = []


def _parse_derived(derived: str) -> dict:
    """The ``derived`` blob's ``k=v`` pairs as a dict (numbers parsed, a
    trailing x/% unit stripped), so JSON artifacts carry qps etc. as fields
    machines can diff instead of strings they must re-parse."""
    out = {}
    for part in filter(None, derived.split(";")):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        num = v[:-1] if v and v[-1] in "x%" else v
        try:
            out[k] = float(num)
        except ValueError:
            out[k] = v
    return out


def emit_row(name: str, us: float, derived: str = "",
             result_spec: str = "ids") -> None:
    """One CSV row. ``result_spec`` is the ResultSpec kind the row measured
    ("ids" unless a benchmark sweeps reduced result shapes) — a first-class
    column so throughput tables distinguish ids/count/top-k runs instead of
    overloading the name or the derived blob."""
    print(f"{name},{us:.2f},{result_spec},{derived}", flush=True)
    ROWS.append({"name": name, "us_per_call": round(us, 2),
                 "result_spec": result_spec, "derived": derived,
                 **_parse_derived(derived)})


def mark() -> int:
    """Bookmark the row stream (pair with ``rows_since``)."""
    return len(ROWS)


def rows_since(start: int) -> list[dict]:
    return ROWS[start:]


def write_bench_json(path: str, bench: str, rows: Optional[list] = None,
                     **extra) -> None:
    """Write one ``BENCH_<name>.json`` artifact: the rows of a bench section
    plus whatever structured payload the bench adds (``extra``), e.g. the
    smoke bench's per-batch-size qps/latency entries that
    ``benchmarks.check_bench`` diffs against the checked-in baseline."""
    doc = {"bench": bench, "rows": ROWS if rows is None else rows, **extra}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
