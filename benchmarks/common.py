"""Shared benchmark utilities.

All benchmarks execute with REPRO_KERNEL_BACKEND=xla (set by run.py before
any repro import): interpret-mode Pallas runs the grid as a Python loop, so
the XLA path — semantically identical to the kernels, validated in tests —
is the honest CPU throughput proxy. On a TPU the same harness times Mosaic.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np


def time_workload(fn: Callable[[], object], n_warm: int = 2, n_iter: int = 5
                  ) -> float:
    """Median seconds per call of fn()."""
    for _ in range(n_warm):
        fn()
    ts = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run_queries(engine, queries, method: str) -> float:
    """Total seconds to run all queries with the given method (one pass)."""
    t0 = time.perf_counter()
    for q in queries:
        engine.query(q, method)
    return time.perf_counter() - t0


def qps(engine, queries, method: str, n_warm: int = 3) -> float:
    """Queries/second after warmup (the paper's throughput metric, §7.1.2)."""
    for q in queries[:n_warm]:
        engine.query(q, method)
    dt = run_queries(engine, queries, method)
    return len(queries) / dt


CSV_HEADER = "name,us_per_call,result_spec,derived"


def emit_row(name: str, us: float, derived: str = "",
             result_spec: str = "ids") -> None:
    """One CSV row. ``result_spec`` is the ResultSpec kind the row measured
    ("ids" unless a benchmark sweeps reduced result shapes) — a first-class
    column so throughput tables distinguish ids/count/top-k runs instead of
    overloading the name or the derived blob."""
    print(f"{name},{us:.2f},{result_spec},{derived}", flush=True)
