import os
os.environ.setdefault("REPRO_KERNEL_BACKEND", "xla")  # see common.py

"""Benchmark runner — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick sizes (CPU box)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
  PYTHONPATH=src python -m benchmarks.run --only fig6,fig10

Prints ``name,us_per_call,derived`` CSV rows. The roofline section reads the
dry-run artifacts under results/dryrun (run repro.launch.dryrun first).
"""
import argparse
import sys
import time
import traceback

from benchmarks import common
from benchmarks.common import CSV_HEADER

# (section name, module[, entry point — defaults to ``run``])
SECTIONS = [
    ("fig4", "benchmarks.bench_hw_features"),
    ("fig5", "benchmarks.bench_dimensionality"),
    ("fig6", "benchmarks.bench_selectivity"),
    ("fig7", "benchmarks.bench_dataset_size"),
    ("fig8", "benchmarks.bench_clusters"),
    ("fig9", "benchmarks.bench_power"),
    ("fig10", "benchmarks.bench_gmrqb"),
    ("fig11", "benchmarks.bench_scaling"),
    ("throughput", "benchmarks.bench_throughput"),
    ("throughput-count", "benchmarks.bench_throughput", "run_count"),
    # reduced result shapes (top-k / aggregate) vs ids at the largest batch
    ("throughput-specs", "benchmarks.bench_throughput", "run_specs"),
    # serve-while-ingest: qps vs delta fraction + post-compaction recovery
    ("throughput-ingest", "benchmarks.bench_throughput", "run_ingest"),
    # AOT-warmed double-buffered pipeline: sync-vs-pipelined head-to-head
    # plus the offered-load sweep (saturation knee, p99 under load)
    ("throughput-pipeline", "benchmarks.bench_throughput", "run_pipeline"),
    # multi-device sweep: needs XLA_FLAGS=--xla_force_host_platform_device_
    # count=8 in the environment (see `make bench-dist`); degrades to a D1
    # row + a pointer when the process only sees one device.
    ("throughput-dist", "benchmarks.bench_throughput", "run_devices"),
    ("mem", "benchmarks.bench_memory"),
    ("roofline", "benchmarks.bench_rooflines"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default="", help="comma-separated section names")
    ap.add_argument("--json-dir", default="",
                    help="also write one BENCH_<section>.json per section "
                         "(its CSV rows as structured records) into this "
                         "directory")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))
    if args.json_dir:
        import os as _os
        _os.makedirs(args.json_dir, exist_ok=True)

    print(CSV_HEADER, flush=True)
    failures = 0
    for name, module, *entry in SECTIONS:
        if only and name not in only:
            continue
        t0 = time.time()
        start = common.mark()
        try:
            import importlib
            mod = importlib.import_module(module)
            getattr(mod, entry[0] if entry else "run")(quick=not args.full)
            print(f"# section {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# section {name} FAILED", flush=True)
            traceback.print_exc()
            continue
        if args.json_dir:
            common.write_bench_json(
                f"{args.json_dir}/BENCH_{name}.json", name,
                rows=common.rows_since(start), full=args.full)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
