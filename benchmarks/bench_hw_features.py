"""Fig. 4: impact of hardware features (scalar / vectorized / parallel).

Paper contestants -> container analogues:
  scalar single-thread  -> numpy row loop amortized via numpy vector ops on
                           one core (the paper's Listing 1 baseline)
  + SIMD                -> XLA-vectorized columnar scan (kernel proxy)
  + multi-threading     -> shard_map over 8 host devices (subprocess)
"""
import subprocess
import sys
import os

import numpy as np

from benchmarks.common import emit_row, qps
from repro.core import Dataset, MDRQEngine
from repro.data import synthetic


def run(quick: bool = True) -> None:
    n, m = (200_000, 20)
    ds = synthetic.synt_uni(n, m, seed=0)
    rng = np.random.default_rng(1)
    queries = [synthetic.selectivity_targeted_query(ds, 1e-3, rng)
               for _ in range(30)]

    # scalar baseline: single-core numpy (row-major, early-break-free)
    rows = ds.rows()
    import time
    for _ in range(2):
        q = queries[0]
        (np.logical_and(rows >= q.lower, rows <= q.upper)).all(1).nonzero()
    t0 = time.perf_counter()
    for q in queries:
        (np.logical_and(rows >= q.lower, rows <= q.upper)).all(1).nonzero()
    dt = (time.perf_counter() - t0) / len(queries)
    emit_row("fig4/scan_scalar_numpy", dt * 1e6, f"qps={1/dt:.1f}")

    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
    for meth in ("scan", "scan_vertical", "kdtree", "vafile"):
        r = qps(eng, queries, meth)
        emit_row(f"fig4/{meth}_vectorized", 1e6 / r, f"qps={r:.1f}")

    # multi-device sharded scan (8 host devices, subprocess)
    script = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "os.environ['REPRO_KERNEL_BACKEND']='xla';"
        "import numpy as np, time;"
        "from repro.core import DistributedScan;"
        "from repro.core.distributed import make_data_mesh;"
        "from repro.data import synthetic;"
        f"ds = synthetic.synt_uni({n}, {m}, seed=0);"
        "d = DistributedScan(ds, mesh=make_data_mesh(8));"
        "rng = np.random.default_rng(1);"
        "qs = [synthetic.selectivity_targeted_query(ds, 1e-3, rng) for _ in range(30)];"
        "[d.query(q) for q in qs[:3]];"
        "t0 = time.perf_counter();"
        "[d.query(q) for q in qs];"
        "dt = (time.perf_counter() - t0) / len(qs);"
        "print('RESULT', dt)"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900, env=env)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            dt = float(line.split()[1])
            emit_row("fig4/scan_vectorized_8dev", dt * 1e6, f"qps={1/dt:.1f}")
