"""Fig. 10 + Table 1: the GMRQ Benchmark — 8 templates + mixed workload."""
import numpy as np

from benchmarks.common import emit_row, qps
from repro.core import MDRQEngine
from repro.data import gmrqb


def run(quick: bool = True) -> None:
    n = 300_000 if quick else 10_000_000
    ds = gmrqb.build(n, seed=0)
    eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
    rng = np.random.default_rng(1)
    inst = 8 if quick else 100
    for k in range(1, 9):
        queries = [gmrqb.template(k, rng, ds) for _ in range(inst)]
        sel = float(np.mean([ds.selectivity(q) for q in queries[:4]]))
        for meth in ("scan", "scan_vertical", "kdtree", "vafile"):
            r = qps(eng, queries, meth, n_warm=1)
            emit_row(f"fig10/T{k}/{meth}", 1e6 / r,
                     f"qps={r:.1f};sel={sel:.6f};paper_sel={gmrqb.PAPER_TABLE1[k-1].avg_selectivity:.6f}")
    mixed = [q for _, q in gmrqb.mixed_workload(ds, 2 * inst, seed=2)]
    for meth in ("scan", "scan_vertical", "kdtree", "vafile", "auto"):
        r = qps(eng, mixed, meth, n_warm=1)
        emit_row(f"fig10/mixed/{meth}", 1e6 / r, f"qps={r:.1f}")
