"""Fig. 6: throughput vs query selectivity + measured break-even point —
the paper's headline experiment (break-even ~1% on 1M x 5)."""
import numpy as np

from benchmarks.common import emit_row, qps
from repro.core import MDRQEngine
from repro.data import synthetic

SELS = (1e-5, 1e-4, 1e-3, 1e-2, 0.05, 0.2, 0.5)


def run(quick: bool = True) -> None:
    n = 200_000 if quick else 1_000_000
    ds = synthetic.synt_uni(n, 5, seed=0)
    eng = MDRQEngine(ds)
    rng = np.random.default_rng(2)
    scan_t, kd_t = {}, {}
    for sel in SELS:
        queries = [synthetic.selectivity_targeted_query(ds, sel, rng)
                   for _ in range(20)]
        meas = float(np.mean([ds.selectivity(q) for q in queries[:5]]))
        for meth in ("scan", "kdtree", "rstar", "vafile"):
            r = qps(eng, queries, meth)
            emit_row(f"fig6/sel{sel:g}/{meth}", 1e6 / r,
                     f"qps={r:.1f};measured_sel={meas:.6f}")
            if meth == "scan":
                scan_t[sel] = 1.0 / r
            if meth == "kdtree":
                kd_t[sel] = 1.0 / r
    # measured break-even: first selectivity where the scan beats the kd-tree
    be = next((s for s in SELS if kd_t[s] >= scan_t[s]), None)
    emit_row("fig6/break_even_selectivity", 0.0,
             f"break_even<={be};paper=0.01")
