"""Fig. 8: throughput vs #clusters (SYNT-CLUST; selectivity rises with k)."""
import numpy as np

from benchmarks.common import emit_row, qps
from repro.core import MDRQEngine
from repro.data import synthetic


def run(quick: bool = True) -> None:
    n = 100_000 if quick else 1_000_000
    for k in (1, 5, 10, 20):
        ds = synthetic.synt_clust(n, 5, k, seed=k)
        eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
        queries = synthetic.workload(ds, 15, seed=k + 10)
        sel = float(np.mean([ds.selectivity(q) for q in queries[:5]]))
        for meth in ("scan", "kdtree", "vafile"):
            r = qps(eng, queries, meth)
            emit_row(f"fig8/k{k}/{meth}", 1e6 / r, f"qps={r:.1f};sel={sel:.4f}")
