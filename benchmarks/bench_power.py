"""Fig. 9: POWER (DEBS-2012-shaped) real-world-skew dataset, size sweep."""
import numpy as np

from benchmarks.common import emit_row, qps
from repro.core import MDRQEngine
from repro.data import synthetic


def run(quick: bool = True) -> None:
    sizes = (10_000, 100_000, 400_000) if quick else (10_000, 100_000, 1_000_000, 10_000_000)
    for n in sizes:
        ds = synthetic.power(n, seed=0)
        eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
        queries = synthetic.workload(ds, 15, seed=5)
        sel = float(np.mean([ds.selectivity(q) for q in queries[:5]]))
        for meth in ("scan", "kdtree", "vafile"):
            r = qps(eng, queries, meth)
            emit_row(f"fig9/n{n}/{meth}", 1e6 / r, f"qps={r:.1f};sel={sel:.4f}")
