"""Fig. 5: throughput vs dimensionality (5..100 dims, SYNT-UNI)."""
import numpy as np

from benchmarks.common import emit_row, qps
from repro.core import MDRQEngine
from repro.data import synthetic


def run(quick: bool = True) -> None:
    n = 100_000 if quick else 1_000_000
    for m in (5, 10, 20, 50, 100):
        ds = synthetic.synt_uni(n, m, seed=m)
        eng = MDRQEngine(ds, structures=("scan", "kdtree", "vafile"))
        queries = synthetic.workload(ds, 20, seed=m + 1)
        sel = float(np.mean([ds.selectivity(q) for q in queries[:5]]))
        for meth in ("scan", "kdtree", "vafile"):
            r = qps(eng, queries, meth)
            emit_row(f"fig5/m{m}/{meth}", 1e6 / r, f"qps={r:.1f};sel={sel:.5f}")
